"""Serve a small sparse model with batched requests through the
continuous-batching engine (prefill + per-slot decode).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine

cfg = get_reduced("deepseek-v2-lite-16b")  # MLA + MoE, 2:4-compressed
lm = LM(cfg)
params = lm.init(jax.random.PRNGKey(0))

eng = ServeEngine(lm, params, slots=4, max_seq=96, prefill_len=16,
                  temperature=0.0)
rng = np.random.default_rng(0)
t0 = time.time()
for i in range(10):
    eng.submit(Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab_size, size=16).astype(np.int32),
        max_new=8 + (i % 4)))
done = eng.run()
dt = time.time() - t0
tokens = sum(len(r.out) for r in done)
assert len(done) == 10 and all(len(r.out) == r.max_new for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.1f}s "
      f"({tokens/dt:.1f} tok/s on CPU, 4 slots, MLA cache + MoE experts)")
for r in done[:3]:
    print(f"  rid={r.rid}: {r.out}")
print("serve_decode OK")
