"""End-to-end driver: train a ~100M-param 2:4-sparse LM for a few hundred
steps on the synthetic pipeline, with checkpointing and fault-tolerant
resume, and verify the loss drops.

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    AttnConfig, Block, FFNConfig, ModelConfig, SparsityConfig,
)
from repro.core.sparsity import NMConfig
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models.transformer import LM
from repro.optim.optimizer import AdamWConfig, adamw_init
from repro.training.checkpoint import Checkpointer
from repro.training.fault_tolerance import run_resilient
from repro.training.train_loop import TrainConfig, make_train_step


def model_100m(sparse=True) -> ModelConfig:
    """~100M params (dense-equivalent): 10L, d=768, untied 32k vocab."""
    attn = AttnConfig(q_heads=12, kv_heads=4, head_dim=64)
    return ModelConfig(
        name="sparse-lm-100m", vocab_size=32_768, d_model=768,
        plan=((Block(attn, FFNConfig(d_ff=3072)), 10),), max_seq=512,
        sparsity=SparsityConfig(nm=NMConfig(2, 4), mode="compressed")
        if sparse else None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = model_100m()
    lm = LM(cfg)
    from repro.models.transformer import count_params
    print(f"model: {count_params(cfg)/1e6:.1f}M float params "
          f"({cfg.sparsity.tag})")

    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=30,
                                       total_steps=args.steps),
                       microbatches=2, remat="none")
    raw_step = jax.jit(make_train_step(lm, tcfg))

    def init_state():
        params = lm.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params)}

    losses = []

    def train_step(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = raw_step(state["params"], state["opt"], b)
        losses.append(float(m["loss"]))
        return {"params": p, "opt": o}, m

    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                       seq_len=args.seq,
                                       global_batch=args.batch))
    with tempfile.TemporaryDirectory() as d:
        res = run_resilient(train_step=train_step, init_state=init_state,
                            pipeline=pipe, ckpt=Checkpointer(d),
                            total_steps=args.steps, ckpt_every=100)
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"steps={res['steps_run']} loss {first:.3f} -> {last:.3f}")
    if args.steps >= 100:  # short runs (CI smoke) only validate wiring
        assert last < first - 0.5, "training did not converge"
    print("train_sparse_lm OK")


if __name__ == "__main__":
    main()
