"""Quickstart: the paper's technique end to end in ~60 lines.

1. Prune a dense weight matrix to 2:4 structured sparsity (paper Fig. 1b).
2. Compress it to (values, int8 col_idx).
3. Multiply with the indexmac Pallas kernel (interpret mode on CPU) and
   check it against the dense product.
4. Build a sparse transformer LM from a registry config, run one training
   step and one decode step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.sparsity import (
    NMConfig, apply_mask, compress_nm, prune_mask_nm,
)
from repro.kernels.indexmac.ops import nm_matmul
from repro.configs import get_reduced
from repro.models.transformer import LM

# --- 1-3: the kernel on a single GEMM -----------------------------------
cfg = NMConfig(2, 4)
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (512, 256))          # dense weights (K, N)
mask = prune_mask_nm(w, cfg, axis=0)            # keep top-2 |w| per 4-block
w_sp = apply_mask(w, mask)
vals, idx = compress_nm(w_sp, cfg, axis=0)      # values + bounded indices
print(f"compressed {w.size} weights -> {vals.size} values "
      f"({cfg.tag}, idx in [0,{cfg.m}))")

x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
y_kernel = nm_matmul(x, vals, idx, cfg, True)   # Pallas (interpret on CPU)
y_dense = x @ w_sp
err = float(jnp.abs(y_kernel - y_dense).max())
print(f"kernel vs dense max err: {err:.2e}")
assert err < 1e-3

# --- 4: a sparse LM from the registry ------------------------------------
model_cfg = get_reduced("yi-9b")                # 2:4-compressed projections
lm = LM(model_cfg)
params = lm.init(jax.random.PRNGKey(2))
tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0,
                            model_cfg.vocab_size)
loss, parts = lm.loss(params, {"tokens": tokens, "labels": tokens})
print(f"sparse-LM train loss: {float(loss):.3f}")

caches = lm.init_cache(2, 64)
logits, caches, _ = lm.forward(params, tokens, mode="prefill",
                               caches=caches, cache_len=jnp.int32(0))
nxt = jnp.argmax(logits[:, -1:], axis=-1)
logits, caches, _ = lm.forward(params, nxt, mode="decode", caches=caches,
                               cache_len=jnp.int32(32))
print(f"decode logits: {logits.shape} — quickstart OK")
