"""Quickstart: the paper's technique end to end in ~60 lines.

1. Prune a dense weight matrix to 2:4 structured sparsity (paper Fig. 1b)
   and compress it into a typed `NMWeight` — (values, int8 col_idx)
   leaves plus the N:M config and kernel policy as metadata.
2. Multiply with `repro.api.nm_matmul` (the Pallas indexmac kernel,
   interpret mode on CPU) and check it against the dense product.
3. Build a sparse transformer LM from a registry config, run one training
   step and one decode step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs import get_reduced
from repro.models.transformer import LM

# --- 1-2: the kernel on a single GEMM -----------------------------------
nm = api.NMConfig(2, 4)
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (512, 256))          # dense weights (K, N)
sw = api.sparsify(w, nm)                        # typed compressed weight
print(f"compressed {w.size} weights -> {sw.vals.size} values "
      f"({sw.nm.tag}, idx in [0,{sw.nm.m}), policy={sw.kernel_policy.mode})")

x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
y_kernel = api.nm_matmul(x, sw)                 # Pallas (interpret on CPU)
y_dense = x @ api.densify(sw)
err = float(jnp.abs(y_kernel - y_dense).max())
print(f"kernel vs dense max err: {err:.2e}")
assert err < 1e-3

# --- 3: a sparse LM from the registry ------------------------------------
model_cfg = get_reduced("yi-9b")                # 2:4-compressed projections
lm = LM(model_cfg)
params = lm.init(jax.random.PRNGKey(2))
n_sparse = sum(api.is_sparse(l) for l in jax.tree.leaves(
    params, is_leaf=api.is_sparse))
print(f"model carries {n_sparse} NMWeight nodes")
tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0,
                            model_cfg.vocab_size)
loss, parts = lm.loss(params, {"tokens": tokens, "labels": tokens})
print(f"sparse-LM train loss: {float(loss):.3f}")

caches = lm.init_cache(2, 64)
logits, caches, _ = lm.forward(params, tokens,
                               view=api.CacheView.prefill(), caches=caches)
nxt = jnp.argmax(logits[:, -1:], axis=-1)
logits, caches, _ = lm.forward(params, nxt,
                               view=api.CacheView.decode(jnp.int32(32)),
                               caches=caches)
print(f"decode logits: {logits.shape} — quickstart OK")
