"""Distribution tests: sharding rules produce valid specs, and reduced
cells lower+compile on a multi-device mesh (single- and multi-pod axes).

Multi-device lowering runs in a subprocess because the placeholder device
count must be set before jax initializes (the rest of the suite runs on
one device).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.models.transformer import LM
from repro.parallel.sharding import batch_pspec, cache_pspecs, param_pspecs

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.specs import make_cell, lower_cell
from repro.roofline.analysis import collective_bytes_from_hlo, analyze

out = {}
for mesh_shape, axes in [((4, 2), ("data", "model")),
                         ((2, 2, 2), ("pod", "data", "model"))]:
    mesh = jax.make_mesh(mesh_shape, axes)
    for arch, sname, kind in [("yi-9b", "t", "train"),
                              ("deepseek-v2-lite-16b", "d", "decode"),
                              ("jamba-v0.1-52b", "p", "prefill")]:
        shape = ShapeConfig(sname, 64, 8, kind)
        cell = make_cell(arch, "train_4k", mesh,
                         cfg_override=get_reduced(arch),
                         shape_override=shape, microbatches=2)
        compiled = lower_cell(cell, mesh).compile()
        rep = analyze(cell.name, compiled, cell.chips, cell.model_flops)
        key = f"{arch}|{kind}|{len(mesh_shape)}d"
        out[key] = {"coll": rep.collective_bytes_per_chip,
                    "flops": rep.flops_per_chip}
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_multi_device_cells_compile(subproc_results):
    assert len(subproc_results) == 6
    for key, v in subproc_results.items():
        assert v["flops"] > 0, key


def test_multi_pod_axis_shards(subproc_results):
    """Multi-pod (3-axis) lowering emits collectives that the 2-axis mesh
    also has — and the train cell must all-reduce gradients across pods
    (strictly more collective traffic per chip than data-only)."""
    for arch in ("yi-9b",):
        two = subproc_results[f"{arch}|train|2d"]
        three = subproc_results[f"{arch}|train|3d"]
        assert three["coll"] > 0 and two["coll"] > 0


# ---------------------------------------------------------------------------
# spec-rule unit tests (single device, no lowering)
# ---------------------------------------------------------------------------


class _FakeMesh:
    axis_names = ("data", "model")
    class devices:  # noqa: D106
        shape = (4, 2)
        size = 8


def test_param_specs_respect_divisibility():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(params, _FakeMesh, "fsdp")
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))[0]
    sflat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, sflat):
        assert len(spec) <= leaf.ndim
        for dim, s in zip(leaf.shape[-len(spec):] if spec else (), spec):
            if s is None:
                continue
            names = (s,) if isinstance(s, str) else s
            size = 1
            for a in names:
                size *= dict(zip(_FakeMesh.axis_names,
                                 _FakeMesh.devices.shape))[a]
            assert dim % size == 0, (path, leaf.shape, spec)


def test_tp_only_mode_drops_data_axis():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(params, _FakeMesh, "tp_only")
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in [a for s in spec for a in
                              ((s,) if isinstance(s, str) else (s or ()))]


def test_batch_pspec_divisibility_fallback():
    assert batch_pspec(8, _FakeMesh) == P(("data",), None)
    assert batch_pspec(3, _FakeMesh) == P(None, None)


def test_cache_specs_shard_sequence_over_model():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    caches = jax.eval_shape(lambda: lm.init_cache(8, 64, dtype=jnp.bfloat16))
    specs = cache_pspecs(caches, _FakeMesh, batch_axes=("data",))
    k_spec = specs[0][0]["k"]
    # stacked (L, B, S, H, D): batch over data, seq over model
    assert k_spec == P(None, "data", "model", None, None)
