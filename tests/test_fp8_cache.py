"""fp8 (e4m3) KV cache: a serving-side memory-traffic optimization in the
paper's spirit — halves cache bytes with no kernel changes (the cache
read/write paths cast through cache.dtype).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.cache import CacheView
from repro.models.transformer import LM


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
def test_fp8_cache_decode_top1_matches_bf16(arch):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    out = {}
    for name, dt in (("bf16", jnp.bfloat16), ("fp8", jnp.float8_e4m3fn)):
        caches = lm.init_cache(2, 32, dtype=dt)
        lp, caches, _ = lm.forward(params, tokens, view=CacheView.prefill(),
                                   caches=caches)
        nxt = jnp.argmax(lp[:, -1:], -1)
        ld, _, _ = lm.forward(params, nxt,
                              view=CacheView.decode(jnp.int32(16)),
                              caches=caches)
        out[name] = np.asarray(ld, np.float32)
    rel = (np.abs(out["bf16"] - out["fp8"]).max()
           / (np.abs(out["bf16"]).max() + 1e-9))
    assert rel < 0.15, rel  # fp8 noise stays bounded
    # greedy decoding is unchanged
    assert (out["bf16"].argmax(-1) == out["fp8"].argmax(-1)).all()


def test_fp8_cache_halves_bytes():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    c16 = jax.eval_shape(lambda: lm.init_cache(2, 32, dtype=jnp.bfloat16))
    c8 = jax.eval_shape(lambda: lm.init_cache(2, 32,
                                              dtype=jnp.float8_e4m3fn))
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c16))
    b8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c8))
    assert b8 == b16 // 2
