"""Unit + property tests for N:M sparsity primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparsity import (
    NMConfig,
    apply_mask,
    check_nm_pattern,
    compress_nm,
    decompress_nm,
    prune_mask_nm,
    random_nm_matrix,
)

CFGS = [NMConfig(1, 2), NMConfig(1, 4), NMConfig(2, 4), NMConfig(2, 8), NMConfig(4, 8)]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize("axis", [0, 1])
def test_prune_keeps_topn_magnitude(cfg, axis):
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    mask = prune_mask_nm(w, cfg, axis=axis)
    pruned = apply_mask(w, mask)
    assert check_nm_pattern(pruned, cfg, axis=axis)
    # every block keeps exactly n (no exact-zero inputs here)
    wl = np.moveaxis(np.asarray(mask), axis, -1)
    blocks = wl.reshape(*wl.shape[:-1], -1, cfg.m)
    assert (blocks.sum(-1) == cfg.n).all()
    # kept entries are the largest-|.| in each block
    wa = np.moveaxis(np.abs(np.asarray(w)), axis, -1).reshape(*blocks.shape)
    kept_min = np.where(blocks, wa, np.inf).min(-1)
    dropped_max = np.where(~blocks, wa, -np.inf).max(-1)
    assert (kept_min >= dropped_max - 1e-7).all()


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize("axis", [0, 1])
def test_compress_decompress_roundtrip(cfg, axis):
    w = random_nm_matrix(jax.random.PRNGKey(1), (48, 32), cfg, axis=axis)
    vals, idx = compress_nm(w, cfg, axis=axis)
    assert idx.dtype == jnp.int8
    assert int(idx.max()) < cfg.m and int(idx.min()) >= 0
    back = decompress_nm(vals, idx, cfg, axis=axis)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_compress_handles_underfull_blocks():
    cfg = NMConfig(2, 4)
    w = jnp.zeros((8, 4)).at[0, 1].set(3.0).at[3, 0].set(-1.0)  # <=1 nz per block
    vals, idx = compress_nm(w, cfg, axis=1)
    back = decompress_nm(vals, idx, cfg, axis=1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_bad_config_rejected():
    with pytest.raises(ValueError):
        NMConfig(4, 4)
    with pytest.raises(ValueError):
        NMConfig(0, 4)
    with pytest.raises(ValueError):
        prune_mask_nm(jnp.zeros((3, 5)), NMConfig(2, 4), axis=1)


@settings(max_examples=30, deadline=None)
@given(
    n_m=st.sampled_from([(1, 2), (1, 4), (2, 4), (2, 8)]),
    rows=st.integers(1, 6),
    blocks=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_roundtrip_and_pattern(n_m, rows, blocks, seed):
    """For any matrix pruned to N:M: pattern holds, compression is lossless,
    and the compressed form is exactly n/m the dense element count."""
    cfg = NMConfig(*n_m)
    k = blocks * cfg.m
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, k))
    pruned = apply_mask(w, prune_mask_nm(w, cfg, axis=1))
    assert check_nm_pattern(pruned, cfg, axis=1)
    vals, idx = compress_nm(pruned, cfg, axis=1)
    assert vals.shape == (rows, k * cfg.n // cfg.m)
    back = decompress_nm(vals, idx, cfg, axis=1)
    np.testing.assert_allclose(np.asarray(back), np.asarray(pruned), atol=0)


@settings(max_examples=20, deadline=None)
@given(
    n_m=st.sampled_from([(1, 4), (2, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_masked_matmul_equals_compressed_matmul(n_m, seed):
    """y computed from the masked-dense weight equals y from (vals, idx)."""
    cfg = NMConfig(*n_m)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = random_nm_matrix(k1, (32, 16), cfg, axis=0)
    x = jax.random.normal(k2, (8, 32))
    vals, idx = compress_nm(w, cfg, axis=0)
    y1 = x @ w
    y2 = x @ decompress_nm(vals, idx, cfg, axis=0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-6)
