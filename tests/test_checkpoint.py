"""Checkpointing: roundtrip, async, GC, elastic re-placement, data cursor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "idx": jnp.arange(16, dtype=jnp.int8)},
            "opt": {"step": jnp.int32(7), "m": jnp.ones((8, 8))}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state()
    ck.save(10, st, extra={"data": {"step": 10, "seed": 0}})
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            st)
    got, meta = ck.restore(template)
    assert meta["step"] == 10
    assert meta["extra"]["data"]["step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, st)


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), async_=True)
    ck.wait()
    assert ck.list_steps() == [3, 4]  # GC kept last 2
    got, meta = ck.restore(_state(0))
    assert meta["step"] == 4


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, _state(1))
    ck.save(2, _state(2))
    got, meta = ck.restore(_state(0), step=1)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, _state(1))


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore({"w": jnp.ones((5,))})


def test_elastic_replacement_onto_shardings(tmp_path):
    """Restore re-places arrays under explicit (single-device) shardings —
    the elastic-resize path; on multi-device meshes the same call re-shards
    onto the new topology."""
    ck = Checkpointer(str(tmp_path))
    st = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, st)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    got, _ = ck.restore(st, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(st["w"]))
