"""Checkpointing: roundtrip, async, GC, elastic re-placement, data cursor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "idx": jnp.arange(16, dtype=jnp.int8)},
            "opt": {"step": jnp.int32(7), "m": jnp.ones((8, 8))}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state()
    ck.save(10, st, extra={"data": {"step": 10, "seed": 0}})
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            st)
    got, meta = ck.restore(template)
    assert meta["step"] == 10
    assert meta["extra"]["data"]["step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, st)


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), async_=True)
    ck.wait()
    assert ck.list_steps() == [3, 4]  # GC kept last 2
    got, meta = ck.restore(_state(0))
    assert meta["step"] == 4


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, _state(1))
    ck.save(2, _state(2))
    got, meta = ck.restore(_state(0), step=1)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, _state(1))


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore({"w": jnp.ones((5,))})


def _nmweight_state():
    """A small param tree mixing typed sparse nodes and plain leaves."""
    from repro.api import KernelPolicy, NMConfig, sparsify

    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    w24 = sparsify(jax.random.normal(k1, (16, 8)), NMConfig(2, 4),
                   kernel_policy=KernelPolicy("auto", block=(8, 128, 128)))
    w14 = sparsify(jax.random.normal(k2, (16, 4)), NMConfig(1, 4),
                   kernel_policy="off")
    return {"params": {"ffn": {"w_up": w24}, "attn": {"wq": w14},
                       "norm": {"scale": jnp.ones((8,))}}}


def test_nmweight_roundtrip_bit_exact(tmp_path):
    """Save an NMWeight-bearing tree, restore into a fresh template:
    vals/idx bit-exact, nm/axis metadata preserved."""
    from repro.core.nmweight import NMWeight

    ck = Checkpointer(str(tmp_path))
    st = _nmweight_state()
    ck.save(5, st)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    got, meta = ck.restore(template)
    for key in ("ffn", "attn"):
        orig = list(st["params"][key].values())[0]
        rest = list(got["params"][key].values())[0]
        assert isinstance(rest, NMWeight)
        np.testing.assert_array_equal(np.asarray(rest.vals),
                                      np.asarray(orig.vals))
        np.testing.assert_array_equal(np.asarray(rest.idx),
                                      np.asarray(orig.idx))
        assert rest.nm == orig.nm and rest.axis == orig.axis
    # the manifest carries the weight metadata explicitly
    tags = {w["n"] for w in meta["weights"].values()}
    assert tags == {1, 2}


def test_nm_metadata_mismatch_rejected(tmp_path):
    """Restoring a 2:4 checkpoint into a 1:4 template of the same leaf
    shapes must fail on metadata, not decompress garbage."""
    import dataclasses

    from repro.core.sparsity import NMConfig

    ck = Checkpointer(str(tmp_path))
    st = _nmweight_state()
    ck.save(1, st)
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    w = bad["params"]["ffn"]["w_up"]
    bad["params"]["ffn"]["w_up"] = dataclasses.replace(w, nm=NMConfig(2, 8))
    with pytest.raises(ValueError, match="metadata mismatch"):
        ck.restore(bad)


def test_legacy_dict_checkpoint_migrates(tmp_path):
    """Pre-NMWeight checkpoints stored compressed weights as {vals, idx}
    dicts whose flatten order (idx first — sorted keys) is the reverse of
    NMWeight's. The migration shim must remap, not transpose."""
    import json
    import os

    from repro.core.nmweight import NMWeight
    from repro.training.checkpoint import _to_legacy

    st = _nmweight_state()
    legacy = _to_legacy(st)  # the exact tree an old Checkpointer saw
    ck = Checkpointer(str(tmp_path))
    ck.save(7, legacy)
    # strip the v2 manifest fields -> byte-identical to an old checkpoint
    mpath = os.path.join(str(tmp_path), "step_00000007", "manifest.json")
    with open(mpath) as f:
        meta = json.load(f)
    for k in ("format", "leaves", "weights"):
        meta.pop(k)
    with open(mpath, "w") as f:
        json.dump(meta, f)

    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    got, _ = ck.restore(template)
    for key in ("ffn", "attn"):
        orig = list(st["params"][key].values())[0]
        rest = list(got["params"][key].values())[0]
        assert isinstance(rest, NMWeight)
        np.testing.assert_array_equal(np.asarray(rest.vals),
                                      np.asarray(orig.vals))
        np.testing.assert_array_equal(np.asarray(rest.idx),
                                      np.asarray(orig.idx))


def test_elastic_replacement_onto_shardings(tmp_path):
    """Restore re-places arrays under explicit (single-device) shardings —
    the elastic-resize path; on multi-device meshes the same call re-shards
    onto the new topology."""
    ck = Checkpointer(str(tmp_path))
    st = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, st)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    got, _ = ck.restore(st, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(st["w"]))
