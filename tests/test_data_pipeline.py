"""Data pipeline: determinism, host sharding, cursor restore."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataPipeline, PipelineConfig


def _cfg(**kw):
    d = dict(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    d.update(kw)
    return PipelineConfig(**d)


def test_deterministic():
    a = DataPipeline(_cfg()).next()
    b = DataPipeline(_cfg()).next()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    b = DataPipeline(_cfg()).next()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_host_sharding_partitions_batch():
    full = DataPipeline(_cfg(), host_id=0, num_hosts=1)
    h0 = DataPipeline(_cfg(), host_id=0, num_hosts=2)
    h1 = DataPipeline(_cfg(), host_id=1, num_hosts=2)
    assert h0.host_batch == 4 and h1.host_batch == 4
    t0, t1 = h0.next()["tokens"], h1.next()["tokens"]
    assert t0.shape == (4, 16)
    assert not np.array_equal(t0, t1)  # hosts draw distinct data


def test_cursor_restore_resumes_exactly():
    p = DataPipeline(_cfg())
    for _ in range(5):
        p.next()
    state = p.state()
    want = p.next()["tokens"]
    q = DataPipeline(_cfg())
    q.restore(state)
    got = q.next()["tokens"]
    np.testing.assert_array_equal(want, got)


def test_seed_mismatch_rejected():
    p = DataPipeline(_cfg(seed=1))
    with pytest.raises(AssertionError):
        p.restore({"step": 3, "seed": 2})


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 50), seed=st.integers(0, 5))
def test_property_any_step_reproducible(step, seed):
    p = DataPipeline(_cfg(seed=seed))
    p.step = step
    a = p.next()["tokens"]
    q = DataPipeline(_cfg(seed=seed))
    q.restore({"step": step, "seed": seed})
    np.testing.assert_array_equal(a, q.next()["tokens"])


def test_copy_span_present():
    b = DataPipeline(_cfg(seq_len=64)).next()["tokens"]
    # at least one row has a repeated half-span (the planted copy task)
    found = False
    for row in b:
        for start in range(0, 64 - 16):
            if np.array_equal(row[start:start + 8],
                              row[start + 8:start + 16]):
                found = True
    assert found
