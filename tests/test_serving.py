"""Serving engine: continuous batching correctness + throughput accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common
from repro.configs import get_reduced
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def yi():
    common.set_compute_dtype(jnp.float32)  # exactness for scheduling tests
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    yield cfg, lm, params
    common.set_compute_dtype(jnp.bfloat16)


def test_engine_serves_all_requests(yi):
    cfg, lm, params = yi
    eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new=4 + i))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(5))
    assert all(len(r.out) == r.max_new for r in done)


def test_continuous_batching_is_isolation_safe(yi):
    """A request's output must not depend on co-scheduled requests or on
    which slot/step it was admitted in."""
    cfg, lm, params = yi
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)

    e1 = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8)
    e1.submit(Request(rid=0, prompt=p, max_new=6))
    alone = e1.run()[0].out

    e2 = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8)
    e2.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new=3))
    e2.submit(Request(rid=1, prompt=p, max_new=6))
    batched = {r.rid: r.out for r in e2.run()}[1]
    assert batched == alone


def test_decode_matches_prefill_extension(yi):
    """Greedy decode token-by-token equals argmax over a full forward."""
    cfg, lm, params = yi
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    eng = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8)
    eng.submit(Request(rid=0, prompt=p, max_new=4))
    out = eng.run()[0].out

    seq = list(p)
    ref = []
    for _ in range(4):
        logits, _, _ = lm.forward(params, jnp.asarray([seq]))
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        seq.append(nxt)
    assert out == ref


def test_temperature_sampling_runs(yi):
    cfg, lm, params = yi
    eng = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8,
                      temperature=1.0, seed=7)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new=6))
    done = eng.run()
    assert len(done[0].out) == 6


def test_temperature_sampling_is_seed_deterministic(yi):
    """The temperature path of ``_sample`` must be a pure function of the
    engine seed: two engines with the same seed produce identical token
    streams, a different seed diverges. This is what makes quantized-vs-
    bf16 serving comparisons reproducible — sampling noise never masks
    (or fakes) a quantization difference."""
    cfg, lm, params = yi

    def serve(seed):
        eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                          temperature=0.8, seed=seed)
        rng = np.random.default_rng(3)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, size=8).astype(np.int32), max_new=5))
        return {r.rid: tuple(r.out) for r in eng.run()}

    a, b = serve(seed=11), serve(seed=11)
    assert a == b  # same seed, same schedule -> bitwise-same streams
    c = serve(seed=12)
    assert c != a  # the seed actually reaches the sampler


def test_batched_admit_matches_full_forward_reference(yi):
    """Admission is now ONE fixed-shape prefill call per engine step (the
    seed engine ran a full slots x prefill_len forward per request and
    discarded all but one slot's rows). Output token ids must be exactly
    what the seed semantics produce: prompt truncated to the *tail*
    prefill_len tokens, left-padded with zeros, then a greedy argmax
    chain — verified against a per-request full forward."""
    cfg, lm, params = yi
    rng = np.random.default_rng(7)
    # mixed lengths: shorter than, equal to, and longer than prefill_len
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 8, 11, 3)]
    eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    got = {r.rid: r.out for r in eng.run()}

    for i, p in enumerate(prompts):
        tail = list(p[-8:])
        seq = [0] * (8 - len(tail)) + tail
        ref = []
        for _ in range(4):
            logits, _, _ = lm.forward(params, jnp.asarray([seq]))
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            seq.append(nxt)
        assert got[i] == ref, i


def test_chunked_prefill_matches_full(yi):
    """prefill_chunk splits prompts into fixed-shape pieces (bounded
    TTFT); the served token streams must be identical to full-prompt
    prefill, including for requests admitted mid-flight into reused
    slots while other slots keep decoding."""
    cfg, lm, params = yi
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(5)]

    def serve(chunk):
        eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                          prefill_chunk=chunk)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=4 + i))
        return {r.rid: r.out for r in eng.run()}

    full = serve(None)
    assert serve(4) == full
    assert serve(2) == full


def test_chunk_must_divide_prefill_len(yi):
    cfg, lm, params = yi
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8,
                    prefill_chunk=3)


def test_long_prompt_truncation_recorded_and_strict_raises(yi):
    """A prompt longer than prefill_len keeps the seed behavior (tail
    kept, silently) but is now *recorded* on the request; a strict
    engine refuses it loudly."""
    cfg, lm, params = yi
    eng = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8)
    long_req = Request(rid=0, prompt=np.arange(12, dtype=np.int32),
                       max_new=2)
    short_req = Request(rid=1, prompt=np.arange(8, dtype=np.int32),
                        max_new=2)
    eng.submit(long_req)
    eng.submit(short_req)
    assert long_req.truncated and not short_req.truncated
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1]

    strict = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8,
                         strict=True)
    with pytest.raises(ValueError, match="strict"):
        strict.submit(Request(rid=2, prompt=np.arange(9, dtype=np.int32),
                              max_new=2))
    strict.submit(Request(rid=3, prompt=np.arange(8, dtype=np.int32),
                          max_new=2))  # exactly prefill_len is fine
    assert len(strict.run()) == 1


def test_zero_recompiles_after_warmup(yi):
    """Every device step is fixed-shape: after the first prefill+decode
    compile the jit caches must not grow, no matter how admissions and
    completions interleave."""
    cfg, lm, params = yi
    eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                      prefill_chunk=4)
    rng = np.random.default_rng(9)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new=3))
    eng.run()
    warm = eng.compiled_cache_sizes()
    if warm["prefill"] < 0:
        pytest.skip("jit cache size introspection unavailable")
    assert warm == {"prefill": 1, "decode": 1}
    for i in range(1, 6):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new=2 + i))
    eng.run()
    assert eng.compiled_cache_sizes() == warm


def test_chunked_prefill_rejects_stateful_mixers(yi):
    """mode="chunk" needs the attention cache-offset path; ssm/rwkv
    engines must refuse chunking loudly instead of mis-serving."""
    from repro.configs import get_reduced as _gr

    cfg = _gr("rwkv6-3b")
    lm = LM(cfg)
    with pytest.raises(NotImplementedError, match="attention"):
        ServeEngine(lm, jax.eval_shape(
            lambda: lm.init(jax.random.PRNGKey(0))),
            slots=1, max_seq=64, prefill_len=8, prefill_chunk=4)


def test_autotune_blocks_warmup_covers_sparse_shapes(yi, monkeypatch):
    """autotune_blocks=True must request a sweep for every compressed GEMM
    shape at both the decode (M=slots) and prefill (M=slots*prefill_len)
    row counts — pins the NMWeight-tree walk and the Kc -> K math."""
    import dataclasses

    from repro.configs.base import SparsityConfig
    from repro.core.nmweight import NMWeight
    from repro.core.sparsity import NMConfig
    from repro.kernels import autotune

    cfg, _, _ = yi
    scfg = dataclasses.replace(
        cfg, sparsity=SparsityConfig(
            nm=NMConfig(2, 4), mode="compressed", use_kernel=True))
    lm = LM(scfg)
    params = lm.init(jax.random.PRNGKey(0))

    asked = []
    monkeypatch.setattr(
        autotune, "ensure_tuned",
        lambda m, n, k, nm, dtype=None, family="", backend="tpu":
            asked.append((m, n, k, family)) or (8, 128, 128))
    ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                autotune_blocks=True)

    want = set()
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, NMWeight)):
        if isinstance(leaf, NMWeight):
            kc, n = leaf.vals.shape[-2:]
            k = kc * leaf.nm.m // leaf.nm.n
            # M = slots rows route to the decode family (its own autotune
            # keys); prefill rows sweep the default family
            want.add((2, n, k, "decode"))
            want.add((16, n, k, ""))
    assert want, "reduced config produced no compressed linears"
    assert set(asked) == want


def test_decode_step_dispatches_zero_reference_paths(yi):
    """Acceptance: with use_kernel=True, every GEMM a decode step issues
    routes to a Pallas decode-family kernel — the per-family dispatch
    counters show decode-family dispatches and zero reference-route
    entries (no record-list sniffing: the bounded history can evict,
    the counters cannot)."""
    import dataclasses

    from repro.configs.base import SparsityConfig
    from repro.core.sparsity import NMConfig
    from repro.kernels import registry

    cfg, _, _ = yi
    scfg = dataclasses.replace(
        cfg, sparsity=SparsityConfig(
            nm=NMConfig(2, 4), mode="compressed", use_kernel=True))
    lm = LM(scfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8)
    rng = np.random.default_rng(3)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new=4))
    registry.clear_history()
    # one step compiles prefill AND the first decode; dispatch counts at
    # trace time, so the decode compile's GEMMs are the M == slots rows
    # (only they route to the nm_matmul_decode* families)
    eng.step()
    counts = registry.dispatch_counts("nm_matmul_decode")
    assert counts and sum(counts.values()) > 0, \
        "decode compile issued no decode-family GEMMs"
    reference = {k: v for k, v in counts.items()
                 if not k[1].startswith("pallas")}
    assert not reference, reference


def test_autotune_warmup_uses_each_weights_own_ratio(yi, monkeypatch):
    """A model mixing N:M ratios per target (2:4 ffn, 1:4 attn) must tune
    each compressed GEMM at the K its own NMConfig implies — the old
    shape-only walk assumed one global ratio and got 1:4 layers wrong."""
    import dataclasses

    from repro.configs.base import SparsityConfig
    from repro.core.nmweight import NMWeight
    from repro.core.sparsity import NMConfig
    from repro.kernels import autotune

    cfg, _, _ = yi
    scfg = dataclasses.replace(
        cfg, sparsity=SparsityConfig(
            nm=NMConfig(2, 4), mode="compressed", use_kernel=True,
            targets=("ffn", "attn_proj"),
            nm_overrides=(("attn_proj", NMConfig(1, 4)),)))
    lm = LM(scfg)
    params = lm.init(jax.random.PRNGKey(0))
    tags = {l.nm.tag for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, NMWeight))
        if isinstance(l, NMWeight)}
    assert tags == {"2:4", "1:4"}

    asked = []
    monkeypatch.setattr(
        autotune, "ensure_tuned",
        lambda m, n, k, nm, dtype=None, family="", backend="tpu":
            asked.append((m, n, k, nm.tag)) or (8, 128, 128))
    ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                autotune_blocks=True)

    want = set()
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, NMWeight)):
        if isinstance(leaf, NMWeight):
            kc, n = leaf.vals.shape[-2:]
            k = kc * leaf.nm.m // leaf.nm.n
            for m_rows in (2, 16):
                want.add((m_rows, n, k, leaf.nm.tag))
    assert set(asked) == want
    # every 1:4 weight was tuned at K = 4 * Kc, not the 2:4 ratio's 2 * Kc
    assert any(tag == "1:4" for *_, tag in asked)


# ---------------------------------------------------------------------------
# block-sparse masked serving: token parity, dispatch proof, recompiles
# ---------------------------------------------------------------------------


def _mask_variant(cfg, **fields):
    """cfg with every AttnConfig mixer's mask/window fields replaced."""
    import dataclasses

    from repro.configs.base import AttnConfig

    def blk(b):
        if isinstance(b.mixer, AttnConfig):
            return dataclasses.replace(
                b, mixer=dataclasses.replace(b.mixer, **fields))
        return b

    plan = tuple(
        ((tuple(blk(x) for x in e) if isinstance(e, tuple) else blk(e)), r)
        for e, r in cfg.plan)
    return dataclasses.replace(cfg, plan=plan)


def _serve_prompts(lm, params, prompts, **kw):
    eng = ServeEngine(lm, params, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=3 + i))
    return {r.rid: tuple(r.out) for r in eng.run()}, eng


def test_blocksparse_serving_token_parity_and_dispatch(yi):
    """A model carrying a local MaskSpec serves token-identically to the
    dense model carrying the equivalent sliding window (slot engine,
    full prefill — the shape that routes the bs_attention prefill
    family), with zero steady-state recompiles and trace-level proof the
    sparse lowering ran: the prefill family dispatched
    xla_bs_attention and never the dense masked_reference fallback."""
    from repro.kernels import registry
    from repro.kernels.blocksparse_attn.mask import MaskSpec

    cfg, _, params = yi  # mask/window change no param shapes
    lm_dense = LM(_mask_variant(cfg, mask=None, window=12))
    lm_mask = LM(_mask_variant(
        cfg, mask=MaskSpec("local", block=8, window=12), window=None))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
               for _ in range(4)]
    kw = dict(slots=2, max_seq=64, prefill_len=32)

    dense, _ = _serve_prompts(lm_dense, params, prompts, **kw)
    registry.clear_history()
    masked, em = _serve_prompts(lm_mask, params, prompts, **kw)
    assert masked == dense
    counts = registry.dispatch_counts("bs_attention")
    assert any(op == "bs_attention" and impl == "xla_bs_attention" and n
               for (op, impl, _), n in counts.items()), counts
    assert not any(op == "bs_attention" and impl == "masked_reference" and n
                   for (op, impl, _), n in counts.items()), counts
    assert sum(registry.dispatch_counts("bs_attention_decode").values()) > 0
    warm = em.compiled_cache_sizes()
    if warm["prefill"] >= 0:
        assert warm == {"prefill": 1, "decode": 1}
    # chunked prefill routes the decode family instead (mode="chunk");
    # tokens must not change
    chunked, _ = _serve_prompts(lm_mask, params, prompts,
                                prefill_chunk=16, **kw)
    assert chunked == dense


def test_blocksparse_paged_serving_matches_slot(yi):
    """The paged engine serves a masked model token-identically to the
    slot engine (block-table gather feeding the mask-aware decode
    path), still with zero steady-state recompiles."""
    from repro.kernels import registry
    from repro.kernels.blocksparse_attn.mask import MaskSpec

    cfg, _, params = yi
    lm_mask = LM(_mask_variant(
        cfg, mask=MaskSpec("local", block=8, window=12), window=None))
    rng = np.random.default_rng(12)
    prompts = [rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
               for _ in range(4)]
    kw = dict(slots=2, max_seq=64, prefill_len=32, prefill_chunk=16)
    slot_out, _ = _serve_prompts(lm_mask, params, prompts, **kw)
    registry.clear_history()
    paged_out, ep = _serve_prompts(lm_mask, params, prompts, paged=True,
                                   **kw)
    assert paged_out == slot_out
    assert sum(registry.dispatch_counts("bs_attention_decode").values()) > 0
    cs = ep.compiled_cache_sizes()
    assert cs in ({"prefill": 1, "decode": 1},
                  {"prefill": -1, "decode": -1}), cs
