"""Validation of the paper's §IV claims against our reproduction.

Claims (IndexMAC, 2023):
  Fig. 5 — avg total speedup 1.95x (1:4) and 1.88x (2:4)
  Fig. 6 — avg memory-access reduction 48% (1:4), 65% (2:4); the
           reduction is LARGER at 2:4
  Fig. 4 — per-layer speedups within ~1.6-2.2x

Our instruction/traffic model is calibrated with ONE constant
(stall_indexed); the assertions below check the *predicted* quantities
against the paper's bands. The 1:4-vs-2:4 speedup ordering (a 3.7%
second-order effect in the paper) is not captured by a counting model and
is documented in EXPERIMENTS.md.
"""
import numpy as np

from benchmarks import fig5_cnn_totals, fig6_memory_traffic
from benchmarks.cnn_specs import CNNS, resnet50_gemms
from repro.core.cost_model import VectorCoreModel
from repro.core.sparsity import NMConfig


def test_fig5_total_speedups_in_band():
    res = fig5_cnn_totals.run()
    for (cnn, tag), sp in res.items():
        assert 1.6 < sp < 2.2, (cnn, tag, sp)
    avg_14 = np.mean([res[(c, "1:4")] for c in CNNS])
    avg_24 = np.mean([res[(c, "2:4")] for c in CNNS])
    # paper: 1.95 / 1.88; combined average within 5%
    combined = (avg_14 + avg_24) / 2
    assert abs(combined - 1.915) / 1.915 < 0.05, (avg_14, avg_24)


def test_fig6_memory_reduction_matches_paper():
    res = fig6_memory_traffic.run()
    avg_14 = np.mean([res[(c, "1:4")] for c in CNNS])
    avg_24 = np.mean([res[(c, "2:4")] for c in CNNS])
    assert 0.35 < avg_14 < 0.55, avg_14  # paper: 0.48
    assert 0.55 < avg_24 < 0.75, avg_24  # paper: 0.65
    assert avg_24 > avg_14  # paper's key ordering (Fig. 6)


def test_fig4_per_layer_band():
    model = VectorCoreModel()
    for cfg, lo_p, hi_p in ((NMConfig(1, 4), 1.60, 2.15),
                            (NMConfig(2, 4), 1.63, 1.99)):
        sp = [model.speedup(m, k, n, cfg)
              for _, m, k, n in resnet50_gemms()]
        # every modeled layer inside a slightly widened paper band
        assert min(sp) > lo_p - 0.15 and max(sp) < hi_p + 0.15, (
            cfg.tag, min(sp), max(sp))


def test_speedup_monotone_in_stall():
    """More exposed memory latency -> more benefit from vindexmac (the
    mechanism's premise: it eliminates indexed loads)."""
    m, k, n = 256, 1152, 784
    cfg = NMConfig(2, 4)
    s_fast = VectorCoreModel(stall_indexed=1.0).speedup(m, k, n, cfg)
    s_slow = VectorCoreModel(stall_indexed=8.0).speedup(m, k, n, cfg)
    assert s_slow > s_fast


def test_tpu_kernel_decode_gemms_memory_bound_win():
    """Beyond-paper: on v5e constants, decode-shaped GEMMs are memory-bound
    and the compressed kernel's roofline time improves by ~the byte
    ratio."""
    from repro.core.cost_model import tpu_dense_cost, tpu_indexmac_cost

    cfg = NMConfig(2, 4)
    m, k, n = 16, 4096, 11008  # yi-9b FFN at decode
    dense = tpu_dense_cost(m, k, n)
    sp = tpu_indexmac_cost(m, k, n, cfg)
    assert dense.t_mem() > dense.t_compute()  # memory-bound
    gain = dense.t_mem() / sp.t_mem()
    assert 1.25 < gain < 1.4  # ~1/0.75 byte ratio (+x/out bytes)
