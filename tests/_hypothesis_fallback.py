"""Deterministic mini-implementation of the `hypothesis` subset the suite
uses, installed by conftest.py when the real package is absent.

The real hypothesis is a declared dev dependency (pyproject.toml) and is
what CI installs; this fallback keeps the property tests *running* (not
skipped, not collection errors) on minimal images: a fixed-seed RNG draws
``max_examples`` examples per test. No shrinking, no database — failures
reproduce exactly because the seed is fixed.

Supported: ``given`` (kwargs form), ``settings(max_examples, deadline)``,
``strategies.integers``, ``strategies.sampled_from``, ``strategies.booleans``.
Anything else raises immediately with a pointer to install hypothesis.
"""
from __future__ import annotations

import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0


class _Strategy:
    def __init__(self, draw_fn, repr_str):
        self._draw = draw_fn
        self._repr = repr_str

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return self._repr


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements), f"sampled_from({elements!r})")


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")


def given(**kw_strategies):
    if not kw_strategies:
        raise TypeError("fallback given() supports keyword strategies only")

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # noqa: BLE001 — annotate the example
                    raise AssertionError(
                        f"falsifying example (fallback hypothesis): {drawn}"
                    ) from e

        # zero-arg signature on purpose: pytest must not mistake the drawn
        # parameters for fixtures (real hypothesis does the same)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def _unsupported(name):
    if name.startswith("__"):  # module machinery probes (__path__, ...)
        raise AttributeError(name)
    raise NotImplementedError(
        f"hypothesis fallback does not implement {name!r}; "
        "pip install hypothesis for the full library"
    )


def install() -> None:
    """Register fallback modules as `hypothesis` / `hypothesis.strategies`."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.__getattr__ = _unsupported

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__version__ = "0.0-fallback"
    hyp.__getattr__ = _unsupported

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
