"""Training substrate: optimizer semantics, microbatch equivalence,
gradient compression, loss-goes-down integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models.transformer import LM
from repro.optim.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.training.train_loop import (
    TrainConfig,
    init_compress_state,
    make_train_step,
)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 60, 110, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6  # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6  # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6  # floor
    assert abs(lrs[5] - 0.1) < 1e-6  # stays at floor


def test_adamw_skips_int_leaves():
    params = {"w": jnp.ones((4, 4)), "idx": jnp.zeros((4, 4), jnp.int8)}
    grads = {"w": jnp.ones((4, 4)),
             "idx": np.zeros((4, 4), jax.dtypes.float0)}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    new, st, m = adamw_update(cfg, params, grads, st)
    assert (np.asarray(new["idx"]) == 0).all()
    assert new["idx"].dtype == jnp.int8
    assert not np.allclose(np.asarray(new["w"]), 1.0)  # w moved


def test_adamw_excludes_nmweight_idx_structurally():
    """No AdamW moments/updates may ever be allocated for the idx leaf
    of an NMWeight — excluded by node type, not dtype — while an
    unrelated integer leaf elsewhere keeps its historical pass-through
    behavior (scalar moment placeholder, leaf untouched)."""
    import dataclasses

    from repro.api import NMConfig, sparsify
    from repro.core.nmweight import NMWeight

    w = sparsify(jax.random.normal(jax.random.PRNGKey(0), (8, 4)),
                 NMConfig(2, 4), kernel_policy="off")
    params = {"lin": w, "b": jnp.ones((4,)),
              "counter": jnp.arange(3, dtype=jnp.int32)}  # unrelated int
    st = adamw_init(params)
    # structural exclusion: idx moment is a scalar placeholder, never
    # an idx-shaped buffer
    assert isinstance(st["m"]["lin"], NMWeight)
    assert st["m"]["lin"].idx.shape == ()
    assert st["m"]["lin"].vals.shape == w.vals.shape
    assert st["m"]["counter"].shape == ()  # int leaf: unchanged behavior

    def loss(p):
        x = jnp.ones((2, 8))
        y = x @ p["lin"].to_dense() + p["b"]
        return jnp.sum(y ** 2)

    grads = jax.grad(loss, allow_int=True)(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    new, st2, _ = adamw_update(cfg, params, grads, st)
    np.testing.assert_array_equal(np.asarray(new["lin"].idx),
                                  np.asarray(w.idx))  # idx bit-identical
    assert new["lin"].idx.dtype == jnp.int8
    assert st2["m"]["lin"].idx.shape == ()  # still no idx-shaped state
    np.testing.assert_array_equal(np.asarray(new["counter"]),
                                  np.asarray(params["counter"]))
    assert not np.allclose(np.asarray(new["lin"].vals),
                           np.asarray(w.vals))  # vals trained
    assert not np.allclose(np.asarray(new["b"]), 1.0)

    # a masked weight's dense w keeps training (recursed, not excluded)
    from repro.core.nmweight import MaskedNMWeight
    mp = {"lin": MaskedNMWeight(w=jnp.ones((8, 4)), nm=NMConfig(2, 4))}
    mst = adamw_init(mp)
    assert mst["m"]["lin"].w.shape == (8, 4)
    mg = {"lin": dataclasses.replace(mp["lin"], w=jnp.ones((8, 4)))}
    mnew, _, _ = adamw_update(cfg, mp, mg, mst)
    assert not np.allclose(np.asarray(mnew["lin"].w), 1.0)


def test_global_norm():
    g = {"a": jnp.ones((3,)) * 2.0, "b": jnp.ones((4,)) * 1.0,
         "i": jnp.zeros((2,), jnp.int8)}
    assert abs(float(global_norm(g)) - 4.0) < 1e-6  # sqrt(12+4)


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch."""
    cfg = get_reduced("codeqwen1.5-7b", sparse=False)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    p1, _, m1 = make_train_step(lm, TrainConfig(microbatches=1, remat="none"))(
        params, opt, batch)
    p4, _, m4 = make_train_step(lm, TrainConfig(microbatches=4, remat="none"))(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-3)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
        if jnp.issubdtype(a.dtype, jnp.inexact) else 0.0, p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-2  # bf16 accumulation tolerance


def test_remat_matches_no_remat():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    _, _, m0 = make_train_step(lm, TrainConfig(remat="none"))(params, opt, batch)
    _, _, m1 = make_train_step(lm, TrainConfig(remat="dots"))(params, opt, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-4)


def test_grad_compression_roundtrip():
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    err = init_compress_state(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    step = make_train_step(lm, TrainConfig(grad_compression=True))
    params2, opt2, err2, metrics = step(params, opt, batch, err)
    assert np.isfinite(float(metrics["loss"]))
    # error feedback is non-trivial
    enorm = float(global_norm(err2))
    assert enorm > 0


@pytest.mark.slow
def test_loss_decreases_end_to_end():
    """The (b)-deliverable training driver at micro scale: loss drops."""
    cfg = get_reduced("codeqwen1.5-7b")
    lm = LM(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=60),
                       microbatches=1, remat="none")
    step = jax.jit(make_train_step(lm, tcfg))
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                       global_batch=8))
    losses = []
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses[::10]
