"""Fault tolerance: injected failures, checkpoint/restart, deterministic
data resume, straggler detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.training.checkpoint import Checkpointer
from repro.training.fault_tolerance import (
    SimulatedFailure,
    StepTimer,
    run_resilient,
)


def _toy_setup(tmp_path):
    """A tiny quadratic 'model' so steps are fast and deterministic."""

    def init_state():
        return {"w": jnp.zeros((4,)), "n": jnp.int32(0)}

    @jax.jit
    def step(state, batch):
        w = state["w"] + jnp.float32(batch["tokens"].mean()) * 0.01
        return {"w": w, "n": state["n"] + 1}

    def train_step(state, batch):
        s = step(state, batch)
        return s, {"n": int(s["n"])}

    pipe = DataPipeline(PipelineConfig(vocab_size=64, seq_len=8,
                                       global_batch=4))
    ckpt = Checkpointer(str(tmp_path))
    return init_state, train_step, pipe, ckpt


def test_run_without_failures(tmp_path):
    init_state, train_step, pipe, ckpt = _toy_setup(tmp_path)
    res = run_resilient(train_step=train_step, init_state=init_state,
                        pipeline=pipe, ckpt=ckpt, total_steps=25,
                        ckpt_every=10)
    assert res["restarts"] == 0
    assert res["steps_run"] == 25
    assert ckpt.latest_step() == 25


def test_survives_injected_failures(tmp_path):
    init_state, train_step, pipe, ckpt = _toy_setup(tmp_path)
    fail_at = {7, 13}

    def hook(step):
        if step in fail_at:
            fail_at.discard(step)
            raise SimulatedFailure(f"node lost at step {step}")

    res = run_resilient(train_step=train_step, init_state=init_state,
                        pipeline=pipe, ckpt=ckpt, total_steps=20,
                        ckpt_every=5, failure_hook=hook)
    assert res["restarts"] == 2
    assert int(res["final_state"]["n"]) == 20  # every step ran exactly once


def test_resumed_run_matches_uninterrupted(tmp_path):
    """Bit-identical final state with and without failures: proves the
    checkpoint + data-cursor resume replays exactly the same batches."""
    init_a, step_a, pipe_a, ck_a = _toy_setup(tmp_path / "a")
    ref = run_resilient(train_step=step_a, init_state=init_a,
                        pipeline=pipe_a, ckpt=ck_a, total_steps=20,
                        ckpt_every=4)

    init_b, step_b, pipe_b, ck_b = _toy_setup(tmp_path / "b")
    flaky = {5, 11, 17}

    def hook(step):
        if step in flaky:
            flaky.discard(step)
            raise SimulatedFailure("boom")

    res = run_resilient(train_step=step_b, init_state=init_b,
                        pipeline=pipe_b, ckpt=ck_b, total_steps=20,
                        ckpt_every=4, failure_hook=hook)
    np.testing.assert_allclose(np.asarray(ref["final_state"]["w"]),
                               np.asarray(res["final_state"]["w"]),
                               rtol=0, atol=0)


def test_too_many_failures_raises(tmp_path):
    init_state, train_step, pipe, ckpt = _toy_setup(tmp_path)

    def hook(step):
        raise SimulatedFailure("always down")

    with pytest.raises(SimulatedFailure):
        run_resilient(train_step=train_step, init_state=init_state,
                      pipeline=pipe, ckpt=ckpt, total_steps=5,
                      ckpt_every=2, failure_hook=hook, max_restarts=3)


def test_straggler_detection():
    t = StepTimer(straggler_factor=3.0)
    for _ in range(10):
        assert not t.record(1.0)
    assert t.record(10.0)  # 10x median flags
    assert not t.record(1.1)
