"""Decode-shape parity suite for the skinny-M kernel family.

Every combination of M in {1, 2, 4, 7} x {f32, int8} x {2:4, 1:4} x
{epilogue off, bias + activation} must be *bit-exact* against the
reference composition ``activation(x @ densify(w) + bias)`` (with the
dequant scales applied before the bias for the int8 family).

Bit-exactness is checked on the integer lattice: integer-valued
operands keep every f32 accumulation exact regardless of summation
order, and (for int8) power-of-two scales keep the scale multiply
exact, so kernel and reference must agree to the last bit — any
discrepancy is a real kernel bug, not float noise. (Arbitrary absmax
scales can differ by 1 ulp from the two-op reference when the backend
fuses the scale-multiply and bias-add into an FMA; the lattice tests
deliberately stay where both orderings are exact.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.sparsity import NMConfig, decompress_nm
from repro.kernels import registry
from repro.kernels.epilogue import apply_epilogue_f32
from repro.quant.qnmweight import QNMWeight

K, N = 128, 256
MS = (1, 2, 4, 7)
CFGS = (NMConfig(2, 4), NMConfig(1, 4))


def _int_operands(cfg: NMConfig, m_rows: int, seed: int = 0):
    """Integer-valued (x, weight, bias) on the exact-f32 lattice."""
    kw = jax.random.randint(jax.random.PRNGKey(seed), (K, N), -4, 5)
    sw = api.sparsify(kw.astype(jnp.float32), cfg, kernel_policy="force")
    x = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (m_rows, K), -4, 5).astype(jnp.float32)
    bias = jax.random.randint(
        jax.random.PRNGKey(seed + 2), (N,), -3, 4).astype(jnp.float32)
    return x, sw, bias


def _quantized(sw) -> QNMWeight:
    """int8 weight with power-of-two scales: every dequant multiply is
    exact, so the lattice parity stays bit-for-bit."""
    vals8 = jnp.clip(sw.vals, -127, 127).astype(jnp.int8)
    scales = jnp.full((N,), 0.25, jnp.float32)
    return QNMWeight(vals=vals8, idx=sw.idx, scales=scales, nm=sw.nm,
                     axis=0, kernel_policy=sw.kernel_policy)


def _reference(x, w, bias, activation):
    """activation(f32(x) @ f32(densify(w)) [* scales] + bias) — the
    composition contract every dispatch family implements."""
    if isinstance(w, QNMWeight):
        dense = decompress_nm(w.vals, w.idx, w.nm, axis=0).astype(jnp.float32)
        y32 = (x.astype(jnp.float32) @ dense) * w.scales[None, :]
    else:
        y32 = x.astype(jnp.float32) @ api.densify(w).astype(jnp.float32)
    return apply_epilogue_f32(y32, bias, activation)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize("m_rows", MS)
@pytest.mark.parametrize("family", ["f32", "int8"])
@pytest.mark.parametrize("epilogue_on", [False, True],
                         ids=["plain", "fused"])
def test_decode_kernel_bit_exact(cfg, m_rows, family, epilogue_on):
    x, sw, bias = _int_operands(cfg, m_rows, seed=m_rows)
    w = _quantized(sw) if family == "int8" else sw
    if epilogue_on:
        ep = api.Epilogue(bias=bias, activation="silu")
        ref = _reference(x, w, bias, "silu")
    else:
        ep, ref = None, _reference(x, w, None, None)
    registry.clear_history()
    y = api.nm_matmul(x, w, epilogue=ep)
    rec = registry.last_dispatch()
    assert rec.op == ("nm_matmul_decode_q" if family == "int8"
                      else "nm_matmul_decode")
    assert rec.impl.startswith("pallas"), rec
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


@pytest.mark.parametrize("activation",
                         ["relu", "gelu", "silu", "relu_sq"])
def test_every_activation_bit_exact(activation):
    cfg = NMConfig(2, 4)
    x, sw, bias = _int_operands(cfg, 4, seed=17)
    y = api.nm_matmul(x, sw,
                      epilogue=api.Epilogue(bias=bias, activation=activation))
    ref = _reference(x, sw, bias, activation)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


def test_reference_decode_matches_kernel_composition():
    """Policy "off" still routes to the decode family (reference impl)
    and applies the identical composition — flipping use_kernel never
    changes the arithmetic on the lattice."""
    cfg = NMConfig(2, 4)
    x, sw, bias = _int_operands(cfg, 2, seed=23)
    ep = api.Epilogue(bias=bias, activation="relu")
    y_kernel = api.nm_matmul(x, sw, epilogue=ep)
    sw_off = dataclasses.replace(sw, kernel_policy=api.KernelPolicy("off"))
    registry.clear_history()
    y_ref = api.nm_matmul(x, sw_off, epilogue=ep)
    assert registry.last_dispatch().impl == "reference_decode"
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_ref))


def test_bias_only_and_activation_only():
    cfg = NMConfig(1, 4)
    x, sw, bias = _int_operands(cfg, 7, seed=29)
    y_b = api.nm_matmul(x, sw, epilogue=api.Epilogue(bias=bias))
    np.testing.assert_array_equal(
        np.asarray(y_b), np.asarray(_reference(x, sw, bias, None)))
    y_a = api.nm_matmul(x, sw, epilogue=api.Epilogue(activation="relu_sq"))
    np.testing.assert_array_equal(
        np.asarray(y_a), np.asarray(_reference(x, sw, None, "relu_sq")))


def test_leading_batch_dims_flatten_into_decode_m():
    cfg = NMConfig(2, 4)
    x, sw, _ = _int_operands(cfg, 6, seed=31)
    x3 = x.reshape(2, 3, K)
    registry.clear_history()
    y = api.nm_matmul(x3, sw)
    assert registry.last_dispatch().op == "nm_matmul_decode"
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(_reference(x, sw, None, None)).reshape(
            2, 3, N))


def test_decode_m_max_env_moves_the_threshold(monkeypatch):
    cfg = NMConfig(2, 4)
    x, sw, _ = _int_operands(cfg, 7, seed=37)
    monkeypatch.setenv("REPRO_DECODE_M_MAX", "4")
    assert api.explain_dispatch((7, K), sw).op == "nm_matmul"
    assert api.explain_dispatch((4, K), sw).op == "nm_matmul_decode"


def test_fused_epilogue_grads_flow():
    """The fused float path trains: grads reach x, vals and bias through
    the custom_vjp (reference-composition backward)."""
    cfg = NMConfig(2, 4)
    x, sw, bias = _int_operands(cfg, 2, seed=41)

    def loss(xv, vv, bv):
        w = dataclasses.replace(sw, vals=vv)
        y = api.nm_matmul(
            xv, w, epilogue=api.Epilogue(bias=bv, activation="silu"))
        return (y ** 2).sum()

    def ref_loss(xv, vv, bv):
        dense = decompress_nm(vv, sw.idx, cfg, axis=0).astype(jnp.float32)
        y = apply_epilogue_f32(xv.astype(jnp.float32) @ dense, bv, "silu")
        return (y ** 2).sum()

    dx, dv, db = jax.grad(loss, argnums=(0, 1, 2))(x, sw.vals, bias)
    rx, rv, rb = jax.grad(ref_loss, argnums=(0, 1, 2))(x, sw.vals, bias)
    assert dx.shape == x.shape and dv.shape == sw.vals.shape
    assert db.shape == bias.shape
    for g in (dx, dv, db):
        assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(rv), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rb), rtol=1e-5,
                               atol=1e-12)
