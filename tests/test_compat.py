"""Regression: the compat shim resolves on the installed JAX, and no
source file outside repro/compat.py touches the drifted names directly."""
import pathlib

import pytest

from repro import compat

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
BENCH = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"

# Names whose home/spelling moved between jax 0.4.x and 0.5.x — only the
# shim may reference them.
DRIFTED = ("get_abstract_mesh", "TPUCompilerParams", "pltpu.CompilerParams",
           "jax.set_mesh", "use_mesh", "jax.shard_map", "check_rep")


def test_all_shims_resolved():
    res = compat.resolved()
    assert set(res) == {
        "get_abstract_mesh", "set_mesh", "make_mesh", "tpu_compiler_params",
        "shard_map", "cost_analysis", "register_dataclass",
    }
    # pallas ships with every jax we support — params must have resolved
    assert res["tpu_compiler_params"] != "unavailable", res


def test_mesh_context_roundtrip():
    assert compat.get_abstract_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        active = compat.get_abstract_mesh()
        assert active is not None
        assert tuple(active.axis_names) == ("data",)
        assert dict(active.shape) == {"data": 1}
        assert not compat.manual_axis_in(active)
    assert compat.get_abstract_mesh() is None


def test_tpu_compiler_params_constructs():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
    assert params is not None
    assert tuple(params.dimension_semantics) == (
        "parallel", "parallel", "arbitrary"
    )


def test_unknown_param_fields_are_dropped():
    # field sets drifted too: unknown kwargs must not blow up the caller
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel",),
        definitely_not_a_real_field_xyz=1,
    )
    assert params is not None


@pytest.mark.parametrize("root", [SRC, BENCH], ids=["src", "benchmarks"])
def test_no_drifted_api_outside_compat(root):
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        text = path.read_text()
        for name in DRIFTED:
            if name in text:
                offenders.append(f"{path.relative_to(root)}: {name}")
    assert not offenders, (
        "version-drifted JAX APIs referenced outside repro/compat.py:\n"
        + "\n".join(offenders)
    )
