"""Public facade (`repro.api`) + NMWeight pytree semantics + the
mixed-per-layer-sparsity acceptance flow (init -> train -> serve ->
checkpoint round-trip) + the API-freeze guard that keeps the typed
representation from regressing into dict key-sniffing / sp= threading."""
import ast
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.nmweight import KernelPolicy, MaskedNMWeight, NMWeight
from repro.core.sparsity import NMConfig, check_nm_pattern, random_nm_matrix
from repro.kernels import registry

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# sparsify / densify / nm_matmul / is_sparse
# ---------------------------------------------------------------------------


def test_sparsify_densify_roundtrip():
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (32, 16), nm, axis=0)
    sw = api.sparsify(w, nm)
    assert isinstance(sw, NMWeight)
    assert sw.vals.shape == (16, 16) and sw.idx.dtype == jnp.int8
    assert sw.nm == nm and sw.axis == 0
    np.testing.assert_array_equal(np.asarray(api.densify(sw)),
                                  np.asarray(w))  # lossless on N:M input


def test_sparsify_prunes_dense_input():
    nm = NMConfig(2, 4)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    sw = api.sparsify(w, nm)
    assert check_nm_pattern(api.densify(sw), nm, axis=0)


def test_sparsify_validates():
    with pytest.raises(ValueError, match="divisible"):
        api.sparsify(jnp.ones((10, 4)), NMConfig(2, 4))
    with pytest.raises(ValueError, match="2D"):
        api.sparsify(jnp.ones((8,)), NMConfig(2, 4))
    with pytest.raises(TypeError, match="kernel_policy"):
        api.sparsify(jnp.ones((8, 4)), NMConfig(2, 4), kernel_policy=42)
    with pytest.raises(ValueError, match="mode"):
        KernelPolicy(mode="sometimes")


def test_is_sparse():
    nm = NMConfig(2, 4)
    sw = api.sparsify(jnp.ones((8, 4)), nm)
    assert api.is_sparse(sw)
    assert api.is_sparse(MaskedNMWeight(w=jnp.ones((8, 4)), nm=nm))
    assert not api.is_sparse({"w": jnp.ones((8, 4))})
    assert not api.is_sparse(jnp.ones((8, 4)))


def test_densify_on_dense_nodes():
    w = jnp.ones((8, 4))
    np.testing.assert_array_equal(np.asarray(api.densify({"w": w})),
                                  np.asarray(w))
    np.testing.assert_array_equal(np.asarray(api.densify(w)), np.asarray(w))


def test_nm_matmul_matches_dense():
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(2), (256, 128), nm, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 256))
    sw = api.sparsify(w, nm)
    y = api.nm_matmul(x, sw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)
    with pytest.raises(TypeError, match="NMWeight"):
        api.nm_matmul(x, {"vals": sw.vals, "idx": sw.idx})


def test_nm_matmul_rejects_wrong_axis():
    nm = NMConfig(2, 4)
    sw = api.sparsify(jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
                      nm, axis=1)
    with pytest.raises(ValueError, match="axis"):
        api.nm_matmul(jnp.ones((4, 16)), sw)


# ---------------------------------------------------------------------------
# kernel policy drives dispatch
# ---------------------------------------------------------------------------


def _policy_weight(mode, k=256, n=128):
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(4), (k, n), nm, axis=0)
    return w, api.sparsify(w, nm, kernel_policy=mode)


def test_policy_off_pins_reference():
    w, sw = _policy_weight("off")
    registry.clear_history()
    api.nm_matmul(jnp.ones((64, 256)), sw)
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "reference" and "use_kernel=False" in rec.reason


def test_policy_auto_takes_kernel_when_shape_allows():
    w, sw = _policy_weight("auto")
    registry.clear_history()
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 256))
    y = api.nm_matmul(x, sw)
    assert registry.last_dispatch("nm_matmul").impl == "pallas_padded"
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_policy_auto_respects_waste_limit_force_ignores_it():
    # prefill-shaped family: N=16 pads to one 128 lane -> 8x waste > 4x
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(4), (256, 16), nm, axis=0)
    sw_auto = api.sparsify(w, nm, kernel_policy="auto")
    x = jax.random.normal(jax.random.PRNGKey(6), (64, 256))
    registry.clear_history()
    api.nm_matmul(x, sw_auto)
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "reference" and "waste" in rec.reason

    sw_force = dataclasses.replace(sw_auto,
                                   kernel_policy=KernelPolicy("force"))
    registry.clear_history()
    y = api.nm_matmul(x, sw_force)
    assert registry.last_dispatch("nm_matmul").impl == "pallas_padded"
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_skinny_m_routes_to_decode_family():
    # M <= REPRO_DECODE_M_MAX selects the decode dispatch family — a
    # Pallas kernel, not the reference fallback the old M-padding-waste
    # heuristic produced for single-row GEMMs.
    w, sw = _policy_weight("auto")
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 256))
    registry.clear_history()
    y = api.nm_matmul(x, sw)
    rec = registry.last_dispatch("nm_matmul_decode")
    assert rec.impl == "pallas_decode" and rec.padded[0] == 8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_decode_waste_limit_and_force():
    # N=4 pads to one 128 lane: 32x N/K waste > the 16x decode limit ->
    # auto falls to reference_decode (same epilogue composition), force
    # still takes the kernel.
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(4), (256, 4), nm, axis=0)
    sw = api.sparsify(w, nm, kernel_policy="auto")
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 256))
    registry.clear_history()
    api.nm_matmul(x, sw)
    rec = registry.last_dispatch("nm_matmul_decode")
    assert rec.impl == "reference_decode" and "decode limit" in rec.reason

    sw_force = dataclasses.replace(sw, kernel_policy=KernelPolicy("force"))
    registry.clear_history()
    y = api.nm_matmul(x, sw_force)
    assert registry.last_dispatch("nm_matmul_decode").impl == "pallas_decode"
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_force_on_unnormalizable_shape_raises_typed_error():
    # satellite fix: force + a shape with no legal kernel geometry must
    # raise (naming the axis and N:M config), never silently serve the
    # reference path.
    nm = NMConfig(2, 4)
    _, sw = _policy_weight("force")
    empty = dataclasses.replace(sw, vals=sw.vals[:, :0], idx=sw.idx[:, :0])
    with pytest.raises(api.KernelForceError, match=r"axis 0.*2:4"):
        api.nm_matmul(jnp.ones((1, 256)), empty)
    with pytest.raises(api.KernelForceError):
        api.explain_dispatch((1, 256), empty)


def test_epilogue_spec_validates():
    with pytest.raises(ValueError, match="activation"):
        api.Epilogue(activation="totally_fused")
    _, sw = _policy_weight("auto")
    with pytest.raises(TypeError, match="Epilogue"):
        api.nm_matmul(jnp.ones((1, 256)), sw, epilogue="relu")


# ---------------------------------------------------------------------------
# explain_dispatch: the documented dry-run routing surface
# ---------------------------------------------------------------------------


def test_explain_dispatch_matches_execution():
    w, sw = _policy_weight("auto")
    for shape in ((1, 256), (4, 256), (64, 256)):
        rec = api.explain_dispatch(shape, sw)
        assert isinstance(rec, api.DispatchRecord)
        registry.clear_history()
        api.nm_matmul(jnp.ones(shape), sw)
        real = registry.last_dispatch(rec.op)
        assert (rec.impl, rec.shape, rec.padded, rec.block) == (
            real.impl, real.shape, real.padded, real.block)


def test_explain_dispatch_decode_vs_prefill_families():
    _, sw = _policy_weight("auto")
    assert api.explain_dispatch((8, 256), sw).op == "nm_matmul_decode"
    assert api.explain_dispatch((9, 256), sw).op == "nm_matmul"
    assert api.explain_dispatch((2, 4, 256), sw).op == "nm_matmul_decode"


def test_explain_dispatch_quantized_and_gather():
    w, sw = _policy_weight("auto")
    qw = api.quantize(sw)
    assert api.explain_dispatch((1, 256), qw).op == "nm_matmul_decode_q"
    gw = api.sparsify(
        jax.random.normal(jax.random.PRNGKey(12), (8, 64)), NMConfig(2, 4),
        axis=1, kernel_policy=KernelPolicy("auto", (8, 128, 64)))
    rec = api.explain_dispatch((64, 128), gw)
    assert rec.op == "indexmac_gather" and rec.impl == "pallas_gather"


def test_policy_block_override_recorded():
    w, _ = _policy_weight("auto")
    sw = api.sparsify(w, NMConfig(2, 4),
                      kernel_policy=KernelPolicy("auto", (128, 128, 256)))
    registry.clear_history()
    api.nm_matmul(jnp.ones((128, 256)), sw)
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "pallas_padded" and rec.block == (128, 128, 256)


# ---------------------------------------------------------------------------
# pytree semantics
# ---------------------------------------------------------------------------


def test_nmweight_is_a_pytree():
    sw = api.sparsify(jax.random.normal(jax.random.PRNGKey(7), (16, 8)),
                      NMConfig(2, 4))
    leaves, treedef = jax.tree_util.tree_flatten(sw)
    assert len(leaves) == 2  # vals, idx — metadata lives in the treedef
    doubled = jax.tree.map(lambda x: x * 2, sw)
    assert isinstance(doubled, NMWeight)
    assert doubled.nm == sw.nm and doubled.kernel_policy == sw.kernel_policy
    # different static metadata -> different treedef (mixed sparsity is
    # structurally visible)
    other = dataclasses.replace(sw, nm=NMConfig(1, 4))
    assert jax.tree_util.tree_structure(other) != treedef


def test_nmweight_paths_use_field_names():
    flat = jax.tree_util.tree_flatten_with_path({"wq": api.sparsify(
        jnp.ones((8, 4)), NMConfig(2, 4))})[0]
    names = [getattr(p[-1], "name", None) for p, _ in flat]
    assert names == ["vals", "idx"]


def test_nmweight_under_jit_and_grad():
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(8), (32, 16), nm, axis=0)
    sw = api.sparsify(w, nm, kernel_policy="off")
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 32))

    @jax.jit
    def f(x, sw):
        return api.nm_matmul(x, sw).sum()

    assert np.isfinite(float(f(x, sw)))
    g = jax.grad(lambda sw: f(x, sw), allow_int=True)(sw)
    assert isinstance(g, NMWeight)
    assert g.vals.shape == sw.vals.shape
    assert bool(jnp.isfinite(g.vals).all())


def test_nmweight_under_vmap_stacks_leaves():
    nm = NMConfig(2, 4)

    def make(key):
        return api.sparsify(jax.random.normal(key, (8, 4)), nm)

    stacked = jax.vmap(make)(jax.random.split(jax.random.PRNGKey(10), 3))
    assert isinstance(stacked, NMWeight)
    assert stacked.vals.shape == (3, 4, 4) and stacked.idx.shape == (3, 4, 4)
    assert stacked.nm == nm


def test_masked_weight_projects():
    nm = NMConfig(2, 4)
    mw = MaskedNMWeight(w=jax.random.normal(jax.random.PRNGKey(11), (16, 8)),
                        nm=nm)
    assert check_nm_pattern(mw.project(), nm, axis=0)
    # straight-through: grads wrt the dense w are defined everywhere
    g = jax.grad(lambda m: jnp.sum(m.project() ** 2))(mw)
    assert g.w.shape == (16, 8)


# ---------------------------------------------------------------------------
# mixed per-layer sparsity: the acceptance flow
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mixed_lm():
    from repro.configs import get_reduced
    from repro.configs.base import SparsityConfig
    from repro.models import common
    from repro.models.transformer import LM

    common.set_compute_dtype(jnp.float32)
    cfg = get_reduced("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(
        cfg, sparsity=SparsityConfig(
            nm=NMConfig(2, 4), mode="compressed",
            targets=("ffn", "attn_proj", "expert"),
            nm_overrides=(("expert", NMConfig(1, 4)),)))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    yield cfg, lm, params
    common.set_compute_dtype(jnp.bfloat16)


def _nm_leaves(tree):
    return [l for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, NMWeight))
        if isinstance(l, NMWeight)]


def test_mixed_sparsity_init_carries_both_configs(mixed_lm):
    _, _, params = mixed_lm
    tags = {w.nm.tag for w in _nm_leaves(params)}
    assert tags == {"2:4", "1:4"}  # 2:4 attn/ffn + 1:4 experts coexist


def test_mixed_sparsity_trains_one_step(mixed_lm):
    from repro.optim.optimizer import AdamWConfig, adamw_init
    from repro.training.train_loop import TrainConfig, make_train_step

    cfg, lm, params = mixed_lm
    opt = adamw_init(params)
    step = make_train_step(lm, TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10),
        microbatches=1, remat="none"))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                             cfg.vocab_size)
    p2, _, metrics = step(params, opt, {"tokens": tok, "labels": tok})
    assert np.isfinite(float(metrics["loss"]))
    for w0, w1 in zip(_nm_leaves(params), _nm_leaves(p2)):
        assert w0.nm == w1.nm
        np.testing.assert_array_equal(np.asarray(w0.idx), np.asarray(w1.idx))


def test_mixed_sparsity_serves_one_decode_step(mixed_lm):
    from repro.serving.engine import Request, ServeEngine

    cfg, lm, params = mixed_lm
    eng = ServeEngine(lm, params, slots=1, max_seq=32, prefill_len=8)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new=2))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 2


def test_mixed_sparsity_checkpoint_roundtrip(mixed_lm, tmp_path):
    from repro.training.checkpoint import Checkpointer

    _, _, params = mixed_lm
    ck = Checkpointer(str(tmp_path))
    ck.save(1, params)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    got, _ = ck.restore(template)
    for w0, w1 in zip(_nm_leaves(params), _nm_leaves(got)):
        assert w0.nm == w1.nm and w0.axis == w1.axis
        np.testing.assert_array_equal(np.asarray(w0.vals),
                                      np.asarray(w1.vals))
        np.testing.assert_array_equal(np.asarray(w0.idx),
                                      np.asarray(w1.idx))


# ---------------------------------------------------------------------------
# API freeze: the typed representation must not regress
# ---------------------------------------------------------------------------

# the checkpoint migration shim is the ONE place allowed to know the
# legacy {"vals", "idx"} dict layout
_SHIM = SRC / "training" / "checkpoint.py"


def test_no_vals_key_sniffing_outside_migration_shim():
    banned = ('"vals" in', "'vals' in", '["vals"]', "['vals']",
              '"idx" in', "'idx' in")
    offenders = []
    for py in sorted(SRC.rglob("*.py")):
        if py == _SHIM:
            continue
        text = py.read_text()
        for pat in banned:
            if pat in text:
                offenders.append((str(py.relative_to(SRC)), pat))
    assert not offenders, (
        f"dict key-sniffing of the compressed representation crept back "
        f"in: {offenders}; dispatch on NMWeight instead")


def test_raw_surface_warns_and_still_computes():
    """The positional surface lives ONLY in repro.kernels.raw; it works
    but deprecates loudly (its messages start with "repro.kernels.raw",
    which pytest promotes to an error everywhere else — see pyproject
    filterwarnings). The one-release re-export shims in the old op
    modules are gone."""
    from repro.kernels import raw

    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(13), (32, 16), nm, axis=0)
    sw = api.sparsify(w, nm)
    x = jax.random.normal(jax.random.PRNGKey(14), (4, 32))
    with pytest.warns(DeprecationWarning, match=r"repro\.kernels\.raw"):
        y = raw.nm_matmul_raw(x, sw.vals, sw.idx, nm, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_old_shim_locations_stay_removed():
    """The PR-era re-export shims must not resurrect: the positional
    names are importable from repro.kernels.raw and nowhere else."""
    from repro.kernels import indexmac_gather
    from repro.kernels.indexmac import ops as indexmac_ops
    from repro.kernels.indexmac_gather import ops as gather_ops

    for mod, name in [(indexmac_ops, "nm_matmul_raw"),
                      (indexmac_ops, "nm_matmul_q_raw"),
                      (gather_ops, "indexmac_gather_spmm"),
                      (indexmac_gather, "indexmac_gather_spmm")]:
        assert not hasattr(mod, name), (mod.__name__, name)


# the deprecated positional surface may only be *defined* in raw.py
_RAW_HOSTS = {
    SRC / "kernels" / "raw.py",
}


def test_no_raw_call_sites_outside_shim_modules():
    """API freeze: no new in-repo call sites of the deprecated positional
    names — src/ and benchmarks/ must use the typed entry points."""
    banned = ("nm_matmul_raw", "nm_matmul_q_raw", "indexmac_gather_spmm")
    roots = [SRC, SRC.parents[1] / "benchmarks"]
    offenders = []
    for root in roots:
        for py in sorted(root.rglob("*.py")):
            if py in _RAW_HOSTS:
                continue
            text = py.read_text()
            for pat in banned:
                if pat in text:
                    offenders.append((str(py), pat))
    assert not offenders, (
        f"deprecated positional kernel surface used outside "
        f"repro.kernels.raw: {offenders}; use repro.api.nm_matmul / "
        f"indexmac_gather with typed weights")


# the legacy attention cache keywords may only be *consumed* in the
# CacheView shim module
_CACHE_SHIM = SRC / "models" / "cache.py"
_ATTN_SURFACES = {"attn_apply", "gqa_apply", "mla_apply", "forward"}
_LEGACY_ATTN_KW = {"mode", "positions", "cache_len", "block_table",
                   "write_mask"}


def test_no_legacy_attention_kwargs_outside_shim():
    """API freeze for the CacheView redesign: no in-repo call site of the
    attention apply surfaces (attn_apply/gqa_apply/mla_apply/LM.forward)
    may pass the legacy addressing keywords — they must build a
    CacheView. External callers keep working through the one-release
    shim in repro.models.cache; first-party code does not get to."""
    roots = [SRC, SRC.parents[1] / "benchmarks", SRC.parents[1] / "examples"]
    offenders = []
    for root in roots:
        for py in sorted(root.rglob("*.py")):
            if py == _CACHE_SHIM:
                continue
            tree = ast.parse(py.read_text())
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                name = (fn.attr if isinstance(fn, ast.Attribute)
                        else fn.id if isinstance(fn, ast.Name) else None)
                if name not in _ATTN_SURFACES:
                    continue
                bad = sorted(kw.arg for kw in node.keywords
                             if kw.arg in _LEGACY_ATTN_KW)
                if bad:
                    offenders.append(
                        (str(py.relative_to(root.parent)), node.lineno,
                         name, bad))
    assert not offenders, (
        f"legacy attention cache keywords used outside the shim: "
        f"{offenders}; pass view=CacheView(...) instead")


def test_legacy_attention_kwargs_warn_and_still_compute():
    """The one-release shim: legacy keywords produce the same result as
    the CacheView call and warn with the repro.models.cache prefix
    (promoted to an error for first-party code via filterwarnings)."""
    from repro.configs.base import AttnConfig
    from repro.models import attention
    from repro.models.cache import CacheView

    cfg = AttnConfig(q_heads=2, kv_heads=2, head_dim=8)
    key = jax.random.PRNGKey(0)
    params = attention.gqa_init(key, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    y_view, _ = attention.gqa_apply(
        params, x, cfg, view=CacheView.train(positions=jnp.arange(4)))
    with pytest.warns(DeprecationWarning, match=r"repro\.models\.cache"):
        y_legacy, _ = attention.gqa_apply(
            params, x, cfg, mode="train", positions=jnp.arange(4))
    np.testing.assert_array_equal(np.asarray(y_view), np.asarray(y_legacy))


def test_attention_kwarg_typos_raise_typed_error():
    """attn_apply's old untyped **kw passthrough silently dropped typos;
    now unknown keywords raise AttnKwargError, and cross_kv against the
    mla kind is rejected up front."""
    from repro.configs.base import AttnConfig
    from repro.models import attention
    from repro.models.cache import AttnKwargError, CacheView

    cfg = AttnConfig(q_heads=2, kv_heads=2, head_dim=8)
    with pytest.raises(AttnKwargError, match="cache_length"):
        attention.attn_apply({}, None, cfg, cache_length=3)
    mla = AttnConfig(kind="mla", q_heads=2, kv_lora_rank=8,
                     rope_head_dim=4, nope_head_dim=8, v_head_dim=8)
    with pytest.raises(AttnKwargError, match="cross_kv"):
        attention.attn_apply({}, None, mla, cross_kv=(None, None))
    with pytest.raises(AttnKwargError, match="not both"):
        attention.attn_apply({}, None, cfg, view=CacheView.train(),
                             mode="train")


def test_cacheview_constructors_validate():
    from repro.models.cache import AttnKwargError, CacheView

    with pytest.raises(AttnKwargError, match="cache_len"):
        CacheView.decode(None)
    with pytest.raises(AttnKwargError, match="block_table"):
        CacheView.chunk(jnp.int32(0), block_table=jnp.zeros((1, 1),
                                                            jnp.int32))
    with pytest.raises(ValueError, match="mode"):
        CacheView(mode="warmup")
    # registered pytree: mode is static aux, arrays are leaves
    v = CacheView.decode(jnp.int32(3))
    leaves, treedef = jax.tree.flatten(v)
    assert len(leaves) == 1
    v2 = jax.tree.unflatten(treedef, leaves)
    assert v2.mode == "decode" and int(v2.cache_len) == 3


def test_no_sp_threading_in_apply_paths():
    """No *_apply function (or the shared linear entry points) may take a
    sparsity config — weights are self-describing typed nodes."""
    offenders = []
    for py in sorted(SRC.rglob("*.py")):
        tree = ast.parse(py.read_text())
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not (node.name.endswith("_apply")
                    or node.name in ("linear_weight_dense",)):
                continue
            args = node.args
            names = [a.arg for a in
                     args.posonlyargs + args.args + args.kwonlyargs]
            if "sp" in names or "sparsity" in names:
                offenders.append((str(py.relative_to(SRC)), node.name))
    assert not offenders, f"sp= threading crept back into: {offenders}"
