"""The dispatch subsystem: registry records, shape padding, autotune cache.

The padding path's contract is exactness: zero rows/columns contribute
exact zeros to the fp32 accumulator, so the padded kernel output must
match the unpadded reference BIT-FOR-BIT on the logical slice (f32).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import (
    NMConfig,
    compress_nm,
    decompress_nm,
    pad_compressed_kn,
    random_nm_matrix,
)
from repro.kernels import autotune, registry
from repro.kernels.indexmac.ops import nm_matmul_positional as nm_matmul
from repro.kernels.indexmac.ref import nm_matmul_ref
from repro.kernels.padding import plan_nm_matmul


def _mk(cfg, K, N, M, dtype=jnp.float32, seed=0):
    w = random_nm_matrix(jax.random.PRNGKey(seed), (K, N), cfg, axis=0).astype(dtype)
    vals, idx = compress_nm(w, cfg, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)).astype(dtype)
    return x, w, vals, idx


# ---------------------------------------------------------------------------
# padded kernel path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [NMConfig(2, 4), NMConfig(1, 4)],
                         ids=lambda c: c.tag)
@pytest.mark.parametrize(
    "shape",
    [(384, 200, 7), (512, 200, 100), (96, 130, 13), (384, 384, 40)],
    ids=lambda s: "K%dN%dM%d" % s,
)
def test_odd_shapes_hit_kernel_and_match_ref_exactly(cfg, shape):
    K, N, M = shape
    x, w, vals, idx = _mk(cfg, K, N, M)
    registry.clear_history()
    y = nm_matmul(x, vals, idx, cfg)
    rec = registry.last_dispatch("nm_matmul")
    assert rec is not None and rec.impl == "pallas_padded", rec
    assert rec.shape == (M, K, N)
    assert rec.padded is not None and rec.block is not None
    pm, pk, pn = rec.padded
    assert pm >= M and pk >= K and pn >= N
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref)), (
        float(jnp.abs(y - y_ref).max())
    )


def test_bf16_odd_shape_matches_ref():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 384, 200, 7, dtype=jnp.bfloat16)
    registry.clear_history()
    y = nm_matmul(x, vals, idx, cfg)
    assert registry.last_dispatch("nm_matmul").impl == "pallas_padded"
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-1,
    )


def test_grad_through_padded_kernel_path():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 384, 200, 7)
    g_x, g_v = jax.grad(
        lambda x, v: jnp.sum(nm_matmul(x, v, idx, cfg) ** 2), argnums=(0, 1)
    )(x, vals)
    g_dx, g_dw = jax.grad(
        lambda x, w: jnp.sum((x @ w) ** 2), argnums=(0, 1)
    )(x, w)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_dx),
                               rtol=1e-4, atol=1e-3)
    grow = (np.arange(vals.shape[0]) // cfg.n)[:, None] * cfg.m + np.asarray(
        idx, dtype=np.int64)
    expect = np.take_along_axis(np.asarray(g_dw), grow, axis=0)
    np.testing.assert_allclose(np.asarray(g_v), expect, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------


def test_use_kernel_false_routes_to_reference():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 64)
    registry.clear_history()
    nm_matmul(x, vals, idx, cfg, False)
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "reference"
    assert "use_kernel=False" in rec.reason


def test_waste_limit_routes_tiny_m_to_reference():
    # single-row decode: padding M 1 -> 8 alone exceeds the default 4x cap
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 1)
    registry.clear_history()
    y = nm_matmul(x, vals, idx, cfg)
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "reference"
    assert "padding waste" in rec.reason
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)


def test_inconsistent_operands_raise_value_error():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 16)
    with pytest.raises(ValueError, match="inconsistent"):
        nm_matmul(x, vals[:-2], idx[:-2], cfg)
    with pytest.raises(ValueError, match="mismatch"):
        nm_matmul(x, vals, idx[:-2], cfg)


def test_dispatch_history_accumulates():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 64)
    registry.clear_history()
    nm_matmul(x, vals, idx, cfg)
    nm_matmul(x, vals, idx, cfg, False)
    impls = [r.impl for r in registry.dispatch_history("nm_matmul")]
    assert impls == ["pallas_padded", "reference"]


# ---------------------------------------------------------------------------
# plan + pad primitives
# ---------------------------------------------------------------------------


def test_plan_respects_granularity():
    cfg = NMConfig(2, 4)
    plan = plan_nm_matmul(7, 200, 384, cfg, (256, 256, 2048))
    bm, bn, bk = plan.block
    assert plan.pm % bm == 0 and plan.pn % bn == 0 and plan.pk % bk == 0
    assert bk % cfg.m == 0
    assert (bk * cfg.n // cfg.m) % 8 == 0  # compressed tile sublane-aligned
    assert plan.needs_padding and plan.waste > 1.0


def test_plan_noop_on_tileable_shape():
    cfg = NMConfig(2, 4)
    plan = plan_nm_matmul(128, 256, 512, cfg, (128, 256, 512))
    assert not plan.needs_padding
    assert plan.waste == 1.0


def test_pad_compressed_roundtrip():
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (32, 20), cfg, axis=0)
    vals, idx = compress_nm(w, cfg, axis=0)
    vp, ip = pad_compressed_kn(vals, idx, kc_pad=24, n_pad=128)
    assert vp.shape == ip.shape == (24, 128)
    back = decompress_nm(vp, ip, cfg, axis=0)
    np.testing.assert_array_equal(np.asarray(back[:32, :20]), np.asarray(w))
    assert float(jnp.abs(back[32:]).max(initial=0.0)) == 0.0
    assert float(jnp.abs(back[:, 20:]).max(initial=0.0)) == 0.0


def test_pad_compressed_rejects_shrink():
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (32, 20), cfg, axis=0)
    vals, idx = compress_nm(w, cfg, axis=0)
    with pytest.raises(ValueError):
        pad_compressed_kn(vals, idx, kc_pad=8, n_pad=20)


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def test_autotune_persists_and_reloads(tmp_cache):
    cfg = NMConfig(2, 4)
    block = autotune.tune(8, 128, 128, cfg, candidates=[(8, 128, 128)])
    assert block == (8, 128, 128)
    on_disk = json.loads(tmp_cache.read_text())
    assert list(on_disk.values()) == [[8, 128, 128]]
    assert list(on_disk)[0].startswith("v2|cpu|tpu|float32|2:4|8x128x128")
    # fresh in-memory state must reload from disk
    autotune.clear_memory_cache()
    assert autotune.cached_block(8, 128, 128, cfg, jnp.float32) == (8, 128, 128)
    assert autotune.best_block(8, 128, 128, cfg, jnp.float32) == (8, 128, 128)


def test_best_block_defaults_without_tuning(tmp_cache):
    assert os.environ.get("REPRO_AUTOTUNE") != "1"
    assert autotune.best_block(64, 256, 512, NMConfig(2, 4)) == \
        autotune.DEFAULT_BLOCK


def test_nm_matmul_uses_cached_block(tmp_cache):
    cfg = NMConfig(2, 4)
    autotune.tune(64, 128, 256, cfg, candidates=[(64, 128, 256)])
    x, w, vals, idx = _mk(cfg, 256, 128, 64)
    registry.clear_history()
    nm_matmul(x, vals, idx, cfg)  # block=None -> cache lookup
    rec = registry.last_dispatch("nm_matmul")
    assert rec.impl == "pallas_padded"
    assert rec.block == (64, 128, 256)


def test_candidates_are_plan_feasible():
    cfg = NMConfig(1, 4)
    for bm, bn, bk in autotune.candidate_blocks(100, 200, 384, cfg):
        assert bk % cfg.m == 0
        plan = plan_nm_matmul(100, 200, 384, cfg, (bm, bn, bk))
        assert plan is not None and plan.block == (bm, bn, bk)
