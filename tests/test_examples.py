"""The examples directory is part of the suite: each example runs as a
subprocess (the same way a user invokes it), so an API change that
breaks the documented entry points fails CI instead of rotting silently.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run_example(name: str, *args: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / name), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO),
    )


def _assert_ok(out, name):
    assert out.returncode == 0, (
        f"{name} failed\n--- stdout ---\n{out.stdout[-2000:]}\n"
        f"--- stderr ---\n{out.stderr[-2000:]}")


def test_quickstart_runs_end_to_end():
    out = _run_example("quickstart.py")
    _assert_ok(out, "quickstart.py")
    assert "quickstart OK" in out.stdout
    assert "kernel vs dense max err" in out.stdout


@pytest.mark.slow
def test_serve_decode_example_runs():
    out = _run_example("serve_decode.py")
    _assert_ok(out, "serve_decode.py")


@pytest.mark.slow
def test_train_sparse_lm_example_runs():
    out = _run_example("train_sparse_lm.py", "--steps", "3")
    _assert_ok(out, "train_sparse_lm.py")
