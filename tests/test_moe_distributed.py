"""Numeric equivalence: the shard_map expert-parallel MoE path must produce
the same outputs as the single-device path (f32, ample capacity).

Runs real multi-device CPU execution in a subprocess (device count must be
set before jax init).
"""
import os
import subprocess
import sys

import pytest

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import common
common.set_compute_dtype(jnp.float32)
from repro.configs.base import MoEConfig
from repro.models.moe import moe_init, moe_apply, _moe_apply_local

cfg = MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=2,
                capacity_factor=8.0)  # ample capacity: no drops either path
params = moe_init(jax.random.PRNGKey(0), 32, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

y_local, aux_local = _moe_apply_local(params, x, cfg)

from repro import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
with compat.set_mesh(mesh):
    y_dist, aux_dist = jax.jit(
        lambda p, x: moe_apply(p, x, cfg)
    )(params, x)

err = float(jnp.abs(y_local - y_dist).max())
aerr = abs(float(aux_local) - float(aux_dist))
print(f"RESULT {err:.3e} {aerr:.3e}")
assert err < 1e-4, err
# aux is the GShard-style per-group (per data shard) balance loss in the
# distributed path — equals the global one up to the across-group variance
assert aerr < 1e-3, aerr
"""


@pytest.mark.slow
def test_shard_map_moe_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT" in proc.stdout
