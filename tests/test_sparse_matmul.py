"""Algorithm 1/2/3 semantic equivalence + operand-traffic model sanity.

Validates the paper's §II/§III claims at the algorithm level:
  * Alg.2 (row-wise SpMM) == Alg.1 (dense) on N:M data
  * Alg.3 (indexmac, B-tile stationary) == Alg.2
  * the traffic model shows Alg.3 eliminating B loads, with a larger
    *relative* total reduction at 2:4 than 1:4 (paper Fig. 6 trend).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse_matmul import (
    indexmac_spmm,
    indexmac_traffic,
    rowwise_dense_matmul,
    rowwise_spmm,
    rowwise_spmm_traffic,
)
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix

CFGS = [NMConfig(1, 4), NMConfig(2, 4), NMConfig(1, 2)]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize("l_rows", [16, 32])
def test_algorithms_agree(cfg, l_rows):
    Mr, K, Nc = 24, 128, 96
    a = random_nm_matrix(jax.random.PRNGKey(0), (Mr, K), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, Nc))
    c1 = rowwise_dense_matmul(a, b)
    c2 = rowwise_spmm(vals, idx, b, cfg)
    c3 = indexmac_spmm(vals, idx, b, cfg, l_rows=l_rows)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c3), np.asarray(c1), rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n_m=st.sampled_from([(1, 4), (2, 4)]),
    rows=st.integers(1, 4),
    kblocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_alg3_equals_alg2(n_m, rows, kblocks, seed):
    cfg = NMConfig(*n_m)
    K = kblocks * 16  # L=16 | K
    a = random_nm_matrix(jax.random.PRNGKey(seed), (rows, K), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, 32))
    c2 = rowwise_spmm(vals, idx, b, cfg)
    c3 = indexmac_spmm(vals, idx, b, cfg, l_rows=16)
    np.testing.assert_allclose(np.asarray(c3), np.asarray(c2), rtol=1e-5, atol=1e-4)


def test_traffic_model_directionality():
    """Paper Fig. 6: proposed reduces total accesses; the reduction is
    LARGER for 2:4 than for 1:4 (more eliminated B loads)."""
    dims = (512, 1024, 512)  # a ResNet-ish GEMM
    red = {}
    for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
        base = rowwise_spmm_traffic(*dims, cfg)
        prop = indexmac_traffic(*dims, cfg)
        assert prop.loads_b < base.loads_b  # B loads eliminated
        assert prop.total < base.total
        red[cfg.tag] = 1 - prop.total / base.total
    assert red["2:4"] > red["1:4"]


def test_traffic_model_a_side_unchanged():
    cfg = NMConfig(2, 4)
    base = rowwise_spmm_traffic(256, 256, 256, cfg)
    prop = indexmac_traffic(256, 256, 256, cfg)
    assert base.loads_a == prop.loads_a  # optimization targets B only
