"""Observability layer: zero overhead when off, faithful when on.

Covers the :mod:`repro.obs` contract end to end — registry semantics
and the Prometheus round-trip, tracer ring buffer and Chrome-trace
schema, the env gate (``REPRO_OBS`` unset vs ``0`` vs ``1``), serve
token parity + zero recompiles with observability on, the per-request
ITL accounting the engines report, and the per-family dispatch
counters that replaced record-list sniffing.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.configs import get_reduced
from repro.models import common
from repro.models.transformer import LM
from repro.obs.check import (
    TraceValidationError,
    validate_chrome_trace,
    validate_metrics,
)
from repro.obs.metrics import MetricsRegistry, parse_prometheus
from repro.obs.trace import Tracer
from repro.serving.engine import Request, ServeEngine
from repro.serving.scheduler import Scheduler


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Every test starts with obs off and the env decision forgotten."""
    obs.reset_for_tests()
    yield
    obs.reset_for_tests()


@pytest.fixture(scope="module")
def yi():
    common.set_compute_dtype(jnp.float32)  # exactness for parity tests
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    yield cfg, lm, params
    common.set_compute_dtype(jnp.bfloat16)


def _serve(lm, cfg, params, **extra):
    eng = ServeEngine(lm, params, slots=2, max_seq=64, prefill_len=8,
                      **extra)
    rng = np.random.default_rng(7)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new=4))
    eng.run()
    return {r.rid: tuple(r.out) for r in eng.finished}, eng


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_metrics_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("reqs_total")
    m.inc("reqs_total", 2.0)
    m.inc("reqs_total", kind="paged")
    m.set_gauge("depth", 3)
    m.set_gauge("depth", 5)
    m.observe("lat_seconds", 0.002)
    m.observe("lat_seconds", 100.0)  # beyond the top edge -> +Inf bucket
    assert m.counter_value("reqs_total") == 3.0
    assert m.counter_value("reqs_total", kind="paged") == 1.0
    assert m.counter_value("never_touched") == 0.0
    assert m.gauge_value("depth") == 5.0
    snap = m.snapshot()
    h = snap["histograms"]["lat_seconds"]
    assert h["count"] == 2 and h["sum"] == pytest.approx(100.002)
    assert h["buckets"][-1][0] == "+Inf" and h["buckets"][-1][1] == 2
    json.dumps(snap)  # strict-JSON (goes into BENCH_results.json)


def test_prometheus_round_trip():
    m = MetricsRegistry()
    m.inc("a_total", 4, op="x", impl="y")
    m.set_gauge("g", 1.5)
    m.observe("h_seconds", 0.03)
    parsed = parse_prometheus(m.to_prometheus())
    assert parsed["types"] == {"a_total": "counter", "g": "gauge",
                               "h_seconds": "histogram"}
    assert parsed["samples"]['a_total{impl="y",op="x"}'] == 4.0
    assert parsed["samples"]["g"] == 1.5
    assert parsed["samples"]["h_seconds_count"] == 1.0
    assert parsed["samples"]['h_seconds_bucket{le="+Inf"}'] == 1.0
    with pytest.raises(ValueError, match="malformed"):
        parse_prometheus("not a metric line at all")


def test_histogram_edges_conflict_rejected():
    m = MetricsRegistry()
    m.define_histogram("h", (1.0, 2.0))
    m.define_histogram("h", (1.0, 2.0))  # same edges: fine
    with pytest.raises(ValueError, match="different"):
        m.define_histogram("h", (1.0, 3.0))


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_span_emits_matched_pair_even_on_exception(tmp_path):
    t = Tracer()
    with pytest.raises(RuntimeError):
        with t.span("work", step=1):
            raise RuntimeError("boom")
    phases = [e["ph"] for e in t.events()]
    assert phases == ["B", "E"]
    path = str(tmp_path / "trace.json")
    t.export_chrome(path)
    stats = validate_chrome_trace(path)
    assert stats["sync_spans"] == 1


def test_tracer_ring_buffer_caps_and_counts_drops():
    t = Tracer(capacity=4)
    for i in range(10):
        t.instant("tick", i=i)
    evs = t.events()
    assert len(evs) == 4 and t.dropped == 6
    assert [e["args"]["i"] for e in evs] == [6, 7, 8, 9]  # newest kept


def test_chrome_export_schema_and_async_request_spans(tmp_path):
    t = Tracer()
    t.async_begin("request 0", 0, slot=1)
    t.instant("engine.step", occupied=1)
    t.async_instant("first_token", 0)
    t.async_end("request 0", 0, tokens=4)
    path = str(tmp_path / "trace.json")
    t.export_chrome(path)
    with open(path) as f:
        payload = json.load(f)
    for ev in payload["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
    stats = validate_chrome_trace(path)
    assert stats == {"events": 4, "sync_spans": 0, "async_spans": 1,
                     "instants": 2}


def test_trace_validation_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"name": "x"}]))  # array form, no keys
    with pytest.raises(TraceValidationError, match="JSON-object"):
        validate_chrome_trace(str(bad))
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    with pytest.raises(TraceValidationError, match="empty"):
        validate_chrome_trace(str(empty))
    unmatched = tmp_path / "unmatched.json"
    unmatched.write_text(json.dumps({"traceEvents": [
        {"name": "w", "ph": "B", "ts": 1, "pid": 1, "tid": 1}]}))
    with pytest.raises(TraceValidationError, match="unmatched"):
        validate_chrome_trace(str(unmatched))
    backwards = tmp_path / "backwards.json"
    backwards.write_text(json.dumps({"traceEvents": [
        {"name": "a", "ph": "i", "ts": 5, "pid": 1, "tid": 1},
        {"name": "b", "ph": "i", "ts": 1, "pid": 1, "tid": 1}]}))
    with pytest.raises(TraceValidationError, match="backwards"):
        validate_chrome_trace(str(backwards))


def test_metrics_validation_requires_subsystems(tmp_path):
    m = MetricsRegistry()
    m.inc("serve_steps_total")
    p = tmp_path / "m.prom"
    p.write_text(m.to_prometheus())
    assert validate_metrics(str(p), require_subsystems=("engine",))
    with pytest.raises(TraceValidationError, match="paging"):
        validate_metrics(str(p), require_subsystems=("engine", "paging"))


# ---------------------------------------------------------------------------
# env gate / global bundle
# ---------------------------------------------------------------------------


def test_env_gate_unset_empty_and_zero_all_mean_off(monkeypatch):
    for value in (None, "", "0"):
        obs.reset_for_tests()
        if value is None:
            monkeypatch.delenv("REPRO_OBS", raising=False)
        else:
            monkeypatch.setenv("REPRO_OBS", value)
        assert obs.get_obs() is None
    obs.reset_for_tests()
    monkeypatch.setenv("REPRO_OBS", "1")
    bundle = obs.get_obs()
    assert bundle is not None
    assert obs.get_obs() is bundle  # cached decision


def test_env_decision_read_once(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert obs.get_obs() is None
    monkeypatch.setenv("REPRO_OBS", "1")
    assert obs.get_obs() is None  # decision already made for this process
    obs.reset_for_tests()
    assert obs.get_obs() is not None


def test_enable_is_idempotent_and_explicit_bundle_wins():
    first = obs.enable()
    assert obs.enable() is first
    mine = obs.Obs.create()
    assert obs.enable(mine) is mine
    assert obs.get_obs() is mine
    obs.disable()
    assert obs.get_obs() is None


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def test_obs_off_is_noop_and_on_keeps_token_parity(yi, monkeypatch):
    """The acceptance triangle: REPRO_OBS unset and REPRO_OBS=0 produce
    byte-identical token streams; turning obs ON changes nothing about
    the tokens and keeps the compiled caches at one entry each."""
    cfg, lm, params = yi
    kw = dict(paged=True, prefill_chunk=4, page_size=4, pool_pages=2 * 16)

    monkeypatch.delenv("REPRO_OBS", raising=False)
    obs.reset_for_tests()
    toks_unset, _ = _serve(lm, cfg, params, **kw)
    assert obs.get_obs() is None

    monkeypatch.setenv("REPRO_OBS", "0")
    obs.reset_for_tests()
    toks_zero, _ = _serve(lm, cfg, params, **kw)
    assert obs.get_obs() is None
    assert toks_zero == toks_unset

    obs.reset_for_tests()
    bundle = obs.enable()
    toks_on, eng = _serve(lm, cfg, params, **kw)
    assert toks_on == toks_unset
    assert eng.compiled_cache_sizes() == {"prefill": 1, "decode": 1}
    snap = bundle.metrics.snapshot()
    assert snap["counters"]["sched_admissions_total"] >= 4
    assert snap["counters"]["page_allocs_total"] > 0
    assert "serve_itl_seconds" in snap["histograms"]


def test_traced_serve_exports_valid_artifacts(yi, tmp_path):
    """An obs-on paged serve exports a schema-valid Chrome trace with
    per-request async spans and a Prometheus file covering the host-side
    subsystems the run exercised."""
    cfg, lm, params = yi
    bundle = obs.enable(obs.Obs.create())
    _, eng = _serve(lm, cfg, params, paged=True, prefill_chunk=4,
                    page_size=4, pool_pages=2 * 16)
    trace = str(tmp_path / "trace.json")
    prom = str(tmp_path / "metrics.prom")
    bundle.tracer.export_chrome(trace)
    with open(prom, "w") as f:
        f.write(bundle.metrics.to_prometheus())
    stats = validate_chrome_trace(trace)
    assert stats["async_spans"] >= 4    # one request span per request
    assert stats["sync_spans"] > 0      # engine.prefill / engine.decode
    assert validate_metrics(
        str(prom), require_subsystems=("engine", "scheduler", "paging"))
    # request spans carry the scheduler's annotations
    with open(trace) as f:
        evs = json.load(f)["traceEvents"]
    begins = [e for e in evs if e["ph"] == "b"]
    assert all(e["cat"] == "request" and "slot" in e["args"]
               for e in begins)
    assert any(e["ph"] == "n" and e["name"] == "first_token" for e in evs)


def test_obs_check_cli(yi, tmp_path, capsys):
    from repro.obs import check as obscheck

    cfg, lm, params = yi
    bundle = obs.enable(obs.Obs.create())
    _serve(lm, cfg, params, paged=True, prefill_chunk=4, page_size=4,
           pool_pages=2 * 16)
    trace = str(tmp_path / "trace.json")
    prom = str(tmp_path / "metrics.prom")
    bundle.tracer.export_chrome(trace)
    with open(prom, "w") as f:
        f.write(bundle.metrics.to_prometheus())
    rc = obscheck.main([trace, prom,
                        "--require-subsystems", "engine,scheduler,paging"])
    assert rc == 0
    rc = obscheck.main([trace, prom, "--require-subsystems", "autotune"])
    assert rc == 1  # reference-route serve records no autotune lookups
    assert "FAIL" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# per-request ITL accounting
# ---------------------------------------------------------------------------


def test_itl_is_per_request_not_global_decode_clock():
    """Two interleaved requests decoding on alternating steps: the global
    decode clock sees every inter-step gap, but each request's own
    cadence is what itl reports. Driven directly through the scheduler
    with synthetic timestamps — no device work."""
    sched = Scheduler(slots=2, max_seq=32, prefill_len=4)
    for rid in range(2):
        sched.submit(Request(rid=rid,
                             prompt=np.arange(1, 5, dtype=np.int32),
                             max_new=4), now=0.0)
    pf = sched.plan_prefill()
    sched.finish_prefill(pf, np.asarray([10, 20]), now=1.0)
    # decode steps at t = 2, 4, 8: every request sees gaps (1, 2, 4)
    for t in (2.0, 4.0, 8.0):
        dc = sched.plan_decode()
        sched.finish_decode(dc, np.asarray([11, 21]), now=t)
    assert len(sched.finished) == 2
    for req in sched.finished:
        assert req.t_tokens == [1.0, 2.0, 4.0, 8.0]
        np.testing.assert_allclose(req.itl_s(), [1.0, 2.0, 4.0])


def test_throughput_stats_keys(yi):
    cfg, lm, params = yi
    _, eng = _serve(lm, cfg, params)
    st = eng.throughput_stats()
    for key in ("requests", "tokens", "ttft_s", "ttft_p50_s",
                "ttft_p99_s", "itl_p50_s", "itl_p99_s"):
        assert key in st
    assert st["ttft_p50_s"] <= st["ttft_p99_s"]
    assert 0 < st["itl_p50_s"] <= st["itl_p99_s"]


def test_preemption_clears_token_timestamps():
    sched = Scheduler(slots=1, max_seq=32, prefill_len=4)
    req = Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                  max_new=8)
    sched.submit(req, now=0.0)
    pf = sched.plan_prefill()
    sched.finish_prefill(pf, np.asarray([10]), now=1.0)
    assert req.t_tokens == [1.0]
    # no paging on this scheduler; exercise the preemption bookkeeping
    # directly (paged preemption path calls the same method)
    from repro.serving.paging import PageManager
    pm = PageManager(page_size=4, pages_per_group=16, slots=1, max_seq=32)
    sp = Scheduler(slots=1, max_seq=32, prefill_len=4, paging=pm)
    rq = Request(rid=1, prompt=np.arange(1, 5, dtype=np.int32), max_new=8)
    sp.submit(rq, now=0.0)
    p = sp.plan_prefill()
    sp.finish_prefill(p, np.asarray([10]), now=1.0)
    assert rq.t_tokens == [1.0]
    sp._preempt(0)
    assert rq.t_tokens == [] and rq.out == [] and rq.t_first is None


# ---------------------------------------------------------------------------
# dispatch counters
# ---------------------------------------------------------------------------


def test_dispatch_counts_reset_with_history():
    from repro.kernels import registry

    registry.clear_history()
    registry._record(registry.DispatchRecord(
        op="nm_matmul_decode", impl="pallas_decode", shape=(2, 64, 64),
        padded=None, block=None, reason=""))
    registry._record(registry.DispatchRecord(
        op="nm_matmul", impl="reference", shape=(16, 64, 64),
        padded=None, block=None, reason=""))
    counts = registry.dispatch_counts()
    assert counts[("nm_matmul_decode", "pallas_decode", "tpu")] == 1
    assert registry.dispatch_counts("nm_matmul_decode") == {
        ("nm_matmul_decode", "pallas_decode", "tpu"): 1}
    # the backend filter selects the third key component
    assert registry.dispatch_counts(backend="tpu") == counts
    assert registry.dispatch_counts(backend="gpu") == {}
    registry.clear_history()
    assert registry.dispatch_counts() == {}
    assert registry.dispatch_history() == []


def test_dispatch_counts_mirror_to_obs_metric():
    from repro.kernels import registry

    bundle = obs.enable(obs.Obs.create())
    registry._record(registry.DispatchRecord(
        op="nm_matmul_decode", impl="pallas_decode", shape=(2, 64, 64),
        padded=None, block=None, reason=""))
    assert bundle.metrics.counter_value(
        "kernel_dispatch_total", op="nm_matmul_decode",
        impl="pallas_decode", backend="tpu") == 1.0
    registry.clear_history()


# ---------------------------------------------------------------------------
# paging counters
# ---------------------------------------------------------------------------


def test_page_manager_mirrors_stats_to_metrics():
    from repro.serving.paging import PageManager

    bundle = obs.Obs.create()
    pm = PageManager(page_size=4, pages_per_group=8, slots=2, max_seq=16,
                     obs=bundle)
    gid = pm.alloc(0)
    pm.register_prefix(0, b"k0", gid)
    hit = pm.peek(0, b"k0")
    pm.hit(hit)
    pm.release(gid)
    pm.release(gid)       # refcount 0, stays cached (evictable)
    assert pm.evict_lru(0)
    pm.count_prefix_lookup(3)
    m = bundle.metrics
    assert m.counter_value("page_allocs_total") == pm.stats.allocs == 1
    assert m.counter_value("page_evictions_total") == pm.stats.evictions == 1
    assert m.counter_value("prefix_hit_pages_total") == \
        pm.stats.prefix_hit_pages == 1
    assert m.counter_value("prefix_lookup_pages_total") == \
        pm.stats.prefix_lookup_pages == 3
    assert m.counter_value("page_frees_total") == 0  # evicted, not freed


def test_null_span_allocates_nothing_per_call():
    s = obs.null_span()
    assert s("anything", a=1) is s
    with s("block") as inner:
        assert inner is s
    assert obs.null_span() is s  # module singleton
