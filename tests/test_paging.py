"""Paged KV cache: PageManager units, page-budget scheduling with the
starvation guard, and paged-engine token parity with the slot engine.

The contract (same one PR 5/6 established for sharded serving): the
paged engine must be *token-identical* to the slot engine on the same
request stream — mixed lengths, mid-flight admissions, shared prefixes,
even preemption-by-recompute (greedy restart reproduces the stream) —
with zero recompiles across admissions. Parity runs in f32 greedy so
equality is exact, not approximate.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common
from repro.configs import get_reduced
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine
from repro.serving.paging import (
    PageManager,
    PoolExhaustedError,
    page_keys,
)
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------------------
# PageManager units
# ---------------------------------------------------------------------------


def _pm(**kw):
    base = dict(page_size=4, pages_per_group=8, slots=2, max_seq=16)
    base.update(kw)
    return PageManager(**base)


def test_alloc_release_recycles_pages():
    pm = _pm()
    a = pm.alloc(0)
    b = pm.alloc(0)
    assert a != b and a % pm.stride != 0 and b % pm.stride != 0  # never null
    assert pm.free_pages(0) == 6
    pm.release(a)
    assert pm.free_pages(0) == 7
    assert pm.alloc(0) == a  # LIFO recycle: freed page is reused first


def test_pool_exhaustion_raises_typed_error():
    pm = _pm()
    for _ in range(8):
        pm.alloc(0)
    with pytest.raises(PoolExhaustedError):
        pm.alloc(0)


def test_refcounted_sharing_and_release():
    pm = _pm()
    a = pm.alloc(0)
    pm.retain(a)
    assert pm.is_shared(a)
    pm.release(a)
    assert not pm.is_shared(a)  # one holder left
    assert pm.free_pages(0) == 7
    pm.release(a)
    assert pm.free_pages(0) == 8  # last release frees


def test_prefix_cache_peek_hit_and_lru_eviction():
    pm = _pm()
    pages = [pm.alloc(0) for _ in range(3)]
    keys = [bytes([i]) * 16 for i in range(3)]
    for k, g in zip(keys, pages):
        pm.register_prefix(0, k, g)
    for g in pages:
        pm.release(g)  # cached pages survive release as evictable
    assert pm.free_pages(0) == 5
    assert pm.evictable_pages(0) == 3
    assert pm.peek(0, keys[1]) == pages[1]
    pm.hit(pages[0])  # bump page 0: now most recently used
    assert pm.evict_lru(0)  # evicts pages[1] (oldest untouched)
    assert pm.peek(0, keys[1]) is None
    assert pm.peek(0, keys[2]) == pages[2]
    pm.release(pages[0])
    assert pm.stats.evictions == 1 and pm.stats.prefix_hit_pages == 1


def test_eviction_skips_actively_referenced_pages():
    pm = _pm(pages_per_group=4)
    a = pm.alloc(0)
    pm.register_prefix(0, b"k" * 16, a)  # cached AND ref=1: not evictable
    assert not pm.evict_lru(0)
    for _ in range(3):
        pm.alloc(0)
    with pytest.raises(PoolExhaustedError):
        pm.alloc_or_evict(0)
    pm.release(a)  # now cache-only -> reclaimable under pressure
    assert pm.alloc_or_evict(0) == a


def test_fork_is_metadata_cow():
    """fork() re-homes a writer off a shared page: fresh private page,
    old refcount decremented, other readers unaffected."""
    pm = _pm()
    a = pm.alloc(0)
    pm.retain(a)  # two readers
    new = pm.fork(a)
    assert new != a and not pm.is_shared(new)
    assert not pm.is_shared(a)  # back to one reader
    assert pm.stats.forks == 1


def test_slot_assign_and_free_releases_pages():
    pm = _pm()
    for p in range(2):
        pm.assign(0, p, pm.alloc(0))
    assert pm.used_pages() == 2 and pm.table[0, 0] != 0
    pm.free_slot(0)
    assert pm.used_pages() == 0 and (pm.table[0] == 0).all()
    assert pm.free_pages(0) == 8


def test_grouped_pools_are_independent():
    pm = _pm(slots=4, groups=2)
    assert [pm.slot_group(i) for i in range(4)] == [0, 0, 1, 1]
    a = pm.alloc(0)
    b = pm.alloc(1)
    assert pm.group_of(a) == 0 and pm.group_of(b) == 1
    assert a % pm.stride != 0 and b % pm.stride != 0
    # a group's prefix registrations are invisible to the other group
    pm.register_prefix(0, b"k" * 16, a)
    assert pm.peek(1, b"k" * 16) is None


def test_manager_validation_errors():
    with pytest.raises(ValueError, match="multiple"):
        _pm(page_size=5)  # 16 % 5 != 0
    with pytest.raises(ValueError, match="full-length"):
        _pm(pages_per_group=3)  # < 16/4 pages per request
    with pytest.raises(ValueError, match="groups"):
        _pm(slots=3, groups=2)


def test_page_keys_chain_semantics():
    """key[p] commits to ALL tokens through page p (a chain, not a
    per-block hash): shared prefix -> equal keys, any earlier
    divergence -> different keys from that page on."""
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    b[5] = 99  # diverge inside page 1
    ka, kb = page_keys(a, 4), page_keys(b, 4)
    assert len(ka) == 4
    assert ka[0] == kb[0]
    assert all(ka[p] != kb[p] for p in (1, 2, 3))  # chained
    assert len(page_keys(a[:7], 4)) == 1  # only full pages get keys


# ---------------------------------------------------------------------------
# scheduler: arrival order, starvation guard, preemption
# ---------------------------------------------------------------------------


def _req(rid, n=8, max_new=4, seed=None):
    rng = np.random.default_rng(seed if seed is not None else rid)
    return Request(rid=rid, prompt=rng.integers(
        1, 500, size=n).astype(np.int32), max_new=max_new)


def test_admission_is_arrival_ordered():
    """Slot admission is FIFO: with every resource free, the first
    arrivals get the slots, in order."""
    sched = Scheduler(slots=2, max_seq=32, prefill_len=8)
    for i in range(4):
        sched.submit(_req(i))
    plan = sched.plan_prefill()
    assert plan.active == [0, 1]
    assert [sched.slots[i].req.rid for i in plan.active] == [0, 1]
    assert [r.rid for r in sched.queue] == [2, 3]


def test_paged_admission_is_arrival_ordered():
    pm = _pm(slots=2, pages_per_group=8)
    sched = Scheduler(slots=2, max_seq=16, prefill_len=8,
                      prefill_chunk=4, paging=pm)
    for i in range(4):
        sched.submit(_req(i))
    plan = sched.plan_prefill()
    assert [sched.slots[i].req.rid for i in plan.active] == [0, 1]


def test_starvation_guard_bypass_once():
    """A request that doesn't fit may be bypassed by later arrivals
    exactly once; the second failure stops admission behind it."""
    # 5 pages: one 8-token prompt (2 pages) admitted into slot 0 leaves
    # 3 free; slot 1 free but a second 2-page prompt fits fine — so use
    # a pool where slot count, not pages, is the contended resource:
    # occupy both slots, then free one while the queue holds big-first.
    pm = _pm(slots=2, pages_per_group=4, max_seq=16)
    sched = Scheduler(slots=2, max_seq=16, prefill_len=8,
                      prefill_chunk=4, paging=pm)
    sched.submit(_req(0))  # 2 pages
    sched.plan_prefill()   # admitted into slot 0; 2 pages left in pool
    # occupy the remaining 2 pages so nothing else fits
    blockers = [pm.alloc(0), pm.alloc(0)]
    sched.submit(_req(1))
    sched.submit(_req(2))
    plan = sched.plan_prefill()
    assert plan.active == [0]  # nobody admitted; both got their one pass
    assert sched.queue[0].bypassed and sched.queue[1].bypassed
    # free ONE page: still not enough for req 1 (needs 2) — and because
    # req 1 was already bypassed once, admission must stop AT req 1:
    # req 2 does not get probed again (order intact, nobody admitted)
    pm.release(blockers[0])
    plan = sched.plan_prefill()
    assert plan.active == [0]
    assert [r.rid for r in sched.queue] == [1, 2]  # order intact
    # free the second page: req 1 now fits and goes first
    pm.release(blockers[1])
    sched.plan_prefill()
    assert sched.slots[1].req.rid == 1
    assert not sched.slots[1].req.bypassed  # guard resets on admission


def test_requeued_preemption_victim_keeps_priority():
    pm = _pm(slots=2, pages_per_group=4, max_seq=16)
    sched = Scheduler(slots=2, max_seq=16, prefill_len=8,
                      prefill_chunk=4, paging=pm)
    sched.submit(_req(0))
    sched.plan_prefill()
    sched.submit(_req(5))
    sched._preempt(0)
    assert [r.rid for r in sched.queue] == [0, 5]  # front, not back
    assert sched.preemptions == 1


# ---------------------------------------------------------------------------
# engine parity: paged == slot, zero recompiles
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def yi():
    common.set_compute_dtype(jnp.float32)  # exactness for parity
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    yield cfg, lm, params
    common.set_compute_dtype(jnp.bfloat16)


def _serve(lm, params, prompts, max_news, **kw):
    eng = ServeEngine(lm, params, **kw)
    for i, (p, n) in enumerate(zip(prompts, max_news)):
        eng.submit(Request(rid=i, prompt=p, max_new=n))
    out = {r.rid: tuple(r.out) for r in eng.run()}
    return out, eng


def _mixed_stream(cfg, n=7, seed=0):
    """Mixed lengths, three of them sharing a prefix with request 0."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=ln).astype(np.int32)
               for ln in (8, 5, 8, 3, 8, 6, 8)[:n]]
    prompts[2][:4] = prompts[0][:4]
    if n > 4:
        prompts[4] = prompts[0].copy()
    max_news = [3 + i % 4 for i in range(n)]
    return prompts, max_news


def test_paged_engine_token_parity_mixed_stream(yi):
    """7 mixed-length requests over 2 slots: admissions happen
    mid-flight as requests finish. Paged output == slot output exactly,
    zero recompiles, and the shared prefixes actually hit the cache."""
    cfg, lm, params = yi
    prompts, max_news = _mixed_stream(cfg)
    kw = dict(slots=2, max_seq=32, prefill_len=8, prefill_chunk=4)
    slot_out, es = _serve(lm, params, prompts, max_news, **kw)
    paged_out, ep = _serve(lm, params, prompts, max_news, paged=True, **kw)
    assert paged_out == slot_out
    assert ep.compiled_cache_sizes() == {"prefill": 1, "decode": 1}
    st = ep.throughput_stats()
    assert st["prefix_hit_pages"] > 0
    assert 0 < st["prefix_hit_rate"] <= 1
    assert st["page_util_max"] <= 1.0
    assert st["queue_depth_max"] >= 1  # stream oversubscribes the slots


def test_paged_engine_token_parity_mla(yi):
    """Same parity on an MLA model: the paged path must serve the
    compressed ckv/kr pools identically (absorbed decode reads the
    gathered latent view)."""
    del yi  # fixture pins f32 for the module
    cfg = get_reduced("deepseek-v2-lite-16b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    prompts, max_news = _mixed_stream(cfg, n=5, seed=3)
    kw = dict(slots=2, max_seq=32, prefill_len=8, prefill_chunk=4)
    slot_out, _ = _serve(lm, params, prompts, max_news, **kw)
    paged_out, ep = _serve(lm, params, prompts, max_news, paged=True, **kw)
    assert paged_out == slot_out
    assert ep.compiled_cache_sizes() == {"prefill": 1, "decode": 1}


def test_paged_parity_full_chunk_prefill(yi):
    """paged without prefill_chunk: the whole prompt prefills as ONE
    mode="chunk" call (page_size defaults to prefill_len). Compared
    against the chunk=4 slot engine — per-token K/V writes and each
    query's full-cache masked attention are chunking-invariant, so the
    greedy streams must agree exactly even though the step counts
    differ."""
    cfg, lm, params = yi
    prompts, max_news = _mixed_stream(cfg, n=4, seed=5)
    kw = dict(slots=2, max_seq=32, prefill_len=8)
    chunked_out, _ = _serve(lm, params, prompts, max_news,
                            prefill_chunk=4, **kw)
    paged_out, _ = _serve(lm, params, prompts, max_news, paged=True, **kw)
    assert paged_out == chunked_out


def test_paged_engine_preemption_recovers(yi):
    """An undersized pool forces preemption-by-recompute mid-decode; the
    preempted request restarts (prefix cache skips its prompt chunks)
    and the final streams still match the slot engine exactly."""
    cfg, lm, params = yi
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(3)]
    max_news = [18, 18, 6]
    kw = dict(slots=2, max_seq=32, prefill_len=8, prefill_chunk=4)
    slot_out, _ = _serve(lm, params, prompts, max_news, **kw)
    # 9 pages of 4: two admitted prompts take 4, decode to length 26
    # needs 7 pages each -> exhaustion mid-decode -> preemption
    paged_out, ep = _serve(lm, params, prompts, max_news, paged=True,
                           pool_pages=9, **kw)
    assert ep.scheduler.preemptions > 0
    assert paged_out == slot_out
    assert ep.compiled_cache_sizes() == {"prefill": 1, "decode": 1}


def test_paged_engine_isolation(yi):
    """A request's stream must not depend on pool pressure or
    co-residents: serve alone vs in a churny batch."""
    cfg, lm, params = yi
    rng = np.random.default_rng(9)
    p = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    kw = dict(slots=2, max_seq=32, prefill_len=8, prefill_chunk=4,
              paged=True)
    alone, _ = _serve(lm, params, [p], [6], **kw)
    others = [rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
              for _ in range(3)]
    batched, _ = _serve(lm, params, others + [p], [3, 4, 5, 6], **kw)
    assert batched[3] == alone[0]


def test_env_var_page_geometry(yi, monkeypatch):
    cfg, lm, params = yi
    monkeypatch.setenv("REPRO_KV_PAGE_SIZE", "8")
    monkeypatch.setenv("REPRO_KV_POOL_PAGES", "6")
    eng = ServeEngine(lm, params, slots=2, max_seq=32, prefill_len=8,
                      paged=True)
    assert eng.page_manager.page_size == 8
    assert eng.page_manager.capacity == 6
    # explicit args beat the environment
    eng = ServeEngine(lm, params, slots=2, max_seq=32, prefill_len=8,
                      paged=True, page_size=4, pool_pages=16)
    assert eng.page_manager.page_size == 4
    assert eng.page_manager.capacity == 16


def test_paged_validation_errors(yi):
    cfg, lm, params = yi
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(lm, params, slots=2, max_seq=32, prefill_len=8,
                    paged=True, page_size=5)
    with pytest.raises(ValueError, match="full-length"):
        ServeEngine(lm, params, slots=2, max_seq=32, prefill_len=8,
                    paged=True, page_size=4, pool_pages=4)
    with pytest.raises(ValueError, match="page_size"):
        # prefill_len must land on a page boundary (prompt pages become
        # immutable prefix-cache entries; decode starts on a fresh page)
        ServeEngine(lm, params, slots=2, max_seq=32, prefill_len=8,
                    paged=True, page_size=16)


def test_paged_rejects_stateful_mixers():
    cfg = get_reduced("rwkv6-3b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="attention"):
        ServeEngine(lm, params, slots=1, max_seq=32, prefill_len=8,
                    paged=True)


# ---------------------------------------------------------------------------
# sharded spec rule (no lowering): paged pools reuse the cache pspecs
# ---------------------------------------------------------------------------


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: D106
        shape = (2, 4)
        size = 8


def test_paged_pool_reuses_head_sharded_cache_specs():
    """The pool leaf (rows, page_size, H, D) has the same rank layout as
    the slot cache (slots, max_seq, H, D): serve_cache_pspecs shards the
    page rows over "data" and the head axis over "model" unchanged."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import serve_cache_pspecs, serve_tp_plan

    cfg = get_reduced("yi-9b")
    blk, rep = cfg.plan[0]
    kvcfg = dataclasses.replace(cfg, plan=((dataclasses.replace(
        blk, mixer=dataclasses.replace(blk.mixer, kv_heads=4)), rep),))
    lm = LM(kvcfg)
    plan = serve_tp_plan(kvcfg, 4)
    assert plan.shard_kv
    pm = PageManager(page_size=4, pages_per_group=8, slots=2, max_seq=32,
                     groups=2)
    pool = jax.eval_shape(lambda: lm.init_cache(pm.rows, pm.page_size))
    specs = serve_cache_pspecs(pool, _FakeMesh, plan)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    k_specs = [s for path, s in flat
               if any(getattr(k, "key", None) == "k" for k in path)]
    assert k_specs, "no k-leaf spec found"
    for s in k_specs:
        assert s[-3:] == P("data", None, "model")[-3:] or \
            tuple(s)[-3:] == ("data", None, "model")


def test_paged_pool_rows_divide_data_axis():
    """rows = groups * stride with groups = dp, so the leading pool axis
    always shards evenly over "data"."""
    for dp in (1, 2, 4):
        pm = PageManager(page_size=4, pages_per_group=8, slots=4,
                         max_seq=16, groups=dp)
        assert pm.rows % dp == 0
