"""Block-sparse attention: mask-pattern parity vs the dense oracle,
dispatch routing + budget behavior, explain/exec no-drift, and the
typed ``api.attention`` surface.

Every pattern's sparse lowering (on CPU hosts: ``xla_bs_attention``,
the block-gather XLA path) is compared against ``masked_reference`` —
dense attention with the same token predicate through ``jnp.where``.
The predicate itself (``token_mask``) is shared between the two, so
parity here proves the *block plan* (tiling, pair lists, gather rows),
not the mask semantics alone.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_reduced
from repro.configs.base import AttnConfig
from repro.kernels import registry
from repro.kernels.blocksparse_attn.mask import (
    MaskSpec,
    compile_mask,
    token_mask,
)
from repro.kernels.blocksparse_attn.ops import (
    MaskForceError,
    bs_attention,
    bs_attention_decode,
)
from repro.kernels.blocksparse_attn.ref import masked_reference
from repro.models import common
from repro.models.cache import CacheView
from repro.models.transformer import LM

# diagonal + first block column: every q row keeps its causal diagonal
# token, so the pattern compiles at any length
_BW_PAIRS = tuple((i, j) for i in range(8) for j in (0, i))

SPECS = [
    MaskSpec("causal", block=16),
    MaskSpec("local", block=16, window=24),
    MaskSpec("local", block=16, window=24, causal=False),
    MaskSpec("strided", block=16, stride=2),
    MaskSpec("blockwise", block=16, blocks=_BW_PAIRS),
]


def _qkv(key, b=2, sq=64, skv=None, hq=4, hkv=2, dk=16, dv=None,
         dtype=jnp.float32):
    skv = sq if skv is None else skv
    dv = dk if dv is None else dv
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, dk), dtype)
    k = jax.random.normal(kk, (b, skv, hkv, dk), dtype)
    v = jax.random.normal(kv, (b, skv, hkv, dv), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# pattern parity vs the dense masked oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq", [64, 67], ids=["even", "odd"])
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.tag)
def test_pattern_parity_vs_masked_reference(spec, sq):
    q, k, v = _qkv(jax.random.PRNGKey(0), sq=sq)
    out = bs_attention(q, k, v, spec=spec, tile=(16, 16))
    ref = masked_reference(q, k, v, spec=spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=2e-5)


def test_parity_bf16_and_output_dtype():
    spec = MaskSpec("local", block=16, window=24)
    q, k, v = _qkv(jax.random.PRNGKey(1), sq=64, dtype=jnp.bfloat16)
    out = bs_attention(q, k, v, spec=spec, tile=(16, 16))
    assert out.dtype == jnp.bfloat16
    ref = masked_reference(q, k, v, spec=spec)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


def test_parity_mla_value_dim_and_scale():
    """MLA-shaped call: Hq == Hkv, Dv != Dk, explicit scale (the
    nope+rope split scale mla_apply passes)."""
    spec = MaskSpec("strided", block=16, stride=2)
    q, k, v = _qkv(jax.random.PRNGKey(2), sq=48, hq=4, hkv=4, dk=24, dv=40)
    out = bs_attention(q, k, v, spec=spec, scale=0.17, tile=(16, 16))
    ref = masked_reference(q, k, v, spec=spec, scale=0.17)
    assert out.shape == (2, 48, 4, 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=2e-5)


def test_chunked_decode_equals_full_prefill():
    """Running the same queries through the decode family chunk by
    chunk (absolute q_positions against the full k/v) reproduces the
    full prefill rows exactly — the invariant serving's chunked prefill
    relies on."""
    spec = MaskSpec("local", block=16, window=24)
    q, k, v = _qkv(jax.random.PRNGKey(3), sq=96)
    full = bs_attention(q, k, v, spec=spec, tile=(16, 16))
    for c0, c1 in ((0, 32), (32, 64), (64, 96)):
        out = bs_attention_decode(
            q[:, c0:c1], k, v, spec=spec, length=c1,
            q_positions=jnp.arange(c0, c1))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(full[:, c0:c1]),
                                   rtol=1e-5, atol=2e-5)


def test_decode_never_reads_past_length():
    """Single-step decode against an overlong cache view: garbage
    beyond ``length`` must not leak into the output."""
    spec = MaskSpec("local", block=16, window=24)
    q, k, v = _qkv(jax.random.PRNGKey(4), sq=96)
    L = 80
    full = bs_attention(q[:, :L], k[:, :L], v[:, :L], spec=spec,
                        tile=(16, 16))
    kg = k.at[:, L:].set(1e3)
    vg = v.at[:, L:].set(-1e3)
    out = bs_attention_decode(q[:, L - 1:L], kg, vg, spec=spec, length=L)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full[:, L - 1:L]),
                               rtol=1e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# dispatch routing, budgets, typed errors
# ---------------------------------------------------------------------------


def test_dispatch_routes_sparse_and_declines_on_budgets(monkeypatch):
    q, k, v = _qkv(jax.random.PRNGKey(5), sq=128)
    registry.clear_history()
    bs_attention(q, k, v, spec=MaskSpec("local", block=16, window=24),
                 tile=(16, 16))
    rec = registry.last_dispatch("bs_attention")
    # CPU host: the TPU pair-list kernel declines (would interpret),
    # the XLA block-gather lowering wins
    assert rec.impl == "xla_bs_attention", rec
    # near-dense: a single-block causal grid is density 1.0 > 0.9
    qs, ks, vs = _qkv(jax.random.PRNGKey(6), sq=32)
    bs_attention(qs, ks, vs, spec=MaskSpec("causal", block=32),
                 tile=(32, 32))
    assert registry.last_dispatch("bs_attention").impl == "masked_reference"
    # wasteful: window 4 inside 16-token tiles -> live blocks are mostly
    # masked lanes (waste ~7.6x > 4.0) -> dense fallback ...
    wspec = MaskSpec("local", block=16, window=4)
    bs_attention(q, k, v, spec=wspec, tile=(16, 16))
    assert registry.last_dispatch("bs_attention").impl == "masked_reference"
    # ... and raising the budget re-admits the sparse lowering
    monkeypatch.setenv("REPRO_BS_WASTE_LIMIT", "32")
    bs_attention(q, k, v, spec=wspec, tile=(16, 16))
    assert registry.last_dispatch("bs_attention").impl == "xla_bs_attention"


def test_tpu_pairlist_kernel_parity_interpret():
    """KernelPolicy("force") on the tpu backend runs the pair-list
    scalar-prefetch Pallas kernel (interpret mode on this host — the
    same body Mosaic compiles on a real TPU) — parity vs the oracle."""
    spec = MaskSpec("local", block=16, window=24)
    q, k, v = _qkv(jax.random.PRNGKey(13), sq=64)
    registry.clear_history()
    out = bs_attention(q, k, v, spec=spec, policy="force", backend="tpu",
                       tile=(16, 16))
    rec = registry.last_dispatch("bs_attention")
    assert rec.impl == "pallas_bs_attention", rec
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(masked_reference(q, k, v, spec=spec)),
        rtol=1e-5, atol=2e-5)


def test_policy_off_and_untileable_fall_back_dense():
    q, k, v = _qkv(jax.random.PRNGKey(7), sq=64)
    spec = MaskSpec("local", block=16, window=24)
    registry.clear_history()
    bs_attention(q, k, v, spec=spec, policy="off")
    assert registry.last_dispatch("bs_attention").impl == "masked_reference"
    # misaligned tile: the mask does not compile; auto mode serves the
    # dense path instead of erroring
    bs_attention(q, k, v, spec=spec, tile=(12, 12))
    assert registry.last_dispatch("bs_attention").impl == "masked_reference"


def test_force_untileable_raises_maskforceerror():
    q, k, v = _qkv(jax.random.PRNGKey(8), sq=64)
    spec = MaskSpec("local", block=16, window=24)
    with pytest.raises(MaskForceError):
        bs_attention(q, k, v, spec=spec, policy="force", tile=(12, 12))
    # a non-causal blockwise pattern that leaves query rows with zero
    # visible tokens never compiles (softmax undefined)
    empty_rows = MaskSpec("blockwise", block=16, blocks=((0, 0),),
                          causal=False)
    with pytest.raises(MaskForceError):
        bs_attention(q, k, v, spec=empty_rows, policy="force",
                     tile=(16, 16))
    # the dry-run shares the route, so it raises the same typed error
    with pytest.raises(api.MaskForceError):
        api.explain_dispatch_attention(
            (2, 64, 4, 16), (2, 64, 2, 16), mask=empty_rows,
            policy="force", tile=(16, 16))


@pytest.mark.parametrize("spec,sq", [
    (MaskSpec("local", block=16, window=24), 64),
    (MaskSpec("causal", block=32), 32),       # density decline
    (MaskSpec("strided", block=16, stride=2), 67),
], ids=["sparse", "dense-decline", "odd-strided"])
def test_explain_matches_execution(spec, sq):
    q, k, v = _qkv(jax.random.PRNGKey(9), sq=sq)
    dry = api.explain_dispatch_attention(q.shape, k.shape, mask=spec,
                                         tile=(spec.block, spec.block))
    registry.clear_history()
    bs_attention(q, k, v, spec=spec, tile=(spec.block, spec.block))
    wet = registry.last_dispatch("bs_attention")
    assert (dry.impl, dry.backend) == (wet.impl, wet.backend)


def test_explain_decode_family():
    rec = api.explain_dispatch_attention(
        (2, 1, 4, 16), (2, 64, 2, 16),
        mask=MaskSpec("local", block=16, window=24), decode=True)
    assert rec.op == "bs_attention_decode"
    assert rec.impl == "masked_decode"


def test_shape_validation():
    q, k, v = _qkv(jax.random.PRNGKey(10), sq=32)
    spec = MaskSpec("causal", block=16)
    with pytest.raises(ValueError, match="multiple of"):
        bs_attention(q[:, :, :3], k, v, spec=spec)  # Hq=3 not mult of 2
    with pytest.raises(ValueError, match="B, S, H, D"):
        bs_attention(q[0], k, v, spec=spec)
    with pytest.raises(TypeError, match="MaskSpec"):
        bs_attention(q, k, v, spec="causal")


# ---------------------------------------------------------------------------
# MaskSpec + compile_mask invariants
# ---------------------------------------------------------------------------


def test_maskspec_validation_and_tags():
    with pytest.raises(ValueError, match="kind"):
        MaskSpec("banded")
    with pytest.raises(ValueError, match="multiple of 8"):
        MaskSpec("causal", block=12)
    with pytest.raises(ValueError, match="window"):
        MaskSpec("local")
    with pytest.raises(ValueError, match="local-only"):
        MaskSpec("causal", window=8)
    with pytest.raises(ValueError, match="stride"):
        MaskSpec("strided")
    with pytest.raises(ValueError, match="blocks"):
        MaskSpec("blockwise")
    with pytest.raises(ValueError, match="non-negative"):
        MaskSpec("blockwise", blocks=((-1, 0),))
    # tags distinguish every spec under test (they key the autotune cache)
    tags = {s.tag for s in SPECS}
    assert len(tags) == len(SPECS)
    # blockwise pairs normalize: dedup + sort, so equal patterns hash equal
    a = MaskSpec("blockwise", blocks=((1, 0), (0, 0), (1, 0)))
    b = MaskSpec("blockwise", blocks=((0, 0), (1, 0)))
    assert a == b and a.tag == b.tag


def test_compile_mask_plan_invariants():
    spec = MaskSpec("local", block=16, window=24)
    plan = compile_mask(spec, 67, 67, (16, 16))
    assert (plan.nqb, plan.nkb) == (5, 5)
    # pair lists are row-major (q-block monotone) — the TPU kernel's
    # scratch init/flush depends on it
    assert (np.diff(plan.pair_q) >= 0).all()
    assert plan.n_live == plan.pair_q.size == int(plan.bitmap.sum())
    # the padded token grid is the shared predicate restricted in-bounds
    qp, kp = np.arange(80), np.arange(80)
    want = token_mask(spec, qp[:, None], kp[None, :])
    want = want & (qp[:, None] < 67) & (kp[None, :] < 67)
    assert (plan.tokens == want).all()
    assert plan.live_tokens == int(want.sum())
    assert 0.0 < plan.density <= 1.0 and plan.waste >= 1.0
    # gather rows cover exactly each q-row's live k-blocks
    for r in range(plan.nqb):
        live = set(np.nonzero(plan.bitmap[r])[0].tolist())
        got = set(plan.row_idx[r][plan.row_valid[r]].tolist())
        assert got == live, r
    # untileable shapes/tiles return None (the force-error trigger)
    assert compile_mask(spec, 0, 64, (16, 16)) is None
    assert compile_mask(spec, 64, 64, (12, 16)) is None


# ---------------------------------------------------------------------------
# the typed api.attention surface
# ---------------------------------------------------------------------------


def test_api_attention_prefill_and_cache_views():
    spec = MaskSpec("local", block=16, window=24)
    q, k, v = _qkv(jax.random.PRNGKey(11), sq=64)
    out = api.attention(q, k, v, mask=spec, tile=(16, 16))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(masked_reference(q, k, v, spec=spec)),
        rtol=1e-5, atol=2e-5)
    # decode view: one query at the cache frontier
    out_d = api.attention(q[:, -1:], k, v, mask=spec,
                          cache=CacheView.decode(jnp.int32(63)))
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out[:, -1:]),
                               rtol=1e-5, atol=2e-5)
    # chunk view: q_positions derived from the scalar cache offset
    out_c = api.attention(q[:, 32:], k, v, mask=spec,
                          cache=CacheView.chunk(jnp.int32(32)))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out[:, 32:]),
                               rtol=1e-5, atol=2e-5)


def test_api_attention_rejects_bad_cache_args():
    spec = MaskSpec("causal", block=16)
    q, k, v = _qkv(jax.random.PRNGKey(12), sq=32)
    with pytest.raises(TypeError, match="CacheView"):
        api.attention(q, k, v, mask=spec, cache={"mode": "decode"})
    with pytest.raises(ValueError, match="cache=None"):
        api.attention(q, k, v, mask=spec, cache=CacheView.train())
    with pytest.raises(ValueError, match="cache=None"):
        api.attention(q, k, v, mask=spec, cache=CacheView.prefill())


# ---------------------------------------------------------------------------
# model-level parity: cfg.mask vs the dense causal/window paths
# ---------------------------------------------------------------------------


def _attn_variant(cfg, **fields):
    """cfg with every AttnConfig mixer's mask/window fields replaced."""
    def blk(b):
        if isinstance(b.mixer, AttnConfig):
            return dataclasses.replace(
                b, mixer=dataclasses.replace(b.mixer, **fields))
        return b

    plan = tuple(
        ((tuple(blk(x) for x in entry) if isinstance(entry, tuple)
          else blk(entry)), rep)
        for entry, rep in cfg.plan)
    return dataclasses.replace(cfg, plan=plan)


@pytest.fixture()
def f32_compute():
    common.set_compute_dtype(jnp.float32)
    yield
    common.set_compute_dtype(jnp.bfloat16)


@pytest.mark.parametrize("arch,dense,masked", [
    ("yi-9b", dict(mask=None, window=12),
     dict(mask=MaskSpec("local", block=8, window=12), window=None)),
    ("deepseek-v2-lite-16b", dict(mask=None, window=None),
     dict(mask=MaskSpec("causal", block=8), window=None)),
], ids=["gqa-local", "mla-causal"])
def test_model_mask_matches_dense_equivalent(arch, dense, masked,
                                             f32_compute):
    gqa = arch == "yi-9b"
    """A MaskSpec encoding the same visibility as the dense causal /
    sliding-window path produces the same logits through the full model
    (GQA and MLA mixers), for train, prefill and decode — and the
    sparse family actually dispatched (no silent dense routing)."""
    cfg_d = _attn_variant(get_reduced(arch), **dense)
    cfg_m = _attn_variant(get_reduced(arch), **masked)
    lm_d, lm_m = LM(cfg_d), LM(cfg_m)
    params = lm_d.init(jax.random.PRNGKey(0))  # mask changes no params
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg_d.vocab_size)
    out_d, _, _ = lm_d.forward(params, tokens)
    registry.clear_history()
    out_m, _, _ = lm_m.forward(params, tokens)
    counts = registry.dispatch_counts("bs_attention")
    assert any(impl == "xla_bs_attention" and n > 0
               for (_, impl, _), n in counts.items()), counts
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_d),
                               rtol=2e-4, atol=2e-4)

    # prefill + one decode step: the decode family path
    def run(lm):
        caches = lm.init_cache(2, 32)
        lp, caches, _ = lm.forward(params, tokens,
                                   view=CacheView.prefill(), caches=caches)
        nxt = jnp.argmax(lp[:, -1:], -1)
        ld, _, _ = lm.forward(params, nxt,
                              view=CacheView.decode(jnp.int32(16)),
                              caches=caches)
        return lp, ld

    lp_d, ld_d = run(lm_d)
    registry.clear_history()
    lp_m, ld_m = run(lm_m)
    if gqa:  # MLA's absorbed decode applies the mask inline, no dispatch
        assert sum(
            registry.dispatch_counts("bs_attention_decode").values()) > 0
    np.testing.assert_allclose(np.asarray(lp_m), np.asarray(lp_d),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ld_m), np.asarray(ld_d),
                               rtol=2e-4, atol=2e-4)
