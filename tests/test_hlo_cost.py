"""Validation of the trip-count-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    x = jnp.zeros((256, 512))
    w = jnp.zeros((512, 128))
    res = analyze_hlo(_compiled_text(lambda x: x @ w, x))
    assert res["flops"] == 2 * 256 * 512 * 128


def test_scan_multiplies_by_trip_count():
    w = jnp.zeros((256, 256))
    x = jnp.zeros((256, 256))

    def f(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=12)[0]

    res = analyze_hlo(_compiled_text(f, x))
    one = 2 * 256 ** 3
    assert res["flops"] == pytest.approx(12 * one, rel=1e-6), \
        res["flops"] / one


def test_nested_scan_multiplies_both_levels():
    w = jnp.zeros((128, 128))
    x = jnp.zeros((128, 128))

    def inner(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=5)[0]

    def outer(x):
        return jax.lax.scan(lambda c, _: (inner(c), None), x, None,
                            length=3)[0]

    res = analyze_hlo(_compiled_text(outer, x))
    one = 2 * 128 ** 3
    assert res["flops"] == pytest.approx(15 * one, rel=1e-6), \
        res["flops"] / one


def test_matches_xla_on_loop_free_program():
    """Sanity: within 2x of XLA's own numbers when there are no loops."""
    x = jnp.zeros((512, 512))
    w1 = jnp.zeros((512, 1024))
    w2 = jnp.zeros((1024, 512))

    def f(x):
        return jax.nn.relu(x @ w1) @ w2

    compiled = jax.jit(f).lower(x).compile()
    from repro import compat

    xla = compat.cost_analysis(compiled)
    res = analyze_hlo(compiled.as_text())
    assert res["flops"] == pytest.approx(float(xla["flops"]), rel=0.05)


def test_grad_of_scan_counts_fwd_and_bwd():
    w = jnp.zeros((64, 64))
    x = jnp.zeros((8, 64))

    def loss(w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y * y)

    res = analyze_hlo(_compiled_text(jax.grad(loss), w))
    one_fwd = 2 * 8 * 64 * 64
    # fwd scan (7x) + bwd scan (7x, two matmuls each: dx and dw)
    assert res["flops"] >= 20 * one_fwd, res["flops"] / one_fwd
