"""Sweeps for the literal vindexmac gather-port kernel vs its oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels.indexmac_gather.ops import (
    indexmac_gather_positional as indexmac_gather_spmm,
)
from repro.kernels.indexmac_gather.ref import indexmac_gather_ref


@pytest.mark.parametrize("cfg", [NMConfig(1, 4), NMConfig(2, 4)], ids=lambda c: c.tag)
@pytest.mark.parametrize("shape", [(16, 128, 128), (8, 256, 128)],
                         ids=lambda s: "Mr%dK%dN%d" % s)
def test_gather_kernel_matches_oracle(cfg, shape):
    mr, k, nc = shape
    a = random_nm_matrix(jax.random.PRNGKey(0), (mr, k), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, nc), dtype=jnp.float32)
    y_ref = indexmac_gather_ref(vals, idx, b, cfg)
    y_k = indexmac_gather_spmm(vals, idx, b, cfg, block=(8, 128, 64))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(a @ b), rtol=1e-5, atol=1e-4)


def test_gather_typed_entry_uses_weight_metadata():
    """indexmac_gather(w, b) derives nm / use-kernel from the NMWeight
    itself (registry.weight_ctx) and rejects the wrong orientation."""
    from repro import api
    from repro.kernels import registry
    from repro.kernels.indexmac_gather.ops import indexmac_gather

    cfg = NMConfig(2, 4)
    a = random_nm_matrix(jax.random.PRNGKey(2), (16, 128), cfg, axis=1)
    w = api.sparsify(a, cfg, axis=1, kernel_policy="auto")
    b = jax.random.normal(jax.random.PRNGKey(3), (128, 128))
    registry.clear_history()
    y = indexmac_gather(w, b)
    assert registry.last_dispatch("indexmac_gather").impl == "pallas_gather"
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)

    registry.clear_history()
    w_off = api.sparsify(a, cfg, axis=1, kernel_policy="off")
    indexmac_gather(w_off, b)
    assert registry.last_dispatch("indexmac_gather").impl == "reference"

    with pytest.raises(ValueError, match="axis"):
        indexmac_gather(api.sparsify(a.T, cfg, axis=0), b)
