"""_write_cache per-slot-offset edge cases (models/attention.py).

The cache write is the one primitive every serving mode shares (decode,
chunked prefill, paged pools all funnel through it or its paged
sibling), so its offset semantics are pinned here: s=1 vs s>1 writes at
ragged per-slot offsets, the boundary write at exactly ``max_seq - s``,
and the out-of-range contract — a typed :class:`CacheLenError` for
concrete offsets, explicit drop (never wraparound) for traced ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import CacheLenError, _write_cache

S = 16  # max_seq of the toy cache


def _cache(b=4, h=2, d=3):
    return jnp.zeros((b, S, h, d), jnp.float32)


def _new(b, s, h=2, d=3, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))


def _ref(cache, new, offsets):
    out = np.array(cache)
    s = new.shape[1]
    for i, off in enumerate(np.atleast_1d(offsets)):
        out[i, off:off + s] = np.asarray(new)[i]
    return out


def test_single_token_write_at_ragged_offsets():
    off = jnp.asarray([0, 5, 11, S - 1], jnp.int32)
    new = _new(4, 1)
    got = _write_cache(_cache(), new, off)
    np.testing.assert_array_equal(np.asarray(got),
                                  _ref(_cache(), new, np.asarray(off)))


def test_chunk_write_at_ragged_offsets():
    """s>1 per-slot writes: each slot's chunk lands at its own offset,
    untouched rows stay exactly zero."""
    off = jnp.asarray([0, 3, 7, S - 4], jnp.int32)
    new = _new(4, 4, seed=1)
    got = _write_cache(_cache(), new, off)
    np.testing.assert_array_equal(np.asarray(got),
                                  _ref(_cache(), new, np.asarray(off)))


def test_write_at_exactly_max_seq_minus_s():
    """The boundary write fills the last s rows and raises nothing."""
    for s in (1, 4):
        off = jnp.full((2,), S - s, jnp.int32)
        new = _new(2, s, seed=2)
        got = np.asarray(_write_cache(_cache(b=2), new, off))
        np.testing.assert_array_equal(got[:, S - s:], np.asarray(new))
        assert (got[:, :S - s] == 0).all()


def test_scalar_offset_matches_vector_offset():
    """The dry-run scalar path and the per-slot vector path agree when
    every slot shares one offset."""
    new = _new(3, 4, seed=3)
    scalar = _write_cache(_cache(b=3), new, jnp.int32(5))
    vector = _write_cache(_cache(b=3), new, jnp.full((3,), 5, jnp.int32))
    np.testing.assert_array_equal(np.asarray(scalar), np.asarray(vector))


@pytest.mark.parametrize("off,s", [
    (S, 1),          # one past the end
    (S - 1, 2),      # chunk straddles the end
    (-1, 1),         # negative offset
])
def test_concrete_out_of_range_raises_typed_error(off, s):
    with pytest.raises(CacheLenError):
        _write_cache(_cache(b=2), _new(2, s),
                     jnp.full((2,), off, jnp.int32))


def test_concrete_scalar_out_of_range_raises_typed_error():
    with pytest.raises(CacheLenError):
        _write_cache(_cache(), _new(4, 2), jnp.int32(S - 1))


def test_traced_out_of_range_drops_not_wraps():
    """Inside jit the offset can't be inspected; rows past the end must
    be DISCARDED — a wraparound would corrupt position 0 (the start of
    some request's prompt)."""
    new = _new(2, 2, seed=4)
    mixed = jnp.asarray([3, S - 1], jnp.int32)  # slot 1 straddles the end

    got = np.asarray(jax.jit(_write_cache)(_cache(b=2), new, mixed))
    # in-range slot written in full
    np.testing.assert_array_equal(got[0, 3:5], np.asarray(new)[0])
    # straddling slot: first row lands, overflow row dropped — and
    # crucially position 0 is untouched (no wraparound)
    np.testing.assert_array_equal(got[1, S - 1], np.asarray(new)[1, 0])
    assert (got[1, 0] == 0).all()
    assert (got[0, :3] == 0).all() and (got[0, 5:] == 0).all()
