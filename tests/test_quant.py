"""Quantized sparse execution: QNMWeight round-trips, the int8 kernel
family (bit-exact vs its oracle on the integer lattice, odd/padded
shapes included), decode top-1 parity vs bf16 (mirroring
test_fp8_cache.py), and the end-to-end wiring — api, serving, autotune
warmup, checkpoint v3, sharding, optimizer, cost accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core.nmweight import KernelPolicy, NMWeight
from repro.models.cache import CacheView
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels import registry
from repro.quant import (
    AbsMaxObserver,
    PercentileObserver,
    QNMWeight,
    quantize_nm,
    quantize_tree,
)

# ---------------------------------------------------------------------------
# quantize / dequantize round-trip
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    kblocks=st.integers(1, 6),
    n=st.integers(1, 12),
    pattern=st.sampled_from([(1, 2), (1, 4), (2, 4), (3, 8)]),
    seed=st.integers(0, 2**16),
)
def test_dequantize_quantize_error_bound_per_channel(kblocks, n, pattern,
                                                     seed):
    """Absmax int8: |deq(q(w)) - w| <= scale/2 elementwise, with each
    channel's own scale — the per-channel quantization error bound."""
    nm = NMConfig(*pattern)
    k = kblocks * nm.m
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    sw = api.sparsify(w, nm)
    qw = api.quantize(sw)
    assert isinstance(qw, QNMWeight)
    assert qw.vals.dtype == jnp.int8 and qw.scales.shape == (n,)
    np.testing.assert_array_equal(np.asarray(qw.idx), np.asarray(sw.idx))
    dq = api.dequantize(qw)
    err = np.abs(np.asarray(dq.vals) - np.asarray(sw.vals))
    bound = np.asarray(qw.scales)[None, :] * 0.5 * (1 + 1e-5) + 1e-7
    assert (err <= bound).all(), (err.max(), bound.min())


def test_quantize_dense_input_and_validation():
    nm = NMConfig(2, 4)
    qw = api.quantize(jax.random.normal(jax.random.PRNGKey(0), (16, 8)), nm)
    assert isinstance(qw, QNMWeight) and qw.vals.shape == (8, 8)
    with pytest.raises(ValueError, match="nm is required"):
        api.quantize(jnp.ones((8, 4)))
    with pytest.raises(ValueError, match="conflicts"):
        api.quantize(api.sparsify(jnp.ones((8, 4)), nm), NMConfig(1, 4))
    with pytest.raises(TypeError, match="already quantized"):
        api.quantize(qw)
    with pytest.raises(TypeError, match="QNMWeight"):
        api.dequantize(api.sparsify(jnp.ones((8, 4)), nm))


def test_percentile_observer_clips_outliers():
    """One huge outlier per channel: percentile calibration ignores it
    (finer resolution for the bulk, outlier saturates at +-127), absmax
    does not."""
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(1), (512, 4), nm, axis=0)
    w = w.at[0, :].set(1e3)  # outlier in every channel
    sw = api.sparsify(w, nm)
    q_abs = api.quantize(sw, method="absmax")
    q_pct = api.quantize(sw, method=PercentileObserver(pct=90.0))
    assert float(q_pct.scales.max()) < float(q_abs.scales.min())
    # the outlier saturated at the int8 rail under percentile calibration
    assert int(np.asarray(q_pct.vals).max()) == 127


def test_observer_api_validation():
    with pytest.raises(ValueError, match="no data"):
        AbsMaxObserver().scales()
    with pytest.raises(ValueError, match="pct"):
        PercentileObserver(pct=0.0)
    with pytest.raises(ValueError, match="unknown calibration"):
        quantize_nm(api.sparsify(jnp.ones((8, 4)), NMConfig(2, 4)),
                    method="zen")
    obs = AbsMaxObserver()
    obs.observe(jnp.ones((8, 4)))
    obs.observe(2 * jnp.ones((8, 4)))  # running max across observations
    np.testing.assert_allclose(np.asarray(obs.scales()), 2.0 / 127)


# ---------------------------------------------------------------------------
# pytree semantics
# ---------------------------------------------------------------------------


def test_qnmweight_is_a_pytree():
    qw = api.quantize(jax.random.normal(jax.random.PRNGKey(2), (16, 8)),
                      NMConfig(2, 4))
    leaves, treedef = jax.tree_util.tree_flatten(qw)
    assert len(leaves) == 3  # vals, idx, scales — metadata in the treedef
    mapped = jax.tree.map(lambda x: x, qw)
    assert isinstance(mapped, QNMWeight) and mapped.nm == qw.nm
    other = dataclasses.replace(qw, nm=NMConfig(1, 4))
    assert jax.tree_util.tree_structure(other) != treedef
    assert api.is_sparse(qw)

    @jax.jit
    def f(x, qw):
        return api.nm_matmul(x, qw).sum()

    assert np.isfinite(float(f(jnp.ones((4, 16)), qw)))


def test_quantize_tree_walks_nmweight_leaves_only():
    nm = NMConfig(2, 4)

    def mk(key):
        return api.sparsify(jax.random.normal(key, (32, 16)), nm)

    stacked = jax.vmap(mk)(jax.random.split(jax.random.PRNGKey(3), 3))
    tree = {"flat": mk(jax.random.PRNGKey(4)), "stack": stacked,
            "dense": {"w": jnp.ones((4, 4))}, "scale": jnp.ones((4,))}
    qt = quantize_tree(tree)
    assert isinstance(qt["flat"], QNMWeight)
    assert isinstance(qt["stack"], QNMWeight)
    assert qt["stack"].vals.shape == (3, 16, 16)
    assert qt["stack"].scales.shape == (3, 16)  # per-slice channels
    assert qt["dense"]["w"].dtype == jnp.float32  # untouched
    # per-slice scales really differ (each layer calibrated on its own)
    assert len({float(s) for s in np.asarray(qt["stack"].scales[:, 0])}) > 1


def test_quantize_tree_rejects_shared_observer_instances():
    """An observer accumulates statistics across observe() calls, so one
    instance walked over every leaf would contaminate each leaf's scales
    with all previous leaves' — the tree walk must refuse it."""
    nm = NMConfig(2, 4)
    tree = {"a": api.sparsify(jax.random.normal(jax.random.PRNGKey(20),
                                                (16, 8)), nm)}
    with pytest.raises(TypeError, match="observer instance"):
        quantize_tree(tree, method=AbsMaxObserver())
    # per-leaf scales are independent: a huge first leaf must not
    # inflate a small second leaf's scales
    big = api.sparsify(1e3 * jax.random.normal(jax.random.PRNGKey(21),
                                               (16, 8)), nm)
    small = api.sparsify(1e-3 * jax.random.normal(jax.random.PRNGKey(22),
                                                  (16, 8)), nm)
    qt = quantize_tree({"big": big, "small": small})
    assert float(qt["small"].scales.max()) < 1e-3
    assert int(np.abs(np.asarray(qt["small"].vals)).max()) > 0


# ---------------------------------------------------------------------------
# int8 kernel family: dispatch + bit-exactness
# ---------------------------------------------------------------------------


def _int_lattice_problem(k, n, m_rows, nm, seed=0):
    """Integer-valued operands: every f32 partial sum is an exactly
    representable integer (|acc| << 2^24), so kernel-vs-oracle equality
    is bitwise regardless of tiling/padding — real bit-exactness, not
    allclose."""
    rng = np.random.default_rng(seed)
    w = random_nm_matrix(jax.random.PRNGKey(seed), (k, n), nm, axis=0)
    sw = api.sparsify(w, nm)
    qvals = rng.integers(-127, 128, size=sw.vals.shape).astype(np.int8)
    # zero-padded slots must stay zero (the N:M invariant)
    qvals = np.where(np.asarray(sw.vals) == 0, 0, qvals).astype(np.int8)
    scales = rng.uniform(0.01, 0.1, size=(n,)).astype(np.float32)
    qw = QNMWeight(vals=jnp.asarray(qvals), idx=sw.idx,
                   scales=jnp.asarray(scales), nm=nm,
                   kernel_policy=KernelPolicy("force"))
    x = rng.integers(-8, 9, size=(m_rows, k)).astype(np.float32)
    return jnp.asarray(x), qw


@pytest.mark.parametrize("shape", [
    (128, 128, 64),    # exactly tileable
    (36, 20, 5),       # odd everything -> padded geometry
    (132, 200, 7),     # odd, multi-padded
], ids=lambda s: "K%dN%dM%d" % s)
@pytest.mark.parametrize("pattern", [(1, 4), (2, 4)],
                         ids=lambda p: "%d:%d" % p)
def test_int8_kernel_bit_exact_vs_int8_ref(shape, pattern):
    from repro.kernels.indexmac.ref import nm_matmul_q_ref

    k, n, m_rows = shape
    nm = NMConfig(*pattern)
    x, qw = _int_lattice_problem(k, n, m_rows, nm)
    registry.clear_history()
    y_k = api.nm_matmul(x, qw)  # force policy -> Pallas kernel
    # skinny M routes to the decode family, larger M to the padded kernel
    if m_rows <= 8:
        rec = registry.last_dispatch("nm_matmul_decode_q")
        assert rec.impl == "pallas_decode_q", rec
    else:
        rec = registry.last_dispatch("nm_matmul_q")
        assert rec.impl == "pallas_padded_q", rec
    y_ref = nm_matmul_q_ref(x, qw.vals, qw.idx, qw.scales, nm)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_ref))


def test_int8_policy_off_pins_reference():
    nm = NMConfig(2, 4)
    x, qw = _int_lattice_problem(64, 16, 4, nm)
    qw = dataclasses.replace(qw, kernel_policy=KernelPolicy("off"))
    registry.clear_history()
    api.nm_matmul(x, qw)
    rec = registry.last_dispatch("nm_matmul_decode_q")  # M=4: decode family
    assert rec.impl == "reference_decode_q"
    assert "use_kernel=False" in rec.reason


def test_int8_matches_float_reference_within_quant_noise():
    """End to end: the int8 path approximates the float sparse matmul
    with error bounded by the per-channel scales."""
    nm = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(5), (256, 128), nm, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(6), (16, 256))
    qw = api.quantize(api.sparsify(w, nm))
    y_q = api.nm_matmul(x, qw)
    y_f = x @ w
    rel = float(jnp.abs(y_q - y_f).max() / jnp.abs(y_f).max())
    assert rel < 0.05, rel


def test_int8_gather_kernel_matches_its_ref():
    from repro.kernels.indexmac_gather.ops import indexmac_gather
    from repro.kernels.indexmac_gather.ref import indexmac_gather_q_ref

    nm = NMConfig(2, 4)
    a = random_nm_matrix(jax.random.PRNGKey(7), (16, 256), nm, axis=1)
    vals, idx = compress_nm(a, nm, axis=1)
    sw = NMWeight(vals=vals, idx=idx, nm=nm, axis=1,
                  kernel_policy=KernelPolicy("auto"))
    qw = quantize_nm(sw)
    assert qw.scales.shape == (16,)  # per output ROW in A-orientation
    b = jax.random.normal(jax.random.PRNGKey(8), (256, 128))
    registry.clear_history()
    c = indexmac_gather(qw, b)
    assert registry.last_dispatch("indexmac_gather_q").impl == \
        "pallas_gather_q"
    c_ref = indexmac_gather_q_ref(qw.vals, qw.idx, qw.scales, b, nm)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-4)


def test_int8_autotune_keys_are_their_own_family(tmp_path, monkeypatch):
    """best_block(dtype=int8) and the float lookup must never share a
    cache entry — the int8 family sweeps its own kernel."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    nm = NMConfig(2, 4)
    blk_q = autotune.tune(8, 128, 128, nm, dtype=jnp.int8,
                          candidates=[(8, 128, 128)], repeats=1)
    assert blk_q == (8, 128, 128)
    # the int8 winner is cached under its own key...
    assert autotune.cached_block(8, 128, 128, nm, jnp.int8) == blk_q
    # ...and invisible to the float family
    assert autotune.cached_block(8, 128, 128, nm, jnp.float32) is None
    autotune.clear_memory_cache()


# ---------------------------------------------------------------------------
# decode parity vs bf16 (mirrors test_fp8_cache.py)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sparse_yi():
    from repro.configs import get_reduced
    from repro.configs.base import SparsityConfig
    from repro.models import common
    from repro.models.transformer import LM

    common.set_compute_dtype(jnp.float32)
    cfg = get_reduced("yi-9b")
    cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(
        nm=NMConfig(2, 4), mode="compressed", use_kernel=False))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    yield cfg, lm, params
    common.set_compute_dtype(jnp.bfloat16)


def test_int8_decode_top1_matches_float(sparse_yi):
    cfg, lm, params = sparse_yi
    qparams = quantize_tree(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    out = {}
    for name, p in (("float", params), ("int8", qparams)):
        caches = lm.init_cache(2, 32)
        lp, caches, _ = lm.forward(p, tokens, view=CacheView.prefill(),
                                   caches=caches)
        nxt = jnp.argmax(lp[:, -1:], -1)
        ld, _, _ = lm.forward(p, nxt, view=CacheView.decode(jnp.int32(16)),
                              caches=caches)
        out[name] = np.asarray(ld, np.float32)
    rel = (np.abs(out["float"] - out["int8"]).max()
           / (np.abs(out["float"]).max() + 1e-9))
    assert rel < 0.15, rel  # int8 noise stays bounded
    # greedy decoding is unchanged
    assert (out["float"].argmax(-1) == out["int8"].argmax(-1)).all()


def test_serve_engine_quantize_int8_end_to_end(sparse_yi):
    from repro.serving.engine import Request, ServeEngine

    cfg, lm, params = sparse_yi
    eng_f = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8)
    eng_q = ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8,
                        quantize="int8")
    leaves = jax.tree.leaves(
        eng_q.params, is_leaf=lambda x: isinstance(x, QNMWeight))
    assert any(isinstance(l, QNMWeight) for l in leaves)
    p = np.arange(8, dtype=np.int32)
    for eng in (eng_f, eng_q):
        eng.submit(Request(rid=0, prompt=p.copy(), max_new=6))
    assert eng_q.run()[0].out == eng_f.run()[0].out  # greedy top-1 parity
    with pytest.raises(ValueError, match="quantize"):
        ServeEngine(lm, params, slots=1, max_seq=64, prefill_len=8,
                    quantize="int4")


def test_serve_with_kernels_routes_through_int8_family(sparse_yi):
    """use_kernel=True + quantize="int8": every compressed GEMM the
    engine issues dispatches through the nm_matmul_q family, and the
    prefill shapes actually take the Pallas q-kernel (decode's tiny M
    legitimately falls back on pad waste)."""
    from repro.serving.engine import Request, ServeEngine

    cfg, lm, _ = sparse_yi
    kcfg = dataclasses.replace(cfg, sparsity=dataclasses.replace(
        cfg.sparsity, use_kernel=True))
    klm = type(lm)(kcfg)
    kparams = klm.init(jax.random.PRNGKey(0))
    registry.clear_history()
    eng = ServeEngine(klm, kparams, slots=2, max_seq=32, prefill_len=8,
                      quantize="int8")
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new=2))
    assert len(eng.run()) == 1
    recs = registry.dispatch_history("nm_matmul_q")
    assert recs, "no quantized GEMM dispatches recorded"
    assert any(r.impl == "pallas_padded_q" for r in recs)
    assert not registry.dispatch_history("nm_matmul")  # nothing floats


def test_autotune_warmup_walks_qnmweight_leaves(sparse_yi, monkeypatch):
    """quantize="int8" + autotune_blocks=True must sweep every compressed
    GEMM shape under the int8 family's keys (value dtype int8)."""
    from repro.kernels import autotune
    from repro.serving.engine import ServeEngine

    cfg, lm, params = sparse_yi
    kcfg = dataclasses.replace(cfg, sparsity=dataclasses.replace(
        cfg.sparsity, use_kernel=True))
    klm = type(lm)(kcfg)
    kparams = klm.init(jax.random.PRNGKey(0))

    asked = []
    monkeypatch.setattr(
        autotune, "ensure_tuned",
        lambda m, n, k, nm, dtype=None, family="", backend="tpu":
            asked.append((m, n, k, jnp.dtype(dtype).name)) or (8, 128, 128))
    ServeEngine(klm, kparams, slots=2, max_seq=64, prefill_len=8,
                autotune_blocks=True, quantize="int8")
    assert asked and all(dt == "int8" for *_, dt in asked)
    want = set()
    for leaf in jax.tree.leaves(
            kparams, is_leaf=lambda x: isinstance(x, NMWeight)):
        if isinstance(leaf, NMWeight):
            kc, n = leaf.vals.shape[-2:]
            for m_rows in (2, 16):
                want.add((m_rows, n, kc * leaf.nm.m // leaf.nm.n))
    assert {(m, n, k) for m, n, k, _ in asked} == want


# ---------------------------------------------------------------------------
# checkpoint v3
# ---------------------------------------------------------------------------


def _quant_state():
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    qw = api.quantize(jax.random.normal(k1, (16, 8)), NMConfig(2, 4))
    sw = api.sparsify(jax.random.normal(k2, (16, 4)), NMConfig(1, 4))
    return {"params": {"ffn": {"w_up": qw}, "attn": {"wq": sw},
                       "norm": {"scale": jnp.ones((8,))}}}


def test_checkpoint_v3_roundtrip_preserves_scales_and_metadata(tmp_path):
    from repro.training.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    st = _quant_state()
    ck.save(3, st)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    got, meta = ck.restore(template)
    assert meta["format"] == 3
    rest = got["params"]["ffn"]["w_up"]
    orig = st["params"]["ffn"]["w_up"]
    assert isinstance(rest, QNMWeight)
    for f in ("vals", "idx", "scales"):
        np.testing.assert_array_equal(np.asarray(getattr(rest, f)),
                                      np.asarray(getattr(orig, f)))
    assert rest.nm == orig.nm and rest.axis == orig.axis
    wm = meta["weights"]["params/ffn/w_up"]
    assert wm["kind"] == "quantized" and wm["scale_dtype"] == "float32"
    assert meta["weights"]["params/attn/wq"]["kind"] == "compressed"


def test_checkpoint_quantized_vs_float_kind_mismatch_rejected(tmp_path):
    """A float (v2-era) checkpoint must not silently restore into a
    quantized template, nor vice versa — kind is part of the contract."""
    from repro.training.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    st = _quant_state()
    ck.save(1, st)
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    qw = st["params"]["ffn"]["w_up"]
    bad["params"]["ffn"]["w_up"] = api.dequantize(qw)  # float template
    with pytest.raises(ValueError, match="metadata mismatch"):
        ck.restore(bad)


def test_checkpoint_v2_float_checkpoints_load_unchanged(tmp_path):
    """A pre-quantization (format 2) checkpoint restores byte-identically
    through the same positional path — v3 only added a node kind."""
    import json
    import os

    from repro.training.checkpoint import Checkpointer

    st = {"w_up": api.sparsify(
        jax.random.normal(jax.random.PRNGKey(10), (16, 8)), NMConfig(2, 4)),
        "scale": jnp.ones((8,))}
    ck = Checkpointer(str(tmp_path))
    ck.save(2, st)
    mpath = os.path.join(str(tmp_path), "step_00000002", "manifest.json")
    with open(mpath) as f:
        meta = json.load(f)
    meta["format"] = 2  # byte-identical to a pre-quant checkpoint
    with open(mpath, "w") as f:
        json.dump(meta, f)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    got, meta = ck.restore(template)
    assert meta["format"] == 2
    np.testing.assert_array_equal(np.asarray(got["w_up"].vals),
                                  np.asarray(st["w_up"].vals))


# ---------------------------------------------------------------------------
# sharding + optimizer + cost accounting
# ---------------------------------------------------------------------------


def test_sharding_co_shards_scales_with_vals_out_axis():
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import param_pspecs

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    qw = api.quantize(jax.random.normal(jax.random.PRNGKey(11), (16, 8)),
                      NMConfig(2, 4))
    specs = param_pspecs({"ffn": {"w_up": qw}}, mesh)
    got = specs["ffn"]["w_up"]
    assert got.vals == P("data", "model")
    assert got.idx == P("data", "model")
    assert got.scales == P("model")  # rides with the vals output axis


def test_sharding_expert_stacked_scales_keep_expert_axis():
    """Expert-parallel quantized weights: the (E, N) scales must shard
    the leading E axis WITH vals — a replicated scales array paired with
    expert-sharded vals would mispair scale rows with expert slices."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import param_pspecs

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    nm = NMConfig(2, 4)

    def mk(key):
        return api.quantize(jax.random.normal(key, (16, 8)), nm)

    stacked = jax.vmap(mk)(jax.random.split(jax.random.PRNGKey(23), 4))
    specs = param_pspecs({"experts": {"w_up": stacked}}, mesh)
    got = specs["experts"]["w_up"]
    assert got.vals == P("model", "data", None)  # EP on the E axis
    assert got.scales == P("model", None)  # E co-sharded, channels local


def test_optimizer_excludes_int8_leaves_structurally():
    from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update

    qw = api.quantize(jax.random.normal(jax.random.PRNGKey(12), (16, 8)),
                      NMConfig(2, 4))
    params = {"q": qw, "w": jnp.ones((4,))}
    state = adamw_init(params)
    # moment placeholders for the quantized node are scalars, not arrays
    assert state["m"]["q"].vals.shape == ()
    assert state["m"]["q"].scales.shape == ()
    grads = {"q": jax.tree.map(jnp.zeros_like, qw),
             "w": jnp.ones((4,))}
    p2, _, _ = adamw_update(AdamWConfig(lr=0.1, warmup_steps=0,
                                        total_steps=10),
                            params, grads, state)
    for f in ("vals", "idx", "scales"):
        np.testing.assert_array_equal(np.asarray(getattr(p2["q"], f)),
                                      np.asarray(getattr(qw, f)))
    assert not np.allclose(np.asarray(p2["w"]), 1.0)  # dense still trains


def test_global_norm_ignores_frozen_qnmweight_grads():
    """A nonzero scales gradient on a frozen QNMWeight must not leak
    into the clip norm applied to trainable parameters."""
    import dataclasses as dc

    from repro.optim.optimizer import global_norm

    qw = api.quantize(jax.random.normal(jax.random.PRNGKey(13), (16, 8)),
                      NMConfig(2, 4))
    dense_g = {"w": jnp.ones((4,))}
    with_q = {"w": jnp.ones((4,)),
              "q": dc.replace(qw, scales=1e6 * jnp.ones_like(qw.scales))}
    np.testing.assert_allclose(np.asarray(global_norm(dense_g)),
                               np.asarray(global_norm(with_q)))


def test_int8_path_moves_fewer_bytes_than_bf16_path():
    """Acceptance: for the same GEMM, the int8 N:M kernel streams fewer
    HBM bytes than the bf16 N:M kernel, which streams fewer than dense."""
    from repro.core.cost_model import (
        tpu_dense_cost,
        tpu_indexmac_cost,
        tpu_indexmac_q_cost,
    )

    m, k, n = 16, 4096, 11008
    nm = NMConfig(2, 4)
    dense = tpu_dense_cost(m, k, n).hbm_bytes
    bf16 = tpu_indexmac_cost(m, k, n, nm).hbm_bytes
    int8 = tpu_indexmac_q_cost(m, k, n, nm).hbm_bytes
    assert int8 < bf16 < dense
    # weight-only view: value bytes halve, the idx byte stays
    kept = k * n * nm.n // nm.m
    assert (bf16 - int8) == pytest.approx(kept - 4 * n)


def test_byte_ratio_threads_explicit_value_bytes():
    nm = NMConfig(2, 4)
    assert nm.byte_ratio(value_bytes=2) == pytest.approx(0.75)   # bf16
    assert nm.byte_ratio(value_bytes=1) == pytest.approx(0.5)    # int8
    assert NMConfig(1, 4).byte_ratio(value_bytes=1) == pytest.approx(0.25)
    from repro.core.sparsity import value_bytes_of

    assert value_bytes_of(jnp.int8) == 1
    assert value_bytes_of(jnp.bfloat16) == 2
    assert value_bytes_of(jnp.float32) == 4
    with pytest.raises(TypeError):
        nm.byte_ratio()  # the 2-byte default is gone — be explicit
