"""GPU kernel family (Pallas-on-Triton lowering) + backend-axis tests.

Parity sweeps run the GPU kernel bodies in interpret mode against the
jnp oracles — the same bodies Triton compiles on a real GPU. The
routing tests opt the gpu backend in with ``REPRO_GPU_INTERPRET=1``
(per-test, via monkeypatch) and assert the dispatch layer routes,
reports and counts the backend exactly as a CUDA host would.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.nmweight import KernelPolicy, NMWeight
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels import autotune, registry
from repro.kernels.backend import interpret_for, platform_backend, resolve_backend
from repro.kernels.indexmac.ref import nm_matmul_q_ref, nm_matmul_ref
from repro.kernels.indexmac_gather.ref import (
    indexmac_gather_q_ref,
    indexmac_gather_ref,
)
from repro.kernels.indexmac_gpu import (
    indexmac_gather_gpu,
    indexmac_gather_gpu_q,
    nm_spmm_gpu,
    nm_spmm_gpu_decode,
    nm_spmm_gpu_decode_q,
    nm_spmm_gpu_q,
)

CFGS = [NMConfig(1, 2), NMConfig(1, 4), NMConfig(2, 4)]


def _mk(cfg, K, N, M, dtype, seed=0):
    w = random_nm_matrix(jax.random.PRNGKey(seed), (K, N), cfg, axis=0).astype(dtype)
    vals, idx = compress_nm(w, cfg, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)).astype(dtype)
    return x, w, vals, idx


def _mk_int8(cfg, K, N, M, seed=0):
    """Integer-lattice operands: integer-valued f32 x, int8 vals — every
    partial sum is an exactly-representable integer (< 2^24), so the
    kernel must be *bit-exact* vs the reference regardless of tiling."""
    _, _, vals, idx = _mk(cfg, K, N, M, jnp.float32, seed)
    vals_q = jnp.clip(jnp.round(vals * 64.0), -127, 127).astype(jnp.int8)
    scales = (0.5 + jax.random.uniform(jax.random.PRNGKey(seed + 2), (N,))
              ).astype(jnp.float32)
    x = jnp.round(
        jax.random.normal(jax.random.PRNGKey(seed + 3), (M, K)) * 8.0)
    return x, vals_q, idx, scales


# ---------------------------------------------------------------------------
# kernel parity (interpret mode), all three GPU families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize(
    "shape", [(256, 128, 64), (512, 384, 128)], ids=lambda s: "K%dN%dM%d" % s
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_gpu_prefill_matches_oracle(cfg, shape, dtype):
    K, N, M = shape
    x, w, vals, idx = _mk(cfg, K, N, M, dtype)
    y_ref = nm_matmul_ref(x, vals, idx, cfg, out_dtype=jnp.float32)
    y_k = nm_spmm_gpu(x, vals, idx, cfg=cfg, out_dtype=jnp.float32,
                      interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), rtol=tol, atol=tol * 10
    )


def test_gpu_prefill_multi_k_chunks():
    """K > block_k exercises the in-kernel reduction loop (nk > 1)."""
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 1024, 128, 32, jnp.float32)
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    y_k = nm_spmm_gpu(x, vals, idx, cfg=cfg, block_m=32, block_n=128,
                      block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
def test_gpu_prefill_int8_bit_exact(cfg):
    K, N, M = 512, 128, 16
    x, vals_q, idx, scales = _mk_int8(cfg, K, N, M)
    y_ref = nm_matmul_q_ref(x, vals_q, idx, scales, cfg)
    y_k = nm_spmm_gpu_q(x, vals_q, idx, scales, cfg=cfg, block_k=256,
                        interpret=True)
    assert np.array_equal(np.asarray(y_k), np.asarray(y_ref))


def test_gpu_decode_matches_oracle_with_fused_epilogue():
    cfg = NMConfig(2, 4)
    K, N, M = 512, 256, 8
    x, w, vals, idx = _mk(cfg, K, N, M, jnp.float32)
    bias = jax.random.normal(jax.random.PRNGKey(7), (N,)).astype(jnp.float32)
    y_ref = jnp.maximum(nm_matmul_ref(x, vals, idx, cfg) + bias, 0.0)
    y_k = nm_spmm_gpu_decode(x, vals, idx, bias, cfg=cfg, block_n=128,
                             activation="relu", interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_gpu_decode_int8_bit_exact():
    cfg = NMConfig(2, 4)
    K, N, M = 512, 256, 8
    x, vals_q, idx, scales = _mk_int8(cfg, K, N, M)
    y_ref = nm_matmul_q_ref(x, vals_q, idx, scales, cfg)
    y_k = nm_spmm_gpu_decode_q(x, vals_q, idx, scales, None, cfg=cfg,
                               interpret=True)
    assert np.array_equal(np.asarray(y_k), np.asarray(y_ref))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
def test_gpu_gather_matches_oracle(cfg):
    Mr, K, Nc = 32, 512, 128
    a = random_nm_matrix(jax.random.PRNGKey(0), (Mr, K), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, Nc), dtype=jnp.float32)
    y_ref = indexmac_gather_ref(vals, idx, b, cfg)
    y_k = indexmac_gather_gpu(vals, idx, b, cfg=cfg, block_m=16,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_gpu_gather_int8_bit_exact():
    cfg = NMConfig(2, 4)
    Mr, K, Nc = 32, 512, 128
    a = random_nm_matrix(jax.random.PRNGKey(0), (Mr, K), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    vals_q = jnp.clip(jnp.round(vals * 64.0), -127, 127).astype(jnp.int8)
    scales = (0.5 + jax.random.uniform(jax.random.PRNGKey(2), (Mr,))
              ).astype(jnp.float32)
    b = jnp.round(jax.random.normal(jax.random.PRNGKey(1), (K, Nc)) * 8.0)
    y_ref = indexmac_gather_q_ref(vals_q, idx, scales, b, cfg)
    y_k = indexmac_gather_gpu_q(vals_q, idx, scales, b, cfg=cfg, block_m=16,
                                interpret=True)
    assert np.array_equal(np.asarray(y_k), np.asarray(y_ref))


def test_gpu_kernels_reject_bad_shapes():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 64, jnp.float32)
    with pytest.raises(ValueError):
        nm_spmm_gpu(x, vals[:-2], idx[:-2], cfg=cfg, interpret=True)
    with pytest.raises(ValueError):  # block_k % m != 0
        nm_spmm_gpu(x, vals, idx, cfg=cfg, block_k=100, interpret=True)
    with pytest.raises(ValueError):  # decode M must be a sublane multiple
        nm_spmm_gpu_decode(x[:5], vals, idx, cfg=cfg, interpret=True)
    with pytest.raises(ValueError):  # quantized kernel needs int8 vals
        nm_spmm_gpu_q(x, vals, idx, jnp.ones((128,)), cfg=cfg, interpret=True)


# ---------------------------------------------------------------------------
# backend resolution (no GPU host in CI — the error paths are the point)
# ---------------------------------------------------------------------------


def _gpu_native() -> bool:
    return jax.default_backend() == "gpu"


def test_resolve_backend_auto_follows_platform(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert resolve_backend(None) == platform_backend()
    assert resolve_backend("auto") == platform_backend()
    assert resolve_backend("tpu") == "tpu"  # interpreter keeps tpu runnable


def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_GPU_INTERPRET", "1")
    monkeypatch.setenv("REPRO_BACKEND", "gpu")
    assert resolve_backend(None) == "gpu"
    # an explicit call/policy value beats the env var
    assert resolve_backend("tpu") == "tpu"
    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError, match="REPRO_BACKEND"):
        resolve_backend(None)
    with pytest.raises(ValueError):
        resolve_backend("cuda")


@pytest.mark.skipif(_gpu_native(), reason="host has a real GPU")
def test_forcing_gpu_without_opt_in_raises_typed_error(monkeypatch):
    monkeypatch.delenv("REPRO_GPU_INTERPRET", raising=False)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    with pytest.raises(api.KernelForceError, match="gpu"):
        resolve_backend("gpu")
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (256, 128), cfg, axis=0)
    sw = api.sparsify(w, cfg,
                      kernel_policy=KernelPolicy("force", backend="gpu"))
    x = jnp.ones((16, 256), jnp.float32)
    with pytest.raises(api.KernelForceError, match="call/policy"):
        api.nm_matmul(x, sw)
    with pytest.raises(api.KernelForceError, match="call/policy"):
        api.explain_dispatch(x.shape, sw)
    # $REPRO_BACKEND names its own source in the error
    sw_auto = api.sparsify(w, cfg, kernel_policy="force")
    monkeypatch.setenv("REPRO_BACKEND", "gpu")
    with pytest.raises(api.KernelForceError, match=r"\$REPRO_BACKEND"):
        api.nm_matmul(x, sw_auto)


def test_interpret_for_tracks_platform(monkeypatch):
    assert interpret_for("tpu") == (jax.default_backend() != "tpu")
    assert interpret_for("gpu") == (jax.default_backend() != "gpu")


# ---------------------------------------------------------------------------
# dispatch routing end-to-end under the interpreter opt-in
# ---------------------------------------------------------------------------


@pytest.fixture
def gpu_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_GPU_INTERPRET", "1")
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    registry.clear_history()
    yield
    registry.clear_history()


def test_policy_backend_routes_prefill_to_gpu(gpu_interpret):
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (512, 128), cfg, axis=0)
    sw = api.sparsify(w, cfg,
                      kernel_policy=KernelPolicy("force", backend="gpu"))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 512), jnp.float32)

    rec = api.explain_dispatch(x.shape, sw)
    assert rec.impl == "pallas_gpu" and rec.backend == "gpu"

    y = api.nm_matmul(x, sw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ api.densify(sw)),
                               rtol=1e-4, atol=1e-3)
    counts = registry.dispatch_counts(backend="gpu")
    assert counts[("nm_matmul", "pallas_gpu", "gpu")] >= 1


def test_call_arg_backend_overrides_auto_policy(gpu_interpret):
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (512, 128), cfg, axis=0)
    sw = api.sparsify(w, cfg, kernel_policy="force")  # backend stays auto
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 512), jnp.float32)
    rec = api.explain_dispatch(x.shape, sw, backend="gpu")
    assert rec.impl == "pallas_gpu" and rec.backend == "gpu"
    y = api.nm_matmul(x, sw, backend="gpu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ api.densify(sw)),
                               rtol=1e-4, atol=1e-3)


def test_env_backend_routes_auto_policy(gpu_interpret, monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "gpu")
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (512, 128), cfg, axis=0)
    sw = api.sparsify(w, cfg, kernel_policy="force")
    rec = api.explain_dispatch((64, 512), sw)
    assert rec.backend == "gpu"


def test_gpu_decode_route_and_quantized_families(gpu_interpret):
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (512, 256), cfg, axis=0)
    sw = api.sparsify(w, cfg,
                      kernel_policy=KernelPolicy("force", backend="gpu"))
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, 512), jnp.float32)
    rec = api.explain_dispatch(x1.shape, sw)
    assert rec.impl == "pallas_gpu_decode" and rec.backend == "gpu"
    y = api.nm_matmul(x1, sw)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x1 @ api.densify(sw)),
                               rtol=1e-4, atol=1e-3)

    qw = api.quantize(sw)
    qrec = api.explain_dispatch((64, 512), qw)
    assert qrec.impl == "pallas_gpu_q" and qrec.backend == "gpu"
    qrec1 = api.explain_dispatch(x1.shape, qw)
    assert qrec1.impl == "pallas_gpu_decode_q" and qrec1.backend == "gpu"


def test_gpu_gather_route(gpu_interpret):
    cfg = NMConfig(2, 4)
    a = random_nm_matrix(jax.random.PRNGKey(0), (32, 512), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    gw = NMWeight(vals=vals, idx=idx, nm=cfg, axis=1,
                  kernel_policy=KernelPolicy("force", backend="gpu"))
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)
    rec = api.explain_dispatch(b.shape, gw)
    assert rec.impl == "pallas_gpu_gather" and rec.backend == "gpu"
    y = api.indexmac_gather(gw, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(api.densify(gw) @ b),
                               rtol=1e-4, atol=1e-3)


def test_gpu_blocksparse_attention_route_and_parity(gpu_interpret):
    """backend="gpu" routes the bs_attention family to the output-tile
    gather kernel (interpret mode on this host — the same body Triton
    compiles on a real GPU) and matches the dense masked reference."""
    from repro.kernels.blocksparse_attn.mask import MaskSpec
    from repro.kernels.blocksparse_attn.ref import masked_reference

    spec = MaskSpec("local", block=16, window=24)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(kv, (2, 64, 2, 16), jnp.float32)

    rec = api.explain_dispatch_attention(q.shape, k.shape, mask=spec,
                                         backend="gpu", tile=(16, 16))
    assert rec.impl == "gpu_bs_attention" and rec.backend == "gpu"
    y = api.attention(q, k, v, mask=spec, backend="gpu", tile=(16, 16))
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(masked_reference(q, k, v, spec=spec)),
        rtol=1e-5, atol=2e-5)
    counts = registry.dispatch_counts(backend="gpu")
    assert counts[("bs_attention", "gpu_bs_attention", "gpu")] >= 1


@pytest.mark.skipif(_gpu_native(), reason="host has a real GPU")
def test_default_policy_still_routes_tpu_silently(monkeypatch):
    """Without the opt-in, gpu registrations are filtered *silently*:
    the default route keeps backend 'tpu' and an empty skip reason."""
    monkeypatch.delenv("REPRO_GPU_INTERPRET", raising=False)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (512, 128), cfg, axis=0)
    sw = api.sparsify(w, cfg, kernel_policy="force")
    rec = api.explain_dispatch((64, 512), sw)
    assert rec.backend == "tpu"
    assert rec.impl.startswith("pallas")
    assert rec.reason == ""


# ---------------------------------------------------------------------------
# autotune: backend-qualified keys + v1 -> v2 migration
# ---------------------------------------------------------------------------


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def test_key_carries_kernel_backend():
    cfg = NMConfig(2, 4)
    k_tpu = autotune._key(64, 128, 512, cfg, jnp.float32, "cpu")
    k_gpu = autotune._key(64, 128, 512, cfg, jnp.float32, "cpu", "gpu")
    assert k_tpu == "v2|cpu|tpu|float32|2:4|64x512x128"
    assert k_gpu == "v2|cpu|gpu|float32|2:4|64x512x128"
    assert autotune._key(8, 128, 512, cfg, jnp.float32, "cpu", "gpu",
                         "decode").endswith("|decode")


def test_migrate_key_v1_to_v2():
    old = "v1|cpu|float32|2:4|64x512x128"
    assert autotune._migrate_key(old) == "v2|cpu|tpu|float32|2:4|64x512x128"
    # decode-family suffix survives
    assert autotune._migrate_key("v1|tpu|int8|2:4|8x512x128|decode") == \
        "v2|tpu|tpu|int8|2:4|8x512x128|decode"
    # non-v1 and malformed keys pass through untouched
    v2 = "v2|cpu|gpu|float32|2:4|64x512x128"
    assert autotune._migrate_key(v2) == v2
    assert autotune._migrate_key("v1|broken") == "v1|broken"


def test_legacy_cache_migrates_on_load(tmp_cache):
    cfg = NMConfig(2, 4)
    platform = jax.default_backend()
    tmp_cache.write_text(json.dumps({
        # legacy entry: pre-backend-axis schema, tpu family implied
        f"v1|{platform}|float32|2:4|64x512x128": [64, 128, 256],
        # legacy entry shadowed by a native v2 one for the same problem
        f"v1|{platform}|float32|2:4|8x512x128": [8, 128, 256],
        f"v2|{platform}|tpu|float32|2:4|8x512x128": [8, 256, 512],
    }))
    assert autotune.cached_block(64, 128, 512, cfg, jnp.float32) == \
        (64, 128, 256)
    # native v2 wins over the migrated legacy entry
    assert autotune.cached_block(8, 128, 512, cfg, jnp.float32) == \
        (8, 256, 512)
    # the migrated entry is tpu-family only: no gpu hit
    assert autotune.cached_block(64, 128, 512, cfg, jnp.float32,
                                 backend="gpu") is None


def test_gpu_defaults_and_candidates(tmp_cache):
    assert autotune.default_block(backend="gpu") == autotune.DEFAULT_GPU_BLOCK
    assert autotune.default_block("decode", "gpu") == \
        autotune.DEFAULT_GPU_DECODE_BLOCK
    assert autotune.best_block(64, 128, 512, NMConfig(2, 4), jnp.float32,
                               backend="gpu") == autotune.DEFAULT_GPU_BLOCK
    cands = autotune.candidate_blocks(64, 128, 512, NMConfig(2, 4),
                                      backend="gpu")
    assert cands and all(len(c) == 3 for c in cands)


# ---------------------------------------------------------------------------
# checkpoint manifests carry the policy backend
# ---------------------------------------------------------------------------


def test_checkpoint_policy_meta_roundtrip():
    from repro.training.checkpoint import _policy_meta, policy_from_meta

    pol = KernelPolicy("force", block=(64, 128, 512), backend="gpu")
    meta = _policy_meta(pol)
    assert meta["backend"] == "gpu"
    assert policy_from_meta(meta) == pol
    # manifests written before the backend axis restore as "auto"
    legacy = {"mode": "auto", "block": None, "decode_block": None}
    assert policy_from_meta(legacy).backend == "auto"
