"""Sharded serving: token parity with the single-device engine on a
forced 8-device host mesh, plus unit tests for the head-aware TP spec
rules.

The parity matrix (float 2:4, int8 2:4, mixed 2:4/1:4, kv-head-sharded,
paged-KV) runs real multi-device CPU execution in a subprocess (device count must
be set before jax initializes — same pattern as test_sharding /
test_moe_distributed); each variant asserts identical token ids AND that
the compiled-step caches hold exactly one entry after serving (zero
recompiles after warmup)."""
import dataclasses
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_reduced
from repro.models.transformer import LM
from repro.parallel.sharding import serve_param_pspecs, serve_tp_plan

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.models import common
common.set_compute_dtype(jnp.float32)  # exactness for parity
from repro import compat
from repro.configs import get_reduced
from repro.configs.base import SparsityConfig
from repro.core.sparsity import NMConfig
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine, ShardedServeEngine

rng = np.random.default_rng(0)
mesh = compat.make_mesh((2, 4), ("data", "model"))

def check(cfg, quantize=None, tag="", chunk=None):
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(5)]
    kw = dict(slots=2, max_seq=64, prefill_len=8, quantize=quantize,
              prefill_chunk=chunk)
    def serve(make):
        eng = make()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=4 + i))
        return {r.rid: tuple(r.out) for r in eng.run()}, eng
    single, _ = serve(lambda: ServeEngine(lm, params, **kw))
    shard, es = serve(
        lambda: ShardedServeEngine(lm, params, mesh=mesh, **kw))
    assert shard == single, (tag, single, shard)
    cs = es.compiled_cache_sizes()
    assert cs in ({"prefill": 1, "decode": 1},
                  {"prefill": -1, "decode": -1}), (tag, cs)
    print(f"OKVARIANT {tag} {es.tp_plan.shard_attn:d}"
          f"{es.tp_plan.shard_kv:d}{es.tp_plan.shard_ffn:d}")

cfg = get_reduced("yi-9b")  # 2:4 compressed by default
check(cfg, tag="float24")
check(cfg, tag="float24-chunked", chunk=4)
check(cfg, quantize="int8", tag="int8")
mixed = dataclasses.replace(cfg, sparsity=SparsityConfig(
    nm=NMConfig(2, 4), mode="compressed",
    targets=("ffn", "attn_proj"),
    nm_overrides=(("attn_proj", NMConfig(1, 4)),)))
check(mixed, tag="mixednm")
# kv_heads divisible by tp: the KV cache actually shards on its head axis
kvblk, rep = cfg.plan[0]
kvcfg = dataclasses.replace(cfg, plan=((dataclasses.replace(
    kvblk, mixer=dataclasses.replace(kvblk.mixer, kv_heads=4)), rep),))
check(kvcfg, tag="kvsharded")
# kernel-on: token parity with the Pallas decode family engaged, and the
# decode compiles (single AND sharded) routed every GEMM to Pallas —
# asserted through the per-family dispatch counters, not the (bounded,
# evictable) record history
from repro.kernels import registry
kcfg = dataclasses.replace(cfg, sparsity=SparsityConfig(
    nm=NMConfig(2, 4), mode="compressed", use_kernel=True))
registry.clear_history()
check(kcfg, tag="kernel24")
counts = registry.dispatch_counts("nm_matmul_decode")
assert counts and sum(counts.values()) > 0, counts
bad = {k: v for k, v in counts.items() if not k[1].startswith("pallas")}
assert not bad, bad
print(f"KERNELDECODE ok {sum(int(v) for v in counts.values())}")
# block-sparse masked model: the sharded engine must serve a
# mask-bearing config token-identically to the single-device engine —
# the mask-aware decode family runs inside the shard_map'd steps on
# every shard (chunked prefill included) — with zero recompiles
from repro.configs.base import AttnConfig
from repro.kernels.blocksparse_attn.mask import MaskSpec
def _mask_blk(b):
    if not isinstance(b.mixer, AttnConfig):
        return b
    return dataclasses.replace(b, mixer=dataclasses.replace(
        b.mixer, mask=MaskSpec("local", block=8, window=12), window=None))
mcfg = dataclasses.replace(cfg, plan=tuple(
    ((tuple(_mask_blk(x) for x in e) if isinstance(e, tuple)
      else _mask_blk(e)), r) for e, r in cfg.plan))
registry.clear_history()
check(mcfg, tag="blocksparse", chunk=4)
bs = registry.dispatch_counts("bs_attention_decode")
assert bs and sum(bs.values()) > 0, bs
print(f"BSDECODE ok {sum(int(v) for v in bs.values())}")
# paged: the sharded PAGED engine (block-table gather, one page sub-pool
# per data shard, head-sharded pool pages via the unchanged cache specs)
# against the single-device SLOT engine — cross-engine AND cross-layout
# token parity in one shot. Shared prompt prefixes must actually hit the
# per-shard prefix caches. kvcfg so the pool's head axis really shards.
lm = LM(kvcfg)
params = lm.init(jax.random.PRNGKey(0))
pp = [rng.integers(0, kvcfg.vocab_size, size=8).astype(np.int32)
      for _ in range(5)]
for p in pp[1:]:
    p[:4] = pp[0][:4]  # every request shares the first page
kw = dict(slots=2, max_seq=64, prefill_len=8, prefill_chunk=4)
def serve_paged(make):
    eng = make()
    for i, p in enumerate(pp):
        eng.submit(Request(rid=i, prompt=p, max_new=4 + i))
    return {r.rid: tuple(r.out) for r in eng.run()}, eng
single, _ = serve_paged(lambda: ServeEngine(lm, params, **kw))
paged, ep = serve_paged(
    lambda: ShardedServeEngine(lm, params, mesh=mesh, paged=True, **kw))
assert paged == single, (single, paged)
cs = ep.compiled_cache_sizes()
assert cs in ({"prefill": 1, "decode": 1},
              {"prefill": -1, "decode": -1}), cs
assert ep.page_manager.groups == 2  # one sub-pool per data shard
st = ep.throughput_stats()
assert st["prefix_hit_pages"] >= 1, st  # shared page reused on-shard
print(f"OKVARIANT paged {ep.tp_plan.shard_attn:d}"
      f"{ep.tp_plan.shard_kv:d}{ep.tp_plan.shard_ffn:d}")
# observability on: the same paged serve with the tracer + metrics
# attached must produce byte-identical tokens and still zero recompiles
# (obs is host-side only; device work is untouched)
import repro.obs as obs_mod
bundle = obs_mod.enable(obs_mod.Obs.create())
single_o, _ = serve_paged(lambda: ServeEngine(lm, params, **kw))
paged_o, eo = serve_paged(
    lambda: ShardedServeEngine(lm, params, mesh=mesh, paged=True, **kw))
obs_mod.disable()
assert single_o == single and paged_o == single, (single, paged_o)
cs = eo.compiled_cache_sizes()
assert cs in ({"prefill": 1, "decode": 1},
              {"prefill": -1, "decode": -1}), cs
snap = bundle.metrics.snapshot()
assert snap["counters"].get("sched_admissions_total", 0) >= 10, snap
assert any(k.startswith("page_allocs_total")
           for k in snap["counters"]), snap
evs = bundle.tracer.events()
assert any(e["ph"] == "b" for e in evs), "no request spans traced"
assert any(e["name"] == "engine.decode" for e in evs), "no decode spans"
print("OBSVARIANT ok")
print("RESULT ok")
"""


@pytest.fixture(scope="module")
def subproc():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_sharded_engine_token_parity(subproc):
    variants = [l.split()[1] for l in subproc.splitlines()
                if l.startswith("OKVARIANT")]
    assert variants == ["float24", "float24-chunked", "int8", "mixednm",
                        "kvsharded", "kernel24", "blocksparse", "paged"]
    assert "RESULT ok" in subproc


def test_kernel_variant_decodes_on_pallas(subproc):
    """The use_kernel=True variant must have routed its decode-family
    GEMMs to the Pallas impls in both engines (asserted in-subprocess
    through the per-family dispatch counters; the marker line carries
    the dispatch count)."""
    assert "KERNELDECODE ok" in subproc


def test_blocksparse_variant_routes_decode_family(subproc):
    """The mask-bearing variant's sharded serve must have routed its
    attention through the bs_attention_decode family (mask-aware decode
    path) — asserted in-subprocess via the dispatch counters."""
    assert "BSDECODE ok" in subproc


def test_obs_on_sharded_parity_and_zero_recompiles(subproc):
    """With observability enabled, the sharded paged serve must emit the
    same token streams as obs-off, keep the compiled caches at one entry
    each, and actually record request spans + metrics (asserted
    in-subprocess)."""
    assert "OBSVARIANT ok" in subproc


def test_kv_sharded_variant_actually_sharded_kv(subproc):
    """The kvsharded variant must have sharded attention AND kv heads;
    the stock reduced config (kv_heads=1) must keep KV replicated."""
    flags = {l.split()[1]: l.split()[2] for l in subproc.splitlines()
             if l.startswith("OKVARIANT")}
    assert flags["float24"] == "101"   # attn + ffn sharded, kv replicated
    assert flags["kvsharded"] == "111"  # kv cache sharded on heads too


# ---------------------------------------------------------------------------
# spec-rule unit tests (single device, no lowering)
# ---------------------------------------------------------------------------


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: D106
        shape = (2, 4)
        size = 8


def test_serve_tp_plan_rejects_moe_and_state_mixers():
    with pytest.raises(NotImplementedError, match="MoE"):
        serve_tp_plan(get_reduced("deepseek-v2-lite-16b"), 4)
    with pytest.raises(NotImplementedError, match="attention"):
        serve_tp_plan(get_reduced("rwkv6-3b"), 4)


def test_serve_tp_plan_head_aware_fallbacks():
    cfg = get_reduced("yi-9b")  # q=8, kv=1, d_ff=256
    plan = serve_tp_plan(cfg, 4)
    assert plan.shard_attn and plan.shard_ffn and not plan.shard_kv
    assert plan.reduce_tags == frozenset({"attn_out", "ffn_down"})
    # tp that does not divide q_heads: attention stays replicated (no
    # psum tag), ffn still shards
    plan3 = serve_tp_plan(cfg, 3)
    assert not plan3.shard_attn and "attn_out" not in plan3.reduce_tags
    # tp=1 never shards
    p1 = serve_tp_plan(cfg, 1)
    assert not (p1.shard_attn or p1.shard_kv or p1.shard_ffn)


def test_serve_tp_plan_gqa_replicated_kv_needs_mqa():
    """q-sharding over replicated KV is only sound for kv_heads == 1: a
    shard's contiguous q-head slice lies in one *global* KV group, but
    the local (hkv, g) reshape would pair it round-robin across all KV
    heads. kv_heads=2 at tp=4 must therefore fall back to replicated
    attention, not serve wrong tokens."""
    cfg = get_reduced("yi-9b")
    blk, rep = cfg.plan[0]
    cfg2 = dataclasses.replace(cfg, plan=((dataclasses.replace(
        blk, mixer=dataclasses.replace(blk.mixer, kv_heads=2)), rep),))
    plan = serve_tp_plan(cfg2, 4)
    assert not plan.shard_attn and not plan.shard_kv
    assert "attn_out" not in plan.reduce_tags
    # ...while kv_heads divisible by tp shards both, grouped locally
    cfg4 = dataclasses.replace(cfg, plan=((dataclasses.replace(
        blk, mixer=dataclasses.replace(blk.mixer, kv_heads=4)), rep),))
    plan4 = serve_tp_plan(cfg4, 4)
    assert plan4.shard_attn and plan4.shard_kv


def test_serve_param_pspecs_co_shard_compressed_pair():
    """vals and idx of every TP-sharded NMWeight carry the same spec
    (the compressed pair moves together), and row-parallel splits land
    on N:M group boundaries."""
    from repro.core.nmweight import NMWeight

    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    plan = serve_tp_plan(cfg, 4)
    specs = serve_param_pspecs(params, _FakeMesh, plan)
    seen_col = seen_row = 0
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, NMWeight))[0]
    for path, leaf in flat:
        if not isinstance(leaf, NMWeight):
            continue
        assert tuple(leaf.vals) == tuple(leaf.idx), path  # co-sharded
        # scan-stacked weights carry a leading None axis — compare the
        # logical (in, out) tail
        tail = tuple(leaf.vals)[-2:]
        if tail == (None, "model"):
            seen_col += 1
        if tail == ("model", None):
            seen_row += 1
    assert seen_col and seen_row  # both parallelism flavours present


def test_serve_param_pspecs_rejects_misaligned_row_split():
    """A row-parallel compressed weight whose per-shard slice would cut
    an N:M group in half must be refused loudly."""
    cfg = get_reduced("yi-9b")
    lm = LM(cfg)
    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    plan = dataclasses.replace(serve_tp_plan(cfg, 4), tp=64)
    with pytest.raises(ValueError, match="group boundaries"):
        serve_param_pspecs(params, _FakeMesh, plan)