"""MoE: routing semantics, capacity behavior, EP-shaped dispatch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import capacity, moe_apply, moe_init


def _setup(e=4, k=2, d=32, dexp=64, shared=1, cf=1.25):
    cfg = MoEConfig(n_experts=e, top_k=k, d_expert=dexp, n_shared=shared,
                    capacity_factor=cf)
    params = moe_init(jax.random.PRNGKey(0), d, cfg)
    return cfg, params


def test_output_shape_and_finite():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and np.isfinite(float(aux))


def test_per_token_determinism_across_batching():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32))
    y_full, _ = moe_apply(params, x, cfg)
    y_a, _ = moe_apply(params, x[:, :16], cfg)
    y_b, _ = moe_apply(params, x[:, 16:], cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, :16]), np.asarray(y_a),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y_b),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_overflow_tokens():
    """With capacity_factor so small that capacity < assignments, output
    must still be finite and some tokens get zero routed contribution."""
    cfg, params = _setup(shared=0, cf=0.01)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32))
    y, _ = moe_apply(params, x, cfg)
    assert bool(jnp.isfinite(y).all())
    # capacity is floor-bounded at 8; with 64*2 assignments over 4 experts
    # (ideal 32/expert) imbalance means some drops -> some zero rows likely
    assert capacity(64, cfg) == 8


def test_capacity_formula():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=1.0)
    assert capacity(256, cfg) == 64  # 256*2/8
    assert capacity(4, cfg) == 8  # floor


def test_shared_expert_always_contributes():
    cfg_s, params_s = _setup(shared=1)
    cfg_n = MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=0)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 32))
    y_s, _ = moe_apply(params_s, x, cfg_s)
    params_ns = dict(params_s)
    params_ns.pop("shared")
    y_n, _ = moe_apply(params_ns, x, cfg_n)
    assert float(jnp.abs(y_s - y_n).max()) > 1e-6


def test_aux_loss_penalizes_imbalance():
    cfg, params = _setup(shared=0)
    # all-positive activations + a router that projects them onto expert 0
    # -> every token routes to expert 0 with probability ~1
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (1, 128, 32)))
    _, aux_rand = moe_apply(params, x, cfg)
    params_biased = dict(params)
    w = np.zeros((32, 4), np.float32)
    w[:, 0] = 1.0
    params_biased["router"] = {"w": jnp.asarray(w)}
    _, aux_skew = moe_apply(params_biased, x, cfg)
    # fully-skewed top-2-of-4 routing hits the max: E*f0*P0 = 4*0.5*1 = 2
    # (x coef 1e-3); random routing must sit strictly below it
    assert float(aux_skew) > 0.0019
    assert float(aux_rand) < float(aux_skew)
