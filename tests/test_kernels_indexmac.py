"""Shape/dtype sweeps for the indexmac Pallas kernel vs the jnp oracle.

The kernel body executes in interpret mode on CPU (per task spec) — the
same body is what Mosaic compiles on a real TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels.indexmac.kernel import nm_spmm_pallas
from repro.kernels.indexmac.ops import nm_matmul_positional as nm_matmul
from repro.kernels.indexmac.ref import nm_matmul_ref

CFGS = [NMConfig(1, 2), NMConfig(1, 4), NMConfig(2, 4)]


def _mk(cfg, K, N, M, dtype, seed=0):
    w = random_nm_matrix(jax.random.PRNGKey(seed), (K, N), cfg, axis=0).astype(dtype)
    vals, idx = compress_nm(w, cfg, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)).astype(dtype)
    return x, w, vals, idx


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag)
@pytest.mark.parametrize(
    "shape",
    [(128, 128, 64), (256, 128, 8), (512, 384, 128), (1024, 256, 32)],
    ids=lambda s: "K%dN%dM%d" % s,
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_kernel_matches_oracle(cfg, shape, dtype):
    K, N, M = shape
    x, w, vals, idx = _mk(cfg, K, N, M, dtype)
    y_ref = nm_matmul_ref(x, vals, idx, cfg, out_dtype=jnp.float32)
    y_k = nm_spmm_pallas(
        x, vals, idx, cfg=cfg,
        block_m=min(64, M), block_n=min(128, N), block_k=min(256, K),
        out_dtype=jnp.float32, interpret=True,
    )
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), rtol=tol, atol=tol * 10
    )


@pytest.mark.parametrize("blocks", [(64, 128, 128), (128, 128, 512), (64, 384, 256)])
def test_kernel_block_shape_sweep(blocks):
    cfg = NMConfig(2, 4)
    K, N, M = 512, 384, 128
    x, w, vals, idx = _mk(cfg, K, N, M, jnp.float32)
    bm, bn, bk = blocks
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    y_k = nm_spmm_pallas(
        x, vals, idx, cfg=cfg, block_m=bm, block_n=bn, block_k=bk, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=1e-4, atol=1e-3)


def test_kernel_multi_k_accumulation():
    """k-grid > 1 exercises the VMEM scratch accumulation path."""
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 2048, 128, 16, jnp.float32)
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    y_k = nm_spmm_pallas(
        x, vals, idx, cfg=cfg, block_m=16, block_n=128, block_k=256, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=1e-4, atol=1e-3)


def test_op_dispatch_and_grad():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 64, jnp.float32)

    y = nm_matmul(x, vals, idx, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-3)

    g_x, g_v = jax.grad(lambda x, v: jnp.sum(nm_matmul(x, v, idx, cfg) ** 2),
                        argnums=(0, 1))(x, vals)
    g_dx, g_dw = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_dx), rtol=1e-4, atol=1e-3)
    grow = (np.arange(vals.shape[0]) // cfg.n)[:, None] * cfg.m + np.asarray(
        idx, dtype=np.int64
    )
    expect = np.take_along_axis(np.asarray(g_dw), grow, axis=0)
    np.testing.assert_allclose(np.asarray(g_v), expect, rtol=1e-4, atol=1e-3)


def test_op_falls_back_on_odd_shapes():
    """Non-tileable shapes must still produce correct results via the ref."""
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 36, 20, 5, jnp.float32)
    y = nm_matmul(x, vals, idx, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-3)


def test_kernel_rejects_bad_shapes():
    cfg = NMConfig(2, 4)
    x, w, vals, idx = _mk(cfg, 256, 128, 64, jnp.float32)
    with pytest.raises(ValueError):
        nm_spmm_pallas(x, vals[:-2], idx[:-2], cfg=cfg, interpret=True)
    with pytest.raises(ValueError):
        nm_spmm_pallas(x, vals, idx, cfg=cfg, block_k=100, interpret=True)
