"""Sparse convolution subsystem: im2col lowering on the indexmac path.

Parity is against ``lax.conv_general_dilated`` (NHWC/HWIO) — the dense
reference the paper's §IV mapping lowers from: float within 1e-4, int8
bit-exact on the integer lattice. Also: odd spatial shapes through the
shape-padding Pallas path, the SparseConv2D VJP vs the dense conv VJP,
the config-derived GEMM tables vs the published block structure, and the
SparseCNN forward models (float + quantized).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import (
    DEFAULT_CNN_SPARSITY,
    get_cnn_config,
    get_cnn_reduced,
)
from repro.configs.base import ConvSpec, SparsityConfig
from repro.core.nmweight import KernelPolicy, NMWeight
from repro.core.sparsity import NMConfig
from repro.kernels import registry
from repro.models.conv import (
    SparseCNN,
    SparseConv2D,
    cnn_layer_gemms,
    cnn_layer_specs,
    im2col,
)
from repro.quant.qnmweight import QNMWeight

SP = dataclasses.replace(DEFAULT_CNN_SPARSITY, use_kernel=False)


def _dense_conv(x, w2d, spec: ConvSpec):
    w_hwio = w2d.reshape(spec.kh, spec.kw, spec.c_in, spec.c_out)
    return jax.lax.conv_general_dilated(
        x, w_hwio, (spec.stride, spec.stride), spec.padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# float parity vs lax.conv_general_dilated
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kh,kw,stride,pad,cin,cout,h,w",
    [
        (3, 3, 1, "SAME", 8, 16, 10, 10),
        (3, 3, 2, "SAME", 8, 16, 11, 13),   # odd spatial, stride
        (1, 1, 2, "SAME", 8, 12, 7, 9),
        (7, 7, 2, "SAME", 4, 8, 23, 23),    # resnet-stem-like window
        (3, 3, 2, "VALID", 8, 16, 11, 13),
        (5, 3, 1, "VALID", 4, 8, 9, 12),    # non-square window
    ],
)
def test_sparse_conv_matches_dense_reference(kh, kw, stride, pad, cin,
                                             cout, h, w):
    spec = ConvSpec("c", cin, cout, kh, kw, stride, padding=pad)
    conv = SparseConv2D(spec)
    params = conv.init(jax.random.PRNGKey(0), sp=SP)
    assert isinstance(params, NMWeight)  # K divisible by 4 in all cases
    x = jax.random.normal(jax.random.PRNGKey(1), (2, h, w, cin))
    y = conv.apply(params, x, compute_dtype=jnp.float32)
    y_ref = _dense_conv(x, api.densify(params), spec)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_im2col_layout_matches_hwio_reshape():
    """patches @ w_hwio.reshape(K, C_out) IS the conv — the layout
    contract every sparse weight in this subsystem relies on."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 9, 11, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
    patches = im2col(x, 3, 3, stride=2, padding="SAME")
    y = jnp.einsum("bhwk,kn->bhwn", patches, w.reshape(-1, 8))
    y_ref = jax.lax.conv_general_dilated(
        x, w, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_api_sparsify_conv_round_trip():
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 8, 16))
    sw = api.sparsify_conv(w, NMConfig(2, 4))
    assert isinstance(sw, NMWeight) and sw.vals.shape[1] == 16
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 7, 8))
    y = api.conv2d(x, sw, kh=3, kw=3, stride=1, compute_dtype=jnp.float32)
    y_ref = _dense_conv(x, api.densify(sw), ConvSpec("c", 8, 16, 3, 3, 1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        api.sparsify_conv(jnp.zeros((8, 16)), NMConfig(2, 4))


# ---------------------------------------------------------------------------
# int8 lattice: bit-exact vs the dense conv on the dequantized weight
# ---------------------------------------------------------------------------


def _int_lattice_conv(spec: ConvSpec, sp=SP, seed=0):
    """Integer activations/values with power-of-two scales: every float
    op on both the kernel path and the dense-conv reference is exact, so
    the comparison is bitwise (same idiom as test_quant)."""
    rng = np.random.default_rng(seed)
    conv = SparseConv2D(spec)
    params = conv.init(jax.random.PRNGKey(seed), sp=sp)
    qvals = rng.integers(-127, 128, size=params.vals.shape).astype(np.int8)
    qvals = np.where(np.asarray(params.vals) == 0, 0, qvals).astype(np.int8)
    scales = 2.0 ** rng.integers(-6, 1, size=(spec.c_out,))
    qw = QNMWeight(
        vals=jnp.asarray(qvals), idx=params.idx,
        scales=jnp.asarray(scales, dtype=jnp.float32), nm=params.nm,
        kernel_policy=KernelPolicy("force"))
    x = rng.integers(-8, 9, size=(2, 9, 9, spec.c_in)).astype(np.float32)
    return conv, qw, jnp.asarray(x)


@pytest.mark.parametrize("pattern", [(1, 4), (2, 4)],
                         ids=lambda p: "%d:%d" % p)
def test_int8_conv_bit_exact_on_lattice(pattern):
    sp = dataclasses.replace(SP, nm=NMConfig(*pattern))
    spec = ConvSpec("c", 8, 16, 3, 3, 1)
    conv, qw, x = _int_lattice_conv(spec, sp=sp)
    assert qw.nm == NMConfig(*pattern)
    registry.clear_history()
    y = conv.apply(qw, x, compute_dtype=jnp.float32)
    rec = registry.last_dispatch("nm_matmul_q")
    assert rec is not None and rec.impl == "pallas_padded_q", rec
    y_ref = _dense_conv(x, qw.to_dense(), spec)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# odd spatial shapes through the shape-padding Pallas path
# ---------------------------------------------------------------------------


def test_odd_spatial_shape_hits_padded_pallas_kernel():
    """7x9 input, stride 2 — a GEMM no tile divides; the force policy
    must route it through pallas_padded, and the result must still match
    the dense conv exactly (zero-padding is exact)."""
    spec = ConvSpec("c", 8, 20, 3, 3, 2)  # C_out=20: pads N too
    conv = SparseConv2D(spec)
    params = conv.init(jax.random.PRNGKey(0), sp=SP)
    params = dataclasses.replace(params, kernel_policy=KernelPolicy("force"))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 9, 8))
    registry.clear_history()
    y = conv.apply(params, x, compute_dtype=jnp.float32)
    rec = registry.last_dispatch("nm_matmul")
    assert rec is not None and rec.impl == "pallas_padded", rec
    assert rec.padded is not None and rec.padded != rec.shape
    y_ref = _dense_conv(x, api.densify(params), spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gradients: SparseConv2D VJP vs the dense conv VJP
# ---------------------------------------------------------------------------


def test_conv_grad_matches_dense_vjp():
    spec = ConvSpec("c", 8, 16, 3, 3, 2)
    conv = SparseConv2D(spec)
    params = conv.init(jax.random.PRNGKey(0), sp=SP)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 9, 8))

    def loss_sparse(vals, x):
        p = dataclasses.replace(params, vals=vals)
        return jnp.sum(conv.apply(p, x, compute_dtype=jnp.float32) ** 2)

    def loss_dense(w2d, x):
        return jnp.sum(_dense_conv(x, w2d, spec) ** 2)

    g_vals, g_x = jax.grad(loss_sparse, argnums=(0, 1))(params.vals, x)
    g_w2d, g_x_ref = jax.grad(loss_dense, argnums=(0, 1))(
        api.densify(params), x)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_x_ref),
                               rtol=1e-4, atol=1e-4)
    # dense dW gathered at the kept positions == compressed dvals
    kc = params.vals.shape[0]
    block_id = jnp.arange(kc, dtype=jnp.int32) // params.nm.n
    grow = block_id[:, None] * params.nm.m + params.idx.astype(jnp.int32)
    g_vals_ref = jnp.take_along_axis(g_w2d, grow, axis=0)
    np.testing.assert_allclose(np.asarray(g_vals), np.asarray(g_vals_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# config-derived GEMM tables vs the published block structure
# ---------------------------------------------------------------------------


def test_resnet50_gemm_table_matches_published_structure():
    table = dict()
    for name, m, k, n in cnn_layer_gemms(get_cnn_config("resnet50")):
        table[name] = (m, k, n)
    assert len(table) == 53
    assert table["conv1"] == (64, 3 * 49, 112 * 112)
    assert table["s2b1_1x1a"] == (64, 64, 56 * 56)
    assert table["s2b1_3x3"] == (64, 64 * 9, 56 * 56)
    assert table["s3b1_proj"] == (512, 256, 28 * 28)
    assert table["s4b6_3x3"] == (256, 256 * 9, 14 * 14)
    assert table["s5b3_1x1b"] == (2048, 512, 7 * 7)


def test_densenet121_gemm_table_matches_published_structure():
    table = dict()
    for name, m, k, n in cnn_layer_gemms(get_cnn_config("densenet121")):
        table[name] = (m, k, n)
    assert len(table) == 120
    assert table["conv1"] == (64, 3 * 49, 112 * 112)
    assert table["d1l1_1x1"] == (128, 64, 56 * 56)
    assert table["t1_1x1"] == (128, 64 + 6 * 32, 56 * 56)
    assert table["d4l16_3x3"] == (32, 128 * 9, 7 * 7)


def test_conv_cost_model_accounting():
    """tpu_conv_cost: the fused-im2col bound saves exactly the activation
    re-read factor, is a no-op for 1x1 convs, and the int8 family
    streams fewer weight bytes."""
    from repro.core.cost_model import conv_gemm_dims, tpu_conv_cost

    nm = NMConfig(2, 4)
    assert conv_gemm_dims(64, 64, 3, 3, 56, 56) == (64, 576, 3136)
    explicit = tpu_conv_cost(64, 64, 3, 3, 56, 56, nm)
    fused = tpu_conv_cost(64, 64, 3, 3, 56, 56, nm, fused_im2col=True)
    assert fused.mxu_flops == explicit.mxu_flops
    assert explicit.hbm_bytes - fused.hbm_bytes == 3136 * (576 - 64) * 2
    one = tpu_conv_cost(64, 256, 1, 1, 56, 56, nm)
    one_f = tpu_conv_cost(64, 256, 1, 1, 56, 56, nm, fused_im2col=True)
    assert one.hbm_bytes == one_f.hbm_bytes
    q = tpu_conv_cost(64, 64, 3, 3, 56, 56, nm, quantized=True)
    assert q.hbm_bytes < explicit.hbm_bytes


def test_layer_specs_gemm_mapping_invariant():
    """Every derived layer satisfies the paper's mapping M=C_out,
    K=C_in*kh*kw, N=H_out*W_out."""
    for cnn in ("resnet50", "densenet121"):
        for layer in cnn_layer_specs(get_cnn_config(cnn)):
            name, m, k, n = layer.gemm
            s = layer.spec
            assert m == s.c_out
            assert k == s.c_in * s.kh * s.kw
            assert n == layer.h_out * layer.w_out


# ---------------------------------------------------------------------------
# SparseCNN forward models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cnn", ["resnet50", "densenet121"])
def test_sparse_cnn_forward_float_and_int8(cnn):
    cfg = get_cnn_reduced(cnn)
    model = SparseCNN(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_sparse = sum(api.is_sparse(l) for l in jax.tree.leaves(
        params, is_leaf=api.is_sparse))
    assert n_sparse > 0  # the backbone really carries NMWeight convs
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.input_hw, cfg.input_hw, 3))
    logits = model.apply(params, x, compute_dtype=jnp.float32)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # int8: quantize_tree swaps NMWeight -> QNMWeight; apply dispatches
    # on the type unchanged and stays close to the float forward.
    qlogits = model.apply(api.quantize_tree(params), x,
                          compute_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(logits - qlogits))) < 0.5


def test_sparse_cnn_respects_sparsity_targets():
    """The stem (K=27, not 4-divisible) falls back to dense; conv/proj
    families are compressed; the head stays dense."""
    cfg = get_cnn_reduced("resnet50")
    params = SparseCNN(cfg).init(jax.random.PRNGKey(0))
    assert isinstance(params["convs"]["conv1"], dict)  # stem dense
    assert isinstance(params["convs"]["s2b1_1x1a"], NMWeight)
    assert isinstance(params["convs"]["s3b1_proj"], NMWeight)
    assert isinstance(params["head"], dict)


def test_sparse_cnn_dense_config_has_no_sparse_nodes():
    cfg = get_cnn_reduced("resnet50", sparse=False)
    params = SparseCNN(cfg).init(jax.random.PRNGKey(0))
    assert not any(api.is_sparse(l) for l in jax.tree.leaves(
        params, is_leaf=api.is_sparse))


def test_sparse_cnn_mixed_nm_override():
    """Per-target overrides work for conv families too (mixed per-layer
    sparsity, e.g. 1:4 projections next to 2:4 convs)."""
    sp = SparsityConfig(targets=("conv", "proj"),
                        nm_overrides=(("proj", NMConfig(1, 4)),))
    cfg = dataclasses.replace(get_cnn_reduced("resnet50"), sparsity=sp)
    params = SparseCNN(cfg).init(jax.random.PRNGKey(0))
    assert params["convs"]["s2b1_1x1a"].nm == NMConfig(2, 4)
    assert params["convs"]["s3b1_proj"].nm == NMConfig(1, 4)
