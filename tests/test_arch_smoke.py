"""Per-architecture smoke tests: reduced config of the same family runs a
forward + train step + a prefill/decode step on CPU; asserts output shapes
and no NaNs. Full configs are touched only via eval_shape param counting
(no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced, runnable_shapes
from repro.models.cache import CacheView
from repro.models.transformer import LM, count_params

BATCH, SEQ = 2, 16


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab_size)
    b = {"tokens": tokens, "labels": tokens}
    if cfg.encoder_plan is not None:
        b["enc_input"] = jax.random.normal(
            k2, (BATCH, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, _, _ = lm.forward(params, batch["tokens"],
                              enc_input=batch.get("enc_input"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"

    # one SGD step via grad of loss — exercises the backward of every
    # mixer + the sparse custom_vjp
    def loss_fn(p):
        loss, _ = lm.loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(params)
    assert np.isfinite(float(loss))
    flat = [g for g in jax.tree.leaves(grads)
            if jnp.issubdtype(g.dtype, jnp.floating)]
    assert flat and all(bool(jnp.isfinite(g).all()) for g in flat), \
        "non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_step(arch):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    caches = lm.init_cache(BATCH, 2 * SEQ)
    logits, caches, _ = lm.forward(
        params, batch["tokens"], view=CacheView.prefill(), caches=caches,
        enc_input=batch.get("enc_input"))
    assert not bool(jnp.isnan(logits).any())
    nxt = jnp.argmax(logits[:, -1:], axis=-1)
    logits_d, caches, _ = lm.forward(
        params, nxt, view=CacheView.decode(jnp.int32(SEQ)), caches=caches)
    assert logits_d.shape == (BATCH, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits_d).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_sparse_and_dense_variants_init(arch):
    """Both sparse and dense reduced variants initialize and run."""
    for sparse in (True, False):
        cfg = get_reduced(arch, sparse=sparse)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        tokens = jnp.zeros((1, 8), jnp.int32)
        enc = (jnp.zeros((1, cfg.encoder_seq, cfg.d_model))
               if cfg.encoder_plan is not None else None)
        logits, _, _ = lm.forward(params, tokens, enc_input=enc)
        assert not bool(jnp.isnan(logits).any())


# expected dense-equivalent parameter counts (±20%) from the public specs
EXPECTED_PARAMS = {
    "chameleon-34b": 34e9,
    "codeqwen1.5-7b": 7e9,
    "internlm2-20b": 20e9,
    "yi-9b": 9e9,
    "gemma3-27b": 27e9,
    "rwkv6-3b": 3e9,
    "whisper-medium": 0.76e9,
    "deepseek-v2-236b": 236e9,
    "deepseek-v2-lite-16b": 16e9,
    "jamba-v0.1-52b": 52e9,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """eval_shape (no allocation) param count of the DENSE full config
    matches the published size."""
    cfg = get_config(arch, sparse=False)
    n = count_params(cfg)
    expect = EXPECTED_PARAMS[arch]
    assert 0.75 * expect < n < 1.35 * expect, (
        f"{arch}: {n/1e9:.2f}B params vs expected {expect/1e9:.0f}B")


@pytest.mark.parametrize("arch", ARCHS)
def test_sparse_config_shrinks_params(arch):
    dense = count_params(get_config(arch, sparse=False))
    sparse = count_params(get_config(arch, sparse=True))
    assert sparse < dense  # 2:4 halves targeted weight values


def test_shape_skips_documented():
    for arch in ARCHS:
        shapes = runnable_shapes(arch)
        assert "train_4k" in shapes and "decode_32k" in shapes
        if arch in ("rwkv6-3b", "jamba-v0.1-52b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
