"""Root conftest: keep the whole suite collectible on minimal images.

`hypothesis` is a real dev dependency (pyproject.toml) and CI installs
it; when it's missing (stripped-down containers) a deterministic
fallback implementation takes its place so the three property-test
modules collect and run instead of erroring at import.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
