import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_MIXED_PRECISION_DOTS"] = "1"  # TPU-form HLO (lower-only)

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# the production meshes and record memory/cost/collective analysis.
#
# The two lines above MUST stay the first statements in this module: jax
# locks the device count on first init, and the dry-run needs 512 host
# placeholder devices. (Smoke tests / benches never import this module.)
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
#   ... --dense --sharding tp_only --out experiments/dryrun

import argparse
import json
import time
import traceback

import jax  # noqa: E402  (after XLA_FLAGS on purpose)

from repro.configs import ARCHS, SHAPES, runnable_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import lower_cell, make_cell
from repro.roofline.analysis import analyze


def run_cell(arch: str, shape: str, mesh_kind: str, *, sparse: bool,
             sharding_mode: str, out_dir: str | None,
             microbatches: int = 8, attn_chunk=None, tag: str = "",
             remat: str = "dots", cache_dtype: str = "bf16") -> dict:
    import jax.numpy as jnp

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    cell = make_cell(arch, shape, mesh, sparse=sparse,
                     sharding_mode=sharding_mode, microbatches=microbatches,
                     attn_chunk=attn_chunk, remat=remat,
                     cache_dtype={"bf16": jnp.bfloat16,
                                  "fp8": jnp.float8_e4m3fn}[cache_dtype])
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    rep = analyze(cell.name + tag, compiled, cell.chips, cell.model_flops)
    result = rep.to_json()
    result.update(arch=arch, shape=shape, mesh=mesh_kind,
                  sparse=sparse, sharding=sharding_mode,
                  t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1))
    mem = result.get("memory", {})
    print(f"[ok] {cell.name}{tag}: "
          f"args {mem.get('argument_size_in_bytes', 0)/2**30:.2f} GiB/dev, "
          f"temp {mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB/dev, "
          f"t_comp {rep.t_compute*1e3:.1f} ms, t_mem {rep.t_memory*1e3:.1f} ms, "
          f"t_coll {rep.t_collective*1e3:.1f} ms -> {rep.bottleneck} "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape}_{mesh_kind}_" \
                f"{'sparse' if sparse else 'dense'}_{sharding_mode}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--sharding", default="fsdp",
                    choices=["fsdp", "tp_only"])
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCHS for s in runnable_shapes(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            fname = f"{arch}_{shape}_{mk}_" \
                    f"{'dense' if args.dense else 'sparse'}_" \
                    f"{args.sharding}{args.tag}.json"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, fname)):
                print(f"[skip] {arch}|{shape}|{mk} (exists)", flush=True)
                continue
            try:
                run_cell(arch, shape, mk, sparse=not args.dense,
                         sharding_mode=args.sharding, out_dir=args.out,
                         microbatches=args.microbatches,
                         attn_chunk=args.attn_chunk, tag=args.tag,
                         remat=args.remat)
            except Exception as e:  # noqa: BLE001 — report all cell failures
                failures.append((arch, shape, mk, repr(e)))
                print(f"[FAIL] {arch}|{shape}|{mk}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}|{s}|{m}" for a, s, m, _ in failures))
    print("all dry-run cells compiled OK")


if __name__ == "__main__":
    main()
