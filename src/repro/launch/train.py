"""End-to-end training driver (CPU-runnable at reduced scale, mesh-ready).

Example (the (b) deliverable end-to-end run):
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 300 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models.transformer import LM
from repro.optim.optimizer import AdamWConfig
from repro.training.checkpoint import Checkpointer
from repro.training.train_loop import TrainConfig, make_train_step
from repro.optim.optimizer import adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_reduced if args.reduced else get_config)(
        args.arch, sparse=not args.dense)
    lm = LM(cfg)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches, remat=args.remat)
    step_fn = jax.jit(make_train_step(lm, tcfg))

    params = lm.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    pipe = DataPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        if cfg.encoder_plan is not None:
            batch["enc_input"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/ (step+1):.2f}s/step)", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"data": pipe.state()}, async_=True)
    if ckpt:
        ckpt.wait()
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not drop"


if __name__ == "__main__":
    main()
