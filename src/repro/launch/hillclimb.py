import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
os.environ.setdefault("REPRO_MIXED_PRECISION_DOTS", "1")

# Perf hillclimbing driver: run named variants of a dry-run cell and diff
# the roofline terms (EXPERIMENTS.md §Perf). Each variant is a (tag,
# kwargs) pair passed to run_cell; results append to a JSONL log.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb --cell dsv2-train
#   PYTHONPATH=src python -m repro.launch.hillclimb --cell yi-decode

import argparse
import json

from repro.launch.dryrun import run_cell

# variant grids per hillclimbed cell -----------------------------------------

CELLS: dict[str, dict] = {
    # most representative of the paper's technique at scale (sparse expert
    # weights dominate bytes) + the memory-bound train cell
    "dsv2-train": {
        "base": dict(arch="deepseek-v2-236b", shape="train_4k",
                     mesh_kind="single", sparse=True, sharding_mode="fsdp",
                     remat="full", microbatches=16),
        "variants": [
            ("paper_dense_baseline", dict(sparse=False)),
            ("remat_dots", dict(remat="dots")),
            ("mb8", dict(microbatches=8)),
            ("chunk2048", dict(attn_chunk=2048)),
            ("gather_compressed", dict(env={"REPRO_GATHER_COMPRESSED": "1"})),
        ],
    },
    # memory-bound decode: the paper technique's direct win (weight bytes)
    "yi-decode": {
        "base": dict(arch="yi-9b", shape="decode_32k", mesh_kind="single",
                     sparse=True, sharding_mode="fsdp"),
        "variants": [
            ("paper_dense_baseline", dict(sparse=False)),
            ("tp_only", dict(sharding_mode="tp_only")),
            ("cache_fp8", dict(cache_dtype="fp8")),
            ("cache_fp8_tp_only", dict(cache_dtype="fp8",
                                       sharding_mode="tp_only")),
        ],
    },
    # worst roofline fraction candidate: collective/memory-heavy prefill
    "gemma3-prefill": {
        "base": dict(arch="gemma3-27b", shape="prefill_32k",
                     mesh_kind="single", sparse=True, sharding_mode="fsdp"),
        "variants": [
            ("paper_dense_baseline", dict(sparse=False)),
            ("chunk1024", dict(attn_chunk=1024)),
            ("chunk2048", dict(attn_chunk=2048)),
            ("tp_only", dict(sharding_mode="tp_only")),
            ("gather_compressed", dict(env={"REPRO_GATHER_COMPRESSED": "1"})),
            ("gather_compressed_chunk2048",
             dict(attn_chunk=2048, env={"REPRO_GATHER_COMPRESSED": "1"})),
        ],
    },
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--only", default=None,
                    help="run a single variant tag (plus base)")
    ap.add_argument("--out", default="experiments/hillclimb")
    ap.add_argument("--skip-base", action="store_true")
    args = ap.parse_args()

    spec = CELLS[args.cell]
    os.makedirs(args.out, exist_ok=True)
    log = os.path.join(args.out, f"{args.cell}.jsonl")

    def record(tag: str, kwargs: dict) -> None:
        base = dict(spec["base"])
        base.update(kwargs)
        env = base.pop("env", {})
        old = {k: os.environ.get(k) for k in env}
        os.environ.update(env)
        try:
            res = run_cell(out_dir=None, tag="_" + tag, **base)
        finally:
            for k, v in old.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
        res["variant"] = tag
        with open(log, "a") as f:
            f.write(json.dumps(res) + "\n")

    if not args.skip_base:
        record("base", {})
    for tag, kw in spec["variants"]:
        if args.only and tag != args.only:
            continue
        record(tag, kw)
    print(f"hillclimb log -> {log}")


if __name__ == "__main__":
    main()
