"""Dry-run cell construction: (arch x shape x mesh) -> jit-able step +
ShapeDtypeStruct inputs + in/out shardings + analytic MODEL_FLOPS.

No allocation happens here: params/opt/caches are eval_shape trees
(weak-type-correct ShapeDtypeStructs); the actual step functions are the
production ones from repro.training / repro.serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import LM, count_params
from repro.optim.optimizer import adamw_init
from repro.parallel.sharding import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
)
from repro.roofline.analysis import model_flops_for
from repro.serving.engine import make_serve_steps
from repro.training.train_loop import TrainConfig, make_train_step


@dataclasses.dataclass
class Cell:
    name: str
    arch: str
    shape: ShapeConfig
    fn: Any  # to be jitted
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    model_flops: float
    chips: int
    cfg: ModelConfig
    donate: tuple = ()  # argnums aliased in place (params/opt/caches)


def _named(mesh: Mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _sds(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def make_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    sparse: bool = True,
    sharding_mode: str = "fsdp",
    microbatches: int = 8,
    remat: str = "dots",
    param_dtype_train=jnp.float32,
    attn_chunk: Optional[int] = None,
    cfg_override: Optional[ModelConfig] = None,
    shape_override: Optional[ShapeConfig] = None,
    cache_dtype=jnp.bfloat16,  # fp8_e4m3 halves KV bytes (EXPERIMENTS P2)
) -> Cell:
    shape = shape_override or SHAPES[shape_name]
    cfg = cfg_override or get_config(arch, sparse=sparse)
    if attn_chunk is not None:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    lm = LM(cfg)
    chips = mesh.devices.size
    n_active = count_params(cfg, active_only=True)
    mflops = model_flops_for(cfg, shape, n_active, count_params(cfg))
    name = f"{arch}|{shape_name}|{'x'.join(map(str, mesh.devices.shape))}" \
           f"|{'sparse' if sparse else 'dense'}"

    b, s = shape.global_batch, shape.seq_len
    tok_sh = NamedSharding(mesh, batch_pspec(b, mesh))
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        params = jax.eval_shape(
            lambda: lm.init(jax.random.PRNGKey(0),
                            param_dtype=param_dtype_train))
        opt = jax.eval_shape(adamw_init, params)
        p_sh = _named(mesh, param_pspecs(params, mesh, sharding_mode))
        o_sh = {"step": repl,
                "m": _named(mesh, param_pspecs(opt["m"], mesh, sharding_mode)),
                "v": _named(mesh, param_pspecs(opt["v"], mesh, sharding_mode))}
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        b_sh = {"tokens": tok_sh, "labels": tok_sh}
        if cfg.encoder_plan is not None:
            batch["enc_input"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            b_sh["enc_input"] = NamedSharding(
                mesh, batch_pspec(b, mesh, rank=3))
        # per-microbatch batch must stay divisible by the DP extent
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        mb = max(1, min(microbatches, b // dp))
        while b % mb or (b // mb) % dp:
            mb -= 1
        tcfg = TrainConfig(microbatches=mb, remat=remat)
        step = make_train_step(lm, tcfg)
        return Cell(name, arch, shape, step, (params, opt, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None), mflops, chips,
                    cfg, donate=(0, 1))

    # serving cells: bf16 params
    params = jax.eval_shape(
        lambda: lm.init(jax.random.PRNGKey(0), param_dtype=jnp.bfloat16))
    p_sh = _named(mesh, param_pspecs(params, mesh, sharding_mode))
    prefill_step, decode_step = make_serve_steps(lm, jit=False)
    caches = jax.eval_shape(
        lambda: lm.init_cache(b, s, dtype=cache_dtype))
    c_sh = _named(mesh, cache_pspecs(
        caches, mesh,
        batch_axes=batch_pspec(b, mesh)[0] or ()))

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        args = [params, tokens, caches]
        in_sh = [p_sh, tok_sh, c_sh]
        if cfg.encoder_plan is not None:
            args.append(jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16))
            in_sh.append(NamedSharding(mesh, batch_pspec(b, mesh, rank=3)))
            fn = prefill_step
        else:
            fn = lambda p, t, c: prefill_step(p, t, c)  # noqa: E731
        return Cell(name, arch, shape, fn, tuple(args), tuple(in_sh),
                    (None, c_sh), mflops, chips, cfg, donate=(2,))

    # decode: one token against a cache of length s
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    clen = jax.ShapeDtypeStruct((), jnp.int32)
    return Cell(name, arch, shape, decode_step,
                (params, token, caches, clen),
                (p_sh, tok_sh, c_sh, repl), (None, c_sh), mflops, chips,
                cfg, donate=(2,))


def lower_cell(cell: Cell, mesh: Optional[Mesh] = None):
    """Lower under an active mesh so in-model shard_hint constraints fire
    (compat.set_mesh exposes the active mesh to the trace on every JAX
    line we support). Donation aliases params/opt (train) and caches
    (serve) in place — without it XLA copies every loop-carried buffer."""
    from repro import compat

    jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=cell.donate)
    if mesh is None:
        return jf.lower(*cell.args)
    with compat.set_mesh(mesh):
        return jf.lower(*cell.args)
