"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_reduced
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantize", choices=["int8"], default=None,
                    help="int8-quantize compressed weights at load "
                         "(per-channel absmax scales)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, slots=args.slots, max_seq=args.max_seq,
                      prefill_len=args.prefill_len,
                      temperature=args.temperature,
                      quantize=args.quantize)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prefill_len).astype(np.int32),
            max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, slots={args.slots})")
    for r in done[:3]:
        print(f"  rid={r.rid} out[:8]={r.out[:8]}")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
