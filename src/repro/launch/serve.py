"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8

Multi-device (tensor-parallel x data-parallel) serving:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --mesh 2x4 --requests 8

(on real accelerators drop the XLA_FLAGS override — the mesh axes map
onto the attached devices; slots must divide the data axis).

Observability: ``--trace-out trace.json`` / ``--metrics-out
metrics.prom`` enable the :mod:`repro.obs` layer for the run (same as
``REPRO_OBS=1``) and export a Perfetto-loadable Chrome trace and a
Prometheus text snapshot on exit. ``--paged`` serves through the paged
KV cache; ``--kernels`` forces the compressed GEMMs through the Pallas
kernel families so the exported metrics include kernel-dispatch and
autotune-cache activity.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.obs as obs_mod
from repro.configs import ARCHS, get_reduced
from repro.models.transformer import LM
from repro.serving.engine import Request, ServeEngine, ShardedServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prompts into chunks of this many tokens "
                         "(bounded TTFT); must divide prefill-len")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantize", choices=["int8"], default=None,
                    help="int8-quantize compressed weights at load "
                         "(per-channel absmax scales)")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve sharded on a (data, model) mesh, e.g. 2x4 "
                         "(slots shard over data, tensor parallel over "
                         "model)")
    ap.add_argument("--strict", action="store_true",
                    help="reject prompts longer than prefill-len instead "
                         "of silently truncating to the tail")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV cache (page pool + "
                         "block tables + prefix cache)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (paged mode; default: the "
                         "prefill chunk)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="total pages in the KV pool (paged mode; "
                         "default: full residency for every slot)")
    ap.add_argument("--kernels", action="store_true",
                    help="route compressed GEMMs through the Pallas "
                         "kernel families (use_kernel=True)")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.JSON",
                    help="enable observability and export a Chrome/"
                         "Perfetto trace here on exit")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS.PROM",
                    help="enable observability and export a Prometheus "
                         "text snapshot here on exit")
    args = ap.parse_args()

    bundle = None
    if args.trace_out or args.metrics_out:
        bundle = obs_mod.enable()

    cfg = get_reduced(args.arch)
    if args.kernels:
        if cfg.sparsity is None:
            raise SystemExit(
                f"--kernels: {args.arch} reduces to a dense config "
                "(no compressed GEMMs to route)")
        cfg = dataclasses.replace(
            cfg, sparsity=dataclasses.replace(
                cfg.sparsity, use_kernel=True))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    kw = dict(slots=args.slots, max_seq=args.max_seq,
              prefill_len=args.prefill_len,
              prefill_chunk=args.prefill_chunk,
              temperature=args.temperature,
              quantize=args.quantize, strict=args.strict,
              paged=args.paged, page_size=args.page_size,
              pool_pages=args.pool_pages)
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh

        data, model = (int(x) for x in args.mesh.lower().split("x"))
        mesh = make_serve_mesh(data, model)
        eng = ShardedServeEngine(lm, params, mesh=mesh, **kw)
        print(f"mesh data={data} model={model}: {eng.tp_plan}")
    else:
        eng = ServeEngine(lm, params, **kw)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prefill_len).astype(np.int32),
            max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    stats = eng.throughput_stats()
    toks = stats["tokens"]
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, slots={args.slots}, "
          f"ttft={stats['ttft_s']*1e3:.0f}ms, "
          f"itl p50={stats['itl_p50_s']*1e3:.0f}ms "
          f"p99={stats['itl_p99_s']*1e3:.0f}ms)")
    for r in done[:3]:
        print(f"  rid={r.rid} out[:8]={r.out[:8]}")
    assert len(done) == args.requests
    assert eng.compiled_cache_sizes() in \
        ({"prefill": 1, "decode": 1}, {"prefill": -1, "decode": -1}), \
        eng.compiled_cache_sizes()
    if bundle is not None:
        if args.trace_out:
            n = bundle.tracer.export_chrome(args.trace_out)
            print(f"wrote {args.trace_out} ({n} events, "
                  f"{bundle.tracer.dropped} dropped)")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(bundle.metrics.to_prometheus())
            print(f"wrote {args.metrics_out}")


if __name__ == "__main__":
    main()
