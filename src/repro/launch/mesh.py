"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's 512 placeholder
devices to be configured before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the local device — smoke tests / examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serve_mesh(data: int = 1, model: int = 1):
    """Serving mesh for ``ShardedServeEngine``: batch slots shard over
    "data", tensor parallelism over "model". Works against real devices
    or a forced host platform (XLA_FLAGS=--xla_force_host_platform_
    device_count=N set before jax initializes)."""
    from repro import compat

    return compat.make_mesh((data, model), ("data", "model"))
