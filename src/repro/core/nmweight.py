"""First-class compressed N:M weight: a typed, registered JAX pytree.

The paper's point is that bounded per-block indices make the compressed
``(values, col_idx)`` pair a first-class operand the hardware can consume
directly; :class:`NMWeight` is the software mirror of that — the pair
travels as two pytree leaves, and the metadata the consumer needs to
interpret them (the :class:`NMConfig`, the compressed axis, and the
kernel dispatch policy) rides along as static treedef aux data. Every
subsystem (model apply, kernel dispatch, sharding, optimizer,
checkpointing, serving autotune) dispatches on the type instead of
sniffing ``{"vals", "idx"}`` dict keys, and nothing threads an
out-of-band ``sp=`` config through apply paths anymore.

Because the metadata is static treedef data, two weights with different
``nm`` hash/compare as different pytree structures — which is exactly
what lets a single model mix sparsity ratios per layer (2:4 ffn next to
1:4 experts) without a global config.

:class:`MaskedNMWeight` is the dense-storage sibling used by the paper's
prune->fine-tune training flow: the weight stays dense, the top-N:M mask
is re-derived every forward (SR-STE style straight-through), and the
``nm`` pattern again travels with the weight.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax

from repro import compat
from repro.core.sparsity import (
    NMConfig,
    apply_mask,
    decompress_nm,
    prune_mask_nm,
)

__all__ = [
    "KernelPolicy",
    "NMWeight",
    "MaskedNMWeight",
    "is_weight_node",
    "register_weight_type",
]

KernelMode = Literal["off", "auto", "force"]
Backend = Literal["auto", "tpu", "gpu"]


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """How a compressed weight's matmuls pick an implementation.

    mode:
      off   — always the XLA reference (dry-run friendly; the default of
              ``SparsityConfig.use_kernel=False``).
      auto  — Pallas kernel when the shape normalizes within the padding
              waste limit, reference otherwise.
      force — Pallas kernel whenever the shape normalizes at all; the
              padding waste limit is ignored (benchmarking / pinning).
    block: optional ``(block_m, block_n, block_k)`` override; ``None``
      consults the autotune cache and falls back to the default triple.
    decode_block: same, for the skinny-M decode kernel family (its
      autotune cache keys are separate, so its override is too).
    backend: which kernel *family* serves this weight's GEMMs —
      ``"auto"`` (default: ``$REPRO_BACKEND``, then the device
      platform), ``"tpu"`` (Pallas-on-Mosaic), or ``"gpu"``
      (Pallas-on-Triton). Forcing a backend the host cannot execute
      raises the typed ``KernelForceError`` at dispatch — see
      :mod:`repro.kernels.backend`.
    """

    mode: KernelMode = "off"
    block: Optional[tuple[int, int, int]] = None
    decode_block: Optional[tuple[int, int, int]] = None
    backend: Backend = "auto"

    def __post_init__(self):
        if self.mode not in ("off", "auto", "force"):
            raise ValueError(f"kernel policy mode {self.mode!r} not in "
                             "('off', 'auto', 'force')")
        if self.backend not in ("auto", "tpu", "gpu"):
            raise ValueError(f"kernel policy backend {self.backend!r} not "
                             "in ('auto', 'tpu', 'gpu')")
        if self.block is not None:
            object.__setattr__(self, "block", tuple(self.block))
        if self.decode_block is not None:
            object.__setattr__(self, "decode_block", tuple(self.decode_block))


@dataclasses.dataclass(frozen=True)
class NMWeight:
    """Compressed N:M weight: ``vals``/``idx`` leaves + static metadata.

    vals: kept values, ``axis`` shrunk by n/m relative to the dense
      weight (same dtype as the dense weight).
    idx:  int8 in-block positions in ``[0, m)``, same shape as ``vals``.
    nm:   the N:M pattern the pair encodes.
    axis: compressed axis of the *logical 2D* weight (0 = the contraction
      dim K of ``y = x @ W``; leading stacked axes from scan/vmap don't
      count — consumers always see the 2D weight under the transform).
    kernel_policy: dispatch policy (see :class:`KernelPolicy`).

    No shape validation happens here: instances flow through jit / vmap /
    grad where leaves are tracers, float0 cotangents, ShapeDtypeStructs
    or PartitionSpecs. ``repro.api.sparsify`` is the validating producer.
    """

    vals: jax.Array
    idx: jax.Array
    nm: NMConfig
    axis: int = 0
    kernel_policy: KernelPolicy = KernelPolicy()

    def astype(self, dtype) -> "NMWeight":
        """Cast ``vals`` (idx stays int8 — it is pattern, not payload)."""
        return dataclasses.replace(self, vals=self.vals.astype(dtype))

    def to_dense(self) -> jax.Array:
        """Materialize the dense weight (tests / export)."""
        return decompress_nm(self.vals, self.idx, self.nm, axis=self.axis)

    @property
    def dense_dim(self) -> int:
        """Size of the compressed axis in the dense weight."""
        return self.vals.shape[self.axis] * self.nm.m // self.nm.n


@dataclasses.dataclass(frozen=True)
class MaskedNMWeight:
    """Dense-storage N:M weight for the prune->fine-tune training flow.

    ``w`` is stored dense; :meth:`project` re-derives the top-N:M mask so
    gradients reach every entry (straight-through) and pruned entries can
    revive between steps.
    """

    w: jax.Array
    nm: NMConfig
    axis: int = 0

    def astype(self, dtype) -> "MaskedNMWeight":
        return dataclasses.replace(self, w=self.w.astype(dtype))

    def project(self) -> jax.Array:
        """Dense weight re-projected onto the N:M constraint set."""
        return apply_mask(self.w, prune_mask_nm(self.w, self.nm,
                                                axis=self.axis))


compat.register_dataclass(
    NMWeight, data_fields=("vals", "idx"),
    meta_fields=("nm", "axis", "kernel_policy"),
)
compat.register_dataclass(
    MaskedNMWeight, data_fields=("w",), meta_fields=("nm", "axis"),
)


# Typed weight node classes. Sibling subsystems that add new weight
# representations (e.g. repro.quant's QNMWeight) register them here at
# import time so every tree walk built on is_weight_node sees them
# without core depending on those subsystems.
_WEIGHT_TYPES: tuple[type, ...] = (NMWeight, MaskedNMWeight)


def register_weight_type(cls: type) -> type:
    """Register an additional typed weight node class (idempotent)."""
    global _WEIGHT_TYPES
    if cls not in _WEIGHT_TYPES:
        _WEIGHT_TYPES = _WEIGHT_TYPES + (cls,)
    return cls


def is_weight_node(x) -> bool:
    """True for the typed sparse weight nodes (compressed, masked, or a
    registered sibling such as the quantized QNMWeight) — the shared
    ``is_leaf`` predicate for tree walks that treat a weight as one
    unit. (The optimizer deliberately uses a narrower test: masked
    weights train their dense storage.)"""
    return isinstance(x, _WEIGHT_TYPES)
