"""Cost models for the paper's evaluation (§IV) and the TPU adaptation.

1. `VectorCoreModel` — a calibrated instruction/memory-stall model of the
   paper's simulated RISC-V decoupled vector core (Table I: 512-bit /
   16-lane engine, L2 8-cycle hit). It counts the *exact* vector-engine
   instruction streams of Algorithm 2 (Row-Wise-SpMM) and Algorithm 3
   (vindexmac) and charges a calibrated average exposed stall per vector
   load. One global constant (`stall_per_vload`) is calibrated once so the
   ResNet50 1:4 average speedup matches the paper; everything else
   (per-layer trends, 2:4 behavior, DenseNet/Inception, Fig. 6 traffic)
   is then *predicted*, not fitted.

2. `tpu_kernel_model` — HBM-byte / MXU-FLOP accounting of the Pallas
   indexmac kernel vs a dense matmul for the same GEMM (the beyond-paper
   roofline story; DESIGN.md §7).

Per-nonzero instruction streams (per output column-tile):
  Alg. 2:  vload B[row] | smove idx->addr | vmacc | slide vals | slide idx
  Alg. 3:  smove idx | vindexmac | slide vals | slide idx
Row overheads: vload vals/idx strips, C handling (Alg. 3 reloads/stores C
once per stationary B-tile; Alg. 2 stores once), B-tile preloads (Alg. 3).
"""
from __future__ import annotations

import dataclasses

from repro.core.sparse_matmul import indexmac_traffic, rowwise_spmm_traffic
from repro.core.sparsity import NMConfig

VLEN = 16  # 32-bit lanes (512-bit vector engine)
L_ROWS = 16  # stationary B-tile rows (paper §IV-A)


@dataclasses.dataclass(frozen=True)
class VectorCoreModel:
    """Cycle model; one calibrated constant.

    Load classes: *streaming* loads (A value/idx strips, C rows, B-tile
    preloads — sequential addresses, prefetch-friendly, 16 load queues)
    issue at 1 cycle; *indexed* loads (Alg. 2's per-nonzero B[row,:] —
    data-dependent addresses) expose `stall_indexed` extra cycles on
    average (L2 hit is 8 cycles; the OoO core + unrolling hides part).
    """

    stall_indexed: float = 3.5

    def _tiles(self, n_cols: int) -> int:
        return -(-n_cols // VLEN)

    def cycles_rowwise(self, m: int, k: int, n: int, cfg: NMConfig) -> float:
        """Algorithm 2, B-stationary (paper's best baseline dataflow)."""
        nnz = k * cfg.n // cfg.m
        ct = self._tiles(n)
        a_strips = -(-nnz // VLEN)
        # per nonzero: vload B (indexed) + smove + vmacc + 2 slides
        per_nnz = 5.0 + self.stall_indexed
        per_row = nnz * per_nnz + 2 * a_strips + 1  # A strips + C store
        return m * ct * per_row

    def cycles_indexmac(self, m: int, k: int, n: int, cfg: NMConfig) -> float:
        """Algorithm 3: vindexmac + stationary B tiles."""
        nnz = k * cfg.n // cfg.m
        ct = self._tiles(n)
        a_strips = -(-nnz // VLEN)
        btiles = -(-k // L_ROWS)
        per_nnz = 4.0  # smove + vindexmac + 2 slides, no memory access
        per_row = nnz * per_nnz + 2 * a_strips + 2 * btiles + 1  # C ld/st
        preload = btiles * L_ROWS  # streaming, once per column-tile
        return m * ct * per_row + ct * preload

    def speedup(self, m: int, k: int, n: int, cfg: NMConfig) -> float:
        return (self.cycles_rowwise(m, k, n, cfg)
                / self.cycles_indexmac(m, k, n, cfg))

    def memory_reduction(self, m: int, k: int, n: int, cfg: NMConfig) -> float:
        base = rowwise_spmm_traffic(m, k, n, cfg, VLEN).total
        prop = indexmac_traffic(m, k, n, cfg, VLEN, L_ROWS).total
        return 1.0 - prop / base


# ---------------------------------------------------------------------------
# TPU kernel accounting (beyond-paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUKernelCost:
    hbm_bytes: float
    mxu_flops: float

    def t_mem(self, hbm_bw: float = 819e9) -> float:
        return self.hbm_bytes / hbm_bw

    def t_compute(self, peak: float = 197e12) -> float:
        return self.mxu_flops / peak

    @property
    def arithmetic_intensity(self) -> float:
        return self.mxu_flops / self.hbm_bytes


def tpu_dense_cost(m: int, k: int, n: int, dtype_bytes: int = 2,
                   out_reread: int = 1) -> TPUKernelCost:
    """x(m,k) @ w(k,n): each operand streamed once, output written once."""
    return TPUKernelCost(
        hbm_bytes=(m * k + k * n) * dtype_bytes + m * n * dtype_bytes
        * out_reread,
        mxu_flops=2.0 * m * k * n,
    )


def tpu_indexmac_cost(m: int, k: int, n: int, cfg: NMConfig,
                      dtype_bytes: int = 2,
                      w_value_bytes: int | None = None,
                      scale_bytes: float = 0.0) -> TPUKernelCost:
    """Pallas indexmac kernel: sparse operand streamed compressed
    (``w_value_bytes`` + 1B idx per kept weight), dense operand streamed
    once (VMEM-stationary across the n sweep), FLOPs unchanged (the MXU
    multiplies re-materialized zeros — DESIGN.md §7).

    ``dtype_bytes`` is the activation/output dtype; ``w_value_bytes``
    the *stored* value dtype of the compressed weight (defaults to the
    activation dtype for the float family; pass 1 for int8).
    ``scale_bytes`` adds dequantization-scale traffic (4 * n for the
    per-output-channel f32 scales of the int8 family)."""
    if w_value_bytes is None:
        w_value_bytes = dtype_bytes
    kept = k * n * cfg.n // cfg.m
    w_bytes = kept * (w_value_bytes + 1) + scale_bytes
    return TPUKernelCost(
        hbm_bytes=m * k * dtype_bytes + w_bytes + m * n * dtype_bytes,
        mxu_flops=2.0 * m * k * n,
    )


def tpu_indexmac_q_cost(m: int, k: int, n: int, cfg: NMConfig,
                        dtype_bytes: int = 2) -> TPUKernelCost:
    """int8 family: one byte per kept value + the f32 per-output-channel
    scale row. Same FLOP count — dequantization is a cast on the way to
    the MXU plus one multiply per output element at writeback."""
    return tpu_indexmac_cost(m, k, n, cfg, dtype_bytes=dtype_bytes,
                             w_value_bytes=1, scale_bytes=4.0 * n)


# ---------------------------------------------------------------------------
# conv workload accounting (im2col lowering — the paper's §IV mapping)
# ---------------------------------------------------------------------------


def conv_gemm_dims(c_out: int, c_in: int, kh: int, kw: int,
                   h_out: int, w_out: int) -> tuple[int, int, int]:
    """(M, K, N) of the im2col GEMM: M=C_out, K=C_in*kh*kw, N=H_out*W_out."""
    return c_out, c_in * kh * kw, h_out * w_out


def tpu_conv_cost(c_out: int, c_in: int, kh: int, kw: int,
                  h_out: int, w_out: int, cfg: NMConfig, *,
                  dtype_bytes: int = 2, quantized: bool = False,
                  fused_im2col: bool = False) -> TPUKernelCost:
    """Pallas-kernel cost of one conv executed as the im2col GEMM.

    The kernel consumes the GEMM in the forward orientation the
    :class:`repro.models.conv.SparseConv2D` layer runs — patches
    ``(N_pix, K)`` x sparse weight ``(K, C_out)`` — so the sparse operand
    bytes are the compressed weight. ``fused_im2col=True`` charges the
    activation once (``N_pix * C_in``, a fused-gather lower bound)
    instead of the materialized ``N_pix * K`` patch bytes, bounding the
    kh*kw activation-reread factor of the explicit lowering.
    """
    m, k, n_pix = c_out, c_in * kh * kw, h_out * w_out
    fn = tpu_indexmac_q_cost if quantized else tpu_indexmac_cost
    cost = fn(n_pix, k, m, cfg, dtype_bytes=dtype_bytes)
    if fused_im2col and kh * kw > 1:
        saved = (n_pix * k - n_pix * c_in) * dtype_bytes
        cost = dataclasses.replace(cost, hbm_bytes=cost.hbm_bytes - saved)
    return cost
