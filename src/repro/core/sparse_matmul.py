"""Algorithm-level references for the paper's three matmul formulations.

These are *semantic* models (pure jnp, vectorized) of the paper's
Algorithms 1-3. They are the ground truth for the Pallas kernels and the
operand-traffic accounting used by the benchmarks. All three compute the
same C = A @ B; they differ in which operand representation they touch and
how often, which is exactly what the paper's evaluation measures.

Orientation follows the paper: A is the (structured-sparse) left operand,
compressed along its rows (the contraction dim); B is dense.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig, compress_nm

__all__ = [
    "rowwise_dense_matmul",
    "rowwise_spmm",
    "indexmac_spmm",
    "TrafficReport",
    "rowwise_spmm_traffic",
    "indexmac_traffic",
]


def rowwise_dense_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Algorithm 1 (dense row-wise): C[i,:] = sum_k A[i,k] * B[k,:]."""
    return jnp.einsum("ik,kn->in", a, b)


def rowwise_spmm(
    values: jax.Array, col_idx: jax.Array, b: jax.Array, cfg: NMConfig
) -> jax.Array:
    """Algorithm 2: row-wise sparse-dense matmul from the compressed form.

    values/col_idx: (rows, K*n/m) as produced by compress_nm(axis=1).
    The *global* row of B addressed by nonzero j of block bl is
    bl*m + col_idx — the paper materializes this by adding B's base address
    (its line 5); here we materialize the global index and gather rows of B
    (the per-nonzero "vload B[row,:]" of line 8).
    """
    rows, knm = values.shape
    nblocks = knm // cfg.n
    block_base = (
        jnp.repeat(jnp.arange(nblocks, dtype=jnp.int32), cfg.n) * cfg.m
    )  # (knm,)
    gidx = col_idx.astype(jnp.int32) + block_base[None, :]  # (rows, knm)
    # Gather the addressed rows of B: (rows, knm, N_cols) -- the memory
    # traffic Algorithm 2 pays per nonzero.
    b_rows = b[gidx]  # vload per nonzero
    return jnp.einsum("rj,rjn->rn", values.astype(b.dtype), b_rows)


def indexmac_spmm(
    values: jax.Array,
    col_idx: jax.Array,
    b: jax.Array,
    cfg: NMConfig,
    l_rows: int = 16,
) -> jax.Array:
    """Algorithm 3 semantics: B is pre-loaded tile-by-tile (L rows at a
    time) and the bounded indices select rows *from the tile* (the
    vindexmac indirect register read). Numerically identical to Alg. 2;
    structured as a loop over stationary tiles of B to model the dataflow.

    l_rows must be a multiple of m (paper §III).
    """
    if l_rows % cfg.m != 0:
        raise ValueError("L must be a multiple of M")
    k = b.shape[0]
    if k % l_rows != 0:
        raise ValueError(f"K={k} not divisible by L={l_rows}")
    rows = values.shape[0]
    blocks_per_tile = l_rows // cfg.m
    nz_per_tile = blocks_per_tile * cfg.n
    ntiles = k // l_rows

    vt = values.reshape(rows, ntiles, nz_per_tile)
    it = col_idx.reshape(rows, ntiles, nz_per_tile).astype(jnp.int32)
    # index *within the stationary tile*: block-within-tile * m + col_idx
    block_in_tile = (
        jnp.repeat(jnp.arange(blocks_per_tile, dtype=jnp.int32), cfg.n) * cfg.m
    )
    tile_idx = it + block_in_tile[None, None, :]  # in [0, l_rows)
    bt = b.reshape(ntiles, l_rows, -1)

    def per_tile(t, c):
        # vrf[tile_idx] — indirect read of the stationary tile, no B memory
        # traffic. one_hot keeps it gather-free (bounded index → select).
        sel = jax.nn.one_hot(tile_idx[:, t], l_rows, dtype=bt.dtype)
        c = c + jnp.einsum(
            "rj,rjl,ln->rn", vt[:, t].astype(bt.dtype), sel, bt[t]
        )
        return c

    c0 = jnp.zeros((rows, b.shape[1]), dtype=jnp.promote_types(values.dtype, b.dtype))
    c = jax.lax.fori_loop(0, ntiles, lambda t, c: per_tile(t, c), c0)
    return c


# ---------------------------------------------------------------------------
# Operand-traffic accounting (paper Fig. 6 reproduction). Counts *vector
# memory accesses* the way the paper's gem5 runs do: one access per
# vector-register-width load/store. elem_bytes and vector bytes cancel in
# the reported ratios, so we count in units of vector-length rows.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    loads_a: float  # values + col_idx vector loads
    loads_b: float
    loads_c: float
    stores_c: float

    @property
    def total(self) -> float:
        return self.loads_a + self.loads_b + self.loads_c + self.stores_c


def _common(rows_a: int, k: int, n_cols: int, cfg: NMConfig, vlen: int):
    nnz_row = k * cfg.n // cfg.m  # non-zeros per row of A
    a_vec_loads = 2 * -(-nnz_row // vlen)  # values + col_idx per row of A
    c_tiles = -(-n_cols // vlen)  # vector tiles per row of C
    return nnz_row, a_vec_loads, c_tiles


def rowwise_spmm_traffic(
    rows_a: int, k: int, n_cols: int, cfg: NMConfig, vlen: int = 16
) -> TrafficReport:
    """Algorithm 2, B-stationary over column tiles (paper's best baseline
    dataflow): for each column-tile of B/C, every row of A re-streams its
    values/idx and issues one vector load of B per nonzero; C row loaded
    once and stored once per tile."""
    nnz_row, a_vec_loads, c_tiles = _common(rows_a, k, n_cols, cfg, vlen)
    loads_a = rows_a * a_vec_loads * c_tiles
    loads_b = rows_a * nnz_row * c_tiles  # one vload B[row,:] per nonzero
    loads_c = 0.0  # accumulate in regs within a tile pass
    stores_c = rows_a * c_tiles
    return TrafficReport(loads_a, loads_b, loads_c, stores_c)


def indexmac_traffic(
    rows_a: int,
    k: int,
    n_cols: int,
    cfg: NMConfig,
    vlen: int = 16,
    l_rows: int = 16,
) -> TrafficReport:
    """Algorithm 3: B loaded exactly once (tile pre-loads); C reloaded and
    re-stored once per (row, B-tile) because the accumulator register is
    repurposed across stationary tiles (paper lines 8/15)."""
    nnz_row, a_vec_loads, c_tiles = _common(rows_a, k, n_cols, cfg, vlen)
    ntiles_b = -(-k // l_rows)
    loads_b = ntiles_b * l_rows * c_tiles  # each row of B loaded once/tile-col
    loads_a = rows_a * a_vec_loads * c_tiles  # same A streaming as Alg.2
    loads_c = rows_a * c_tiles * ntiles_b
    stores_c = rows_a * c_tiles * ntiles_b
    return TrafficReport(loads_a, loads_b, loads_c, stores_c)
