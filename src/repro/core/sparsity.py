"""N:M structured sparsity primitives.

The paper's data format (Fig. 1b): within every block of M consecutive
elements along a row of the sparse matrix A, at most N are non-zero. The
compressed representation stores, per block, exactly N ``values`` and N
``col_idx`` entries (zero-padded when fewer than N non-zeros exist). The
indices are *bounded*: ``col_idx in [0, M)`` relative to the block — the
property that makes register-file (here: VMEM) residency of the dense
operand possible.

Orientation note: the paper compresses A along its rows (the contraction
dimension k of C = A @ B). For transformer weights we use y = x @ W with W
sparse along K (its rows), i.e. per *output column* of W each K-block of M
holds at most N non-zeros. ``axis`` selects the compressed axis.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "NMConfig",
    "value_bytes_of",
    "prune_mask_nm",
    "apply_mask",
    "compress_nm",
    "decompress_nm",
    "check_nm_pattern",
    "random_nm_matrix",
    "pad_compressed_kn",
]


@dataclasses.dataclass(frozen=True)
class NMConfig:
    """N:M structured sparsity configuration.

    n: max non-zeros per block.
    m: block size (consecutive elements along the compressed axis).
    """

    n: int = 2
    m: int = 4

    def __post_init__(self):
        if not (1 <= self.n < self.m):
            raise ValueError(f"need 1 <= n < m, got {self.n}:{self.m}")

    @property
    def density(self) -> float:
        return self.n / self.m

    @property
    def tag(self) -> str:
        return f"{self.n}:{self.m}"

    def byte_ratio(self, value_bytes: int, dense_value_bytes: int = 2) -> float:
        """Compressed-bytes ratio vs a dense bf16 weight.

        ``value_bytes`` is the *stored* dtype of the kept values (2 for
        bf16, 1 for int8, 4 for f32) — explicit, because the old 2-byte
        default silently mis-accounted quantized weights. Each kept
        value also carries one int8 index byte; ``dense_value_bytes`` is
        the dense baseline's dtype (bf16 by default). Per-output-channel
        scale bytes are O(N) and amortize to ~0 per weight — use
        :func:`repro.core.cost_model.tpu_indexmac_cost` when they
        matter.
        """
        return (self.n * (value_bytes + 1)) / (self.m * dense_value_bytes)


def value_bytes_of(dtype) -> int:
    """Bytes per stored value for a weight dtype — the explicit argument
    every byte-accounting caller threads instead of assuming bf16."""
    return int(jnp.dtype(dtype).itemsize)


def _move_axis_last(x: jax.Array, axis: int) -> jax.Array:
    return jnp.moveaxis(x, axis, -1)


def prune_mask_nm(w: jax.Array, cfg: NMConfig, axis: int = 0) -> jax.Array:
    """Magnitude-based N:M mask: keep the top-``n`` |w| in every ``m``-block.

    Returns a boolean mask with w's shape. Deterministic (ties broken by
    position via stable argsort on (-|w|, position)).
    """
    if w.shape[axis] % cfg.m != 0:
        raise ValueError(
            f"axis {axis} size {w.shape[axis]} not divisible by M={cfg.m}"
        )
    wl = _move_axis_last(w, axis)
    blocks = wl.reshape(*wl.shape[:-1], wl.shape[-1] // cfg.m, cfg.m)
    # rank within each block by |value| descending; keep rank < n
    order = jnp.argsort(-jnp.abs(blocks), axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = ranks < cfg.n
    mask = mask.reshape(*wl.shape[:-1], wl.shape[-1])
    return jnp.moveaxis(mask, -1, axis)


def apply_mask(w: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.where(mask, w, jnp.zeros_like(w))


def compress_nm(w: jax.Array, cfg: NMConfig, axis: int = 0):
    """Compress an (already N:M-sparse) matrix.

    Returns (values, idx):
      values: same dtype as w, shape = w.shape with ``axis`` shrunk by n/m.
      idx:    int8, same shape as values, entries in [0, m).

    Within each block the kept entries are ordered by ascending position
    (paper Fig. 1b stores them left-to-right). Blocks with fewer than n
    non-zeros are padded with value 0 / idx of the last kept position (a
    zero value makes the index a don't-care).
    """
    if w.shape[axis] % cfg.m != 0:
        raise ValueError(
            f"axis {axis} size {w.shape[axis]} not divisible by M={cfg.m}"
        )
    wl = _move_axis_last(w, axis)
    lead = wl.shape[:-1]
    blocks = wl.reshape(*lead, wl.shape[-1] // cfg.m, cfg.m)
    nz = blocks != 0
    # Order: non-zeros first (by position), then zeros. Stable sort on key:
    # key = position + m * (is_zero) keeps ascending-position among non-zeros.
    pos = jnp.arange(cfg.m, dtype=jnp.int32)
    key = jnp.where(nz, pos, pos + cfg.m)
    order = jnp.argsort(key, axis=-1, stable=True)  # (..., blocks, m)
    take = order[..., : cfg.n]  # first n slots
    values = jnp.take_along_axis(blocks, take, axis=-1)
    idx = take.astype(jnp.int8)
    values = values.reshape(*lead, -1)
    idx = idx.reshape(*lead, -1)
    return jnp.moveaxis(values, -1, axis), jnp.moveaxis(idx, -1, axis)


def decompress_nm(
    values: jax.Array, idx: jax.Array, cfg: NMConfig, axis: int = 0
) -> jax.Array:
    """Inverse of :func:`compress_nm` (zero-padded positions stay zero)."""
    vl = _move_axis_last(values, axis)
    il = _move_axis_last(idx, axis)
    lead = vl.shape[:-1]
    nblocks = vl.shape[-1] // cfg.n
    v = vl.reshape(*lead, nblocks, cfg.n)
    i = il.reshape(*lead, nblocks, cfg.n).astype(jnp.int32)
    # one-hot expand: out[..., b, j] = sum_n v[..., b, n] * (i[..., b, n]==j)
    onehot = jax.nn.one_hot(i, cfg.m, dtype=v.dtype)  # (..., b, n, m)
    dense = jnp.einsum("...bn,...bnm->...bm", v, onehot)
    dense = dense.reshape(*lead, nblocks * cfg.m)
    return jnp.moveaxis(dense, -1, axis)


def pad_compressed_kn(
    values: jax.Array, idx: jax.Array, *, kc_pad: int, n_pad: int
):
    """Zero-pad a compressed (Kc, N) pair to (kc_pad, n_pad).

    Appended rows are whole zero blocks (callers pad K by multiples of M,
    so Kc grows by multiples of N) and appended columns are zero output
    channels; a zero value makes its index a don't-care, so the padded
    pair decompresses to the original W bordered by zeros.
    """
    kc, nn = values.shape
    if kc_pad < kc or n_pad < nn:
        raise ValueError(
            f"pad target ({kc_pad}, {n_pad}) smaller than ({kc}, {nn})"
        )
    if (kc_pad, n_pad) == (kc, nn):
        return values, idx
    pad = ((0, kc_pad - kc), (0, n_pad - nn))
    return jnp.pad(values, pad), jnp.pad(idx, pad)


def check_nm_pattern(w: jax.Array | np.ndarray, cfg: NMConfig, axis: int = 0) -> bool:
    """True iff every M-block along ``axis`` has at most N non-zeros."""
    w = np.asarray(w)
    wl = np.moveaxis(w, axis, -1)
    blocks = wl.reshape(*wl.shape[:-1], wl.shape[-1] // cfg.m, cfg.m)
    return bool(((blocks != 0).sum(-1) <= cfg.n).all())


def random_nm_matrix(
    key: jax.Array,
    shape: Sequence[int],
    cfg: NMConfig,
    axis: int = 0,
    dtype=jnp.float32,
) -> jax.Array:
    """Random dense-valued matrix that satisfies the N:M pattern exactly
    (every block has exactly N non-zeros) — used by tests and benchmarks."""
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, tuple(shape), dtype=jnp.float32)
    # Avoid exact zeros so "exactly N per block" holds post-masking.
    w = jnp.where(w == 0, 1e-3, w)
    mask = prune_mask_nm(w, cfg, axis=axis)
    return apply_mask(w, mask).astype(dtype)
