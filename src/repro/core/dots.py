"""Mixed-precision dot policy shared by models and kernels.

TPU-native form: low-precision operands with f32 accumulation
(preferred_element_type) — the MXU accumulates in f32 natively and no
f32 operand copies are materialized. The CPU *runtime* rejects mixed dots
at dispatch, so CPU execution falls back to f32 operand casts.

REPRO_MIXED_PRECISION_DOTS=1 forces the TPU form — set by the dry-run,
which lowers on the CPU backend but never executes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def mixed_dots() -> bool:
    env = os.environ.get("REPRO_MIXED_PRECISION_DOTS")
    if env is not None:
        return env == "1"
    return jax.default_backend() != "cpu"


def acc_einsum(subs: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """einsum with f32 accumulation; operand dtype per mixed_dots()."""
    if mixed_dots():
        return jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(subs, a.astype(jnp.float32), b.astype(jnp.float32))


def acc_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    if mixed_dots():
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
