"""Single resolution point for version-drifted JAX APIs.

The repo targets the current JAX while staying runnable on the 0.4.x line
(the oldest toolchain we CI against). Every API whose name or home moved
between 0.4.x and 0.5.x is resolved HERE, once, at import time — the rest
of the codebase imports from ``repro.compat`` and never touches the
drifting names directly (enforced by tests/test_compat.py).

Resolved surface:

* ``get_abstract_mesh()`` — 0.5.x ``jax.sharding.get_abstract_mesh``;
  on 0.4.x falls back to the thread-local physical mesh's abstract view.
  Returns ``None`` when no mesh context is active (callers treat that as
  "hints are no-ops").
* ``set_mesh(mesh)`` — context manager. 0.5.x ``jax.set_mesh`` /
  ``jax.sharding.use_mesh``; on 0.4.x ``with mesh:`` (which is what feeds
  the 0.4.x ``get_abstract_mesh`` fallback above, so the pair is
  self-consistent on both lines).
* ``make_mesh(shape, axes)`` — ``jax.make_mesh`` where present, else
  built from ``mesh_utils.create_device_mesh``.
* ``manual_axis_in(mesh)`` — True when any mesh axis is Manual
  (inside shard_map). 0.4.x meshes have no axis_types: always False.
* ``tpu_compiler_params(**kw)`` — Pallas-TPU compiler params object:
  ``pltpu.CompilerParams`` (>= 0.5) or ``pltpu.TPUCompilerParams``
  (0.4.x), whichever the installed Pallas exports.
* ``register_dataclass(cls, data_fields, meta_fields)`` — pytree
  registration for dataclasses (``NMWeight``): native
  ``jax.tree_util.register_dataclass`` where present (keyword spelling
  drifted across lines), else built from
  ``register_pytree_with_keys``.
* ``resolved()`` — {name: "how it resolved"} for diagnostics and the
  compat regression test.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax

_RESOLVED: dict[str, str] = {}


def jax_version() -> tuple[int, ...]:
    return tuple(int(p) for p in jax.__version__.split(".")[:3])


# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------

if hasattr(jax.sharding, "get_abstract_mesh"):
    _RESOLVED["get_abstract_mesh"] = "jax.sharding.get_abstract_mesh"

    def get_abstract_mesh():
        """Active (abstract) mesh, or None outside any mesh context."""
        m = jax.sharding.get_abstract_mesh()
        return m if m is not None and m.shape_tuple else None

else:  # 0.4.x: the active mesh lives in the thread-local resource env
    _RESOLVED["get_abstract_mesh"] = "jax._src.mesh.thread_resources"

    def get_abstract_mesh():
        """Active mesh, or None outside any mesh context.

        Returns the *physical* mesh on this line: 0.4.x shard_map and
        with_sharding_constraint are only fully supported against it
        (AbstractMesh existed but plumbing it through jit trips XLA's
        sharding-remover pass).
        """
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is None or pm.empty:
            return None
        return pm


if hasattr(jax, "set_mesh"):
    _RESOLVED["set_mesh"] = "jax.set_mesh"
    _set_mesh_impl = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):
    _RESOLVED["set_mesh"] = "jax.sharding.use_mesh"
    _set_mesh_impl = jax.sharding.use_mesh
else:
    _RESOLVED["set_mesh"] = "with-mesh-context (0.4.x)"

    @contextlib.contextmanager
    def _set_mesh_impl(mesh):
        with mesh:
            yield


def set_mesh(mesh):
    """Context manager activating `mesh` so shard hints see it."""
    return _set_mesh_impl(mesh)


if hasattr(jax, "make_mesh"):
    _RESOLVED["make_mesh"] = "jax.make_mesh"

    def make_mesh(shape: Sequence[int], axes: Sequence[str]):
        return jax.make_mesh(tuple(shape), tuple(axes))

else:
    _RESOLVED["make_mesh"] = "mesh_utils.create_device_mesh"

    def make_mesh(shape: Sequence[int], axes: Sequence[str]):
        from jax.experimental import mesh_utils

        devices = mesh_utils.create_device_mesh(tuple(shape))
        return jax.sharding.Mesh(devices, tuple(axes))


# Alias without the drifted name: call sites outside this module use
# `compat.active_mesh()` so a grep for the moved API hits only this file.
def active_mesh():
    return get_abstract_mesh()


def manual_axis_in(mesh: Any) -> bool:
    """True iff any axis of `mesh` is Manual (inside a shard_map region).

    0.5.x meshes carry axis_types; on 0.4.x shard_map instead binds the
    mesh axes into the tracing axis env, so "any mesh axis currently
    bound" is the equivalent signal. Missing either detection would let
    shard hints emit with_sharding_constraint inside manual regions —
    which trips XLA's sharding-remover on the 0.4.x line.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    types = getattr(mesh, "axis_types", None)
    if axis_type is not None and types is not None:
        try:
            return any(t == axis_type.Manual for t in types)
        except TypeError:
            return False
    try:
        from jax._src import core as _core

        bound = _core.get_axis_env().axis_sizes
    except (ImportError, AttributeError):
        return False
    return any(a in bound for a in mesh.axis_names)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _RESOLVED["shard_map"] = "jax.shard_map"

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:  # 0.4.x: experimental home, and the check kwarg is `check_rep`
    _RESOLVED["shard_map"] = "jax.experimental.shard_map"

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        from jax.experimental.shard_map import shard_map as _shard_map

        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )


# ---------------------------------------------------------------------------
# compiled-artifact introspection
# ---------------------------------------------------------------------------

_RESOLVED["cost_analysis"] = "normalized (dict | [dict])"


def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` as a flat dict.

    0.4.x returns a one-element list of dicts (per device program), newer
    JAX returns the dict itself.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------


def _resolve_tpu_params_cls():
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pallas not available at all
        return None, "unavailable"
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls, f"pltpu.{name}"
    return None, "unavailable"


_TPU_PARAMS_CLS, _how = _resolve_tpu_params_cls()
_RESOLVED["tpu_compiler_params"] = _how


def tpu_compiler_params(**kwargs) -> Optional[Any]:
    """Pallas-TPU compiler params under whichever name this JAX exports.

    Drops kwargs the installed class doesn't know (field sets drifted
    too); returns None when Pallas TPU params are unavailable, which
    ``pallas_call`` accepts as "no params".
    """
    if _TPU_PARAMS_CLS is None:
        return None
    try:
        return _TPU_PARAMS_CLS(**kwargs)
    except TypeError:
        import dataclasses

        known = {f.name for f in dataclasses.fields(_TPU_PARAMS_CLS)}
        return _TPU_PARAMS_CLS(
            **{k: v for k, v in kwargs.items() if k in known}
        )


# ---------------------------------------------------------------------------
# dataclass pytree registration
# ---------------------------------------------------------------------------

if hasattr(jax.tree_util, "register_dataclass"):
    _RESOLVED["register_dataclass"] = "jax.tree_util.register_dataclass"

    def register_dataclass(cls, data_fields: Sequence[str],
                           meta_fields: Sequence[str]):
        """Register ``cls`` as a pytree: data_fields are leaves (with
        GetAttrKey paths), meta_fields are static treedef aux data."""
        return jax.tree_util.register_dataclass(
            cls, list(data_fields), list(meta_fields)
        )

else:  # very old lines: build it from register_pytree_with_keys
    _RESOLVED["register_dataclass"] = "register_pytree_with_keys"

    def register_dataclass(cls, data_fields: Sequence[str],
                           meta_fields: Sequence[str]):
        import dataclasses

        from jax.tree_util import GetAttrKey, register_pytree_with_keys

        data_fields = tuple(data_fields)
        meta_fields = tuple(meta_fields)

        def flatten_with_keys(obj):
            children = [(GetAttrKey(f), getattr(obj, f))
                        for f in data_fields]
            aux = tuple(getattr(obj, f) for f in meta_fields)
            return children, aux

        def unflatten(aux, children):
            kw = dict(zip(data_fields, children))
            kw.update(zip(meta_fields, aux))
            return cls(**kw)

        def flatten(obj):
            return ([getattr(obj, f) for f in data_fields],
                    tuple(getattr(obj, f) for f in meta_fields))

        register_pytree_with_keys(cls, flatten_with_keys, unflatten, flatten)
        return dataclasses.dataclass(cls) if not dataclasses.is_dataclass(
            cls) else cls


def resolved() -> dict[str, str]:
    """How each drifted API resolved on the installed JAX (diagnostics)."""
    return dict(_RESOLVED)
