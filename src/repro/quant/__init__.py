"""Quantized sparse execution: int8 compressed N:M weights.

  qnmweight.py — QNMWeight registered pytree (int8 vals/idx + f32
                 per-output-channel scales, NMConfig/axis/KernelPolicy
                 static metadata)
  calibrate.py — absmax / percentile observers, quantize_nm /
                 dequantize, tree-level quantize_tree / dequantize_tree

The quantized kernels live with their float siblings under
``repro.kernels.indexmac`` / ``repro.kernels.indexmac_gather`` (ops
``nm_matmul_q`` / ``indexmac_gather_q``); ``repro.api.quantize`` /
``repro.api.nm_matmul`` are the user-facing entry points.
"""
from repro.quant.calibrate import (  # noqa: F401
    AbsMaxObserver,
    PercentileObserver,
    dequantize,
    dequantize_tree,
    quantize_nm,
    quantize_tree,
)
from repro.quant.qnmweight import QMAX, QNMWeight  # noqa: F401
