"""Calibration: per-output-channel int8 scales for compressed weights.

Two observers cover the standard weight-quantization recipes:

* :class:`AbsMaxObserver` — scale = max|w| / 127 per channel. Lossless
  range coverage, sensitive to outliers.
* :class:`PercentileObserver` — scale = percentile(|w|, p) / 127 per
  channel. Clips the outlier tail (values beyond the percentile saturate
  at ±127) in exchange for finer resolution of the bulk.

Observers accumulate statistics over one or more ``observe`` calls (a
weight is usually observed once; activation-style multi-batch
calibration composes the same way) and produce ``scales()``.

``quantize_nm`` is the validating producer of :class:`QNMWeight`: it
accepts a dense 2D array (pruned + compressed via ``repro.api.sparsify``
semantics) or an existing :class:`NMWeight`, calibrates per output
channel, and quantizes the *compressed* vals — per-channel statistics
over kept values equal those over the dense channel, because pruned
entries are exact zeros. ``dequantize`` is the inverse (up to the
quantization error bound: |w - deq(q(w))| <= scale/2 per element for
absmax, tested by property in tests/test_quant.py).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.nmweight import KernelPolicy, NMWeight
from repro.core.sparsity import NMConfig
from repro.quant.qnmweight import QMAX, QNMWeight

__all__ = [
    "AbsMaxObserver",
    "PercentileObserver",
    "quantize_nm",
    "dequantize",
    "quantize_tree",
    "dequantize_tree",
]

_EPS = 1e-12  # all-zero channels quantize with a harmless unit-ish scale


class AbsMaxObserver:
    """Running per-channel absmax over observed tensors.

    ``axis`` is the reduction (compressed) axis: statistics survive per
    index of the *other* axis — the output channel.
    """

    def __init__(self):
        self._max: Optional[jax.Array] = None

    def observe(self, w: jax.Array, axis: int = 0) -> "AbsMaxObserver":
        m = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis)
        self._max = m if self._max is None else jnp.maximum(self._max, m)
        return self

    def scales(self, qmax: int = QMAX) -> jax.Array:
        if self._max is None:
            raise ValueError("observer has seen no data; call observe first")
        return jnp.maximum(self._max, _EPS) / qmax


class PercentileObserver:
    """Per-channel |w| percentile over everything observed so far.

    Keeps the observed tensors (weights are small relative to
    activations; calibration is offline) and computes the percentile
    over their concatenation along the reduction axis.
    """

    def __init__(self, pct: float = 99.9):
        if not 0.0 < pct <= 100.0:
            raise ValueError(f"pct must be in (0, 100], got {pct}")
        self.pct = pct
        self._seen: list[jax.Array] = []
        self._axis: Optional[int] = None

    def observe(self, w: jax.Array, axis: int = 0) -> "PercentileObserver":
        if self._axis is not None and axis != self._axis:
            raise ValueError(
                f"observer reduction axis changed: {self._axis} -> {axis}")
        self._axis = axis
        self._seen.append(jnp.abs(w.astype(jnp.float32)))
        return self

    def scales(self, qmax: int = QMAX) -> jax.Array:
        if not self._seen:
            raise ValueError("observer has seen no data; call observe first")
        stacked = jnp.concatenate(self._seen, axis=self._axis)
        p = jnp.percentile(stacked, self.pct, axis=self._axis)
        return jnp.maximum(p, _EPS) / qmax


_OBSERVERS = {"absmax": AbsMaxObserver, "percentile": PercentileObserver}

Observer = Union[AbsMaxObserver, PercentileObserver]


def _as_observer(method) -> Observer:
    if isinstance(method, (AbsMaxObserver, PercentileObserver)):
        return method
    if isinstance(method, str):
        cls = _OBSERVERS.get(method)
        if cls is None:
            raise ValueError(
                f"unknown calibration method {method!r}; expected one of "
                f"{sorted(_OBSERVERS)} or an observer instance")
        return cls()
    raise TypeError(
        f"method must be a string or observer, got {type(method).__name__}")


def quantize_nm(
    w: Union[jax.Array, NMWeight],
    nm: Optional[NMConfig] = None,
    *,
    method: Union[str, Observer] = "absmax",
    axis: int = 0,
    kernel_policy: Optional[Union[KernelPolicy, str]] = None,
) -> QNMWeight:
    """Quantize a weight to the int8 compressed representation.

    ``w`` is a dense 2D array (``nm`` required; pruned top-|w| N:M and
    compressed first) or an existing :class:`NMWeight` (``nm`` must be
    omitted or match). ``method`` picks the calibration observer; a
    pre-populated observer instance may be passed to reuse statistics
    gathered elsewhere. ``kernel_policy`` overrides the policy carried
    over from the source weight (defaults: the NMWeight's own policy,
    or "auto" for dense input).
    """
    if isinstance(w, QNMWeight):
        raise TypeError("weight is already quantized")
    if isinstance(w, NMWeight):
        if nm is not None and nm != w.nm:
            raise ValueError(
                f"nm {nm.tag} conflicts with the weight's own {w.nm.tag}")
        sw = w
    else:
        from repro.api import sparsify  # lazy: api imports this module

        if nm is None:
            raise ValueError("nm is required when quantizing a dense array")
        sw = sparsify(jnp.asarray(w), nm, axis=axis,
                      kernel_policy=kernel_policy or KernelPolicy("auto"))
    if sw.vals.ndim != 2:
        raise ValueError(
            f"quantize_nm expects a 2D weight, got vals shape {sw.vals.shape}")

    # Per-output-channel statistics over the compressed vals: kept values
    # are exactly the dense channel's non-zeros, so absmax is identical
    # to the dense channel's. Percentiles are over *kept* magnitudes
    # (pruned zeros excluded) — the pct-th percentile of the values the
    # int8 grid actually has to represent, which is the distribution
    # that matters for clipping.
    obs = _as_observer(method)
    obs.observe(sw.vals, axis=sw.axis)
    scales = obs.scales()

    bcast = scales[None, :] if sw.axis == 0 else scales[:, None]
    q = jnp.round(sw.vals.astype(jnp.float32) / bcast)
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    policy = sw.kernel_policy
    if kernel_policy is not None:
        policy = (kernel_policy if isinstance(kernel_policy, KernelPolicy)
                  else KernelPolicy(mode=kernel_policy))
    return QNMWeight(vals=q, idx=sw.idx, scales=scales.astype(jnp.float32),
                     nm=sw.nm, axis=sw.axis, kernel_policy=policy)


def dequantize(qw: QNMWeight, dtype=jnp.float32) -> NMWeight:
    """Float :class:`NMWeight` with the same pattern (fallback path)."""
    if not isinstance(qw, QNMWeight):
        raise TypeError(
            f"dequantize expects a QNMWeight, got {type(qw).__name__}")
    return qw.dequantize(dtype=dtype)


def quantize_tree(params, *, method: str = "absmax"):
    """Quantize every :class:`NMWeight` leaf of a param tree to int8.

    Dense leaves, masked weights and everything else pass through
    unchanged — the walk is the gate, exactly like the serving autotune
    warmup. Scan-stacked (3D+) NMWeight leaves are quantized per stacked
    slice via vmap so each layer gets its own per-channel scales.

    ``method`` must be a method *name* here, not an observer instance:
    one observer accumulates statistics across observe calls, so reusing
    it for every leaf would contaminate each leaf's scales with all
    previous leaves' (per-weight observer instances belong with
    per-weight :func:`quantize_nm` calls).
    """
    if not isinstance(method, str):
        raise TypeError(
            "quantize_tree needs a method name ('absmax' | 'percentile'); "
            "an observer instance would accumulate statistics across "
            "leaves — pass it to quantize_nm for the one weight it "
            "calibrates")

    def one(p):
        if not isinstance(p, NMWeight):
            return p
        if p.vals.ndim == 2:
            return quantize_nm(p, method=method)
        f = lambda sw: quantize_nm(sw, method=method)  # noqa: E731
        for _ in range(p.vals.ndim - 2):
            f = jax.vmap(f)
        return f(p)

    return jax.tree.map(one, params,
                        is_leaf=lambda x: isinstance(x, NMWeight))


def dequantize_tree(params, dtype=jnp.float32):
    """Inverse of :func:`quantize_tree` (up to quantization error)."""
    return jax.tree.map(
        lambda p: dequantize(p, dtype=dtype) if isinstance(p, QNMWeight)
        else p,
        params, is_leaf=lambda x: isinstance(x, QNMWeight))
