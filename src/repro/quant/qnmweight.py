"""Quantized compressed N:M weight: int8 payload + per-channel scales.

The paper's compressed pair already halves-or-better the sparse
operand's bytes (values + bounded int8 indices); quantizing the kept
values to int8 compounds the same lever — the kernel streams one byte
per kept value instead of two (bf16) or four (f32), with a float32
scale per *output channel* applied once at accumulator writeback. The
follow-up RISC-V work (arXiv 2501.10189) and the sparse-DNN HW/SW
co-design line (arXiv 2504.19659) both pull exactly this combination.

:class:`QNMWeight` mirrors :class:`repro.core.nmweight.NMWeight`: the
``vals`` (int8), ``idx`` (int8) and ``scales`` (float32) arrays are
pytree leaves; the :class:`NMConfig`, compressed ``axis`` and
:class:`KernelPolicy` ride as static treedef metadata. Every subsystem
(api dispatch, kernel registry, sharding, optimizer, checkpointing,
serving autotune) dispatches on the type.

Scale layout: one scale per output channel, i.e. per index along the
*non-compressed* axis of the logical 2D weight —

* ``axis=0`` (``y = x @ W``, W compressed along K): ``vals`` is
  ``(Kc, N)`` and ``scales`` is ``(N,)`` — one scale per output column,
  constant over the contraction, so it factors out of the dot and is
  applied once per output tile.
* ``axis=1`` (the paper's A-orientation, ``C = A @ B``): ``vals`` is
  ``(Mr, Kc)`` and ``scales`` is ``(Mr,)`` — one scale per output row.

Symmetric quantization (no zero point): zero stays exactly zero, which
the N:M representation requires — a quantized zero-padded slot must
still kill its index's contribution.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.nmweight import (
    KernelPolicy,
    NMWeight,
    register_weight_type,
)
from repro.core.sparsity import NMConfig, decompress_nm

__all__ = ["QNMWeight", "QMAX"]

QMAX = 127  # symmetric int8 range [-127, 127]; -128 never produced


@dataclasses.dataclass(frozen=True)
class QNMWeight:
    """Quantized compressed N:M weight (int8 payload, f32 scales).

    vals:   int8 quantized kept values, ``axis`` shrunk by n/m relative
            to the dense weight.
    idx:    int8 in-block positions in ``[0, m)``, same shape as vals.
    scales: float32 per-output-channel dequantization scales, shape =
            (vals.shape[1 - axis],) for 2D weights (leading stacked
            axes from scan/vmap carry through).
    nm:     the N:M pattern the pair encodes.
    axis:   compressed axis of the logical 2D weight (see module doc).
    kernel_policy: dispatch policy, same semantics as NMWeight's.

    No shape/dtype validation happens here: instances flow through
    jit / vmap / eval_shape where leaves are tracers or
    ShapeDtypeStructs. ``repro.quant.calibrate.quantize_nm`` is the
    validating producer.
    """

    vals: jax.Array
    idx: jax.Array
    scales: jax.Array
    nm: NMConfig
    axis: int = 0
    kernel_policy: KernelPolicy = KernelPolicy()

    @property
    def dense_dim(self) -> int:
        """Size of the compressed axis in the dense weight."""
        return self.vals.shape[self.axis] * self.nm.m // self.nm.n

    def _scale_bcast(self) -> jax.Array:
        """Scales broadcast against the compressed (vals) layout."""
        if self.axis == 0:
            return self.scales[..., None, :]  # (..., 1, N)
        return self.scales[..., :, None]      # (..., Mr, 1)

    def dequantize(self, dtype=jnp.float32) -> NMWeight:
        """Float NMWeight with the same pattern (the fallback path)."""
        vals = (self.vals.astype(jnp.float32) * self._scale_bcast())
        return NMWeight(vals=vals.astype(dtype), idx=self.idx, nm=self.nm,
                        axis=self.axis, kernel_policy=self.kernel_policy)

    def to_dense(self, dtype=jnp.float32) -> jax.Array:
        """Materialize the dense float weight (tests / export)."""
        d8 = decompress_nm(self.vals, self.idx, self.nm, axis=self.axis)
        # the non-compressed axis sits in the same position in the dense
        # and compressed layouts, so the same broadcast applies.
        return (d8.astype(jnp.float32) * self._scale_bcast()).astype(dtype)


compat.register_dataclass(
    QNMWeight, data_fields=("vals", "idx", "scales"),
    meta_fields=("nm", "axis", "kernel_policy"),
)
register_weight_type(QNMWeight)
