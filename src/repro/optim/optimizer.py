"""AdamW with cosine schedule and global-norm clipping.

Sparse weights are typed :class:`repro.core.nmweight.NMWeight` nodes and
are handled *structurally*: the node is one unit (``is_leaf``), moments
are allocated for its ``vals`` leaf only, and the ``idx`` leaf — pattern
metadata, not a parameter — is passed through untouched with a scalar
placeholder in the moment trees. Quantized
:class:`repro.quant.QNMWeight` nodes are excluded structurally as one
unit: int8 values are a serving artifact, not trainable parameters (the
gradient of a rounding lattice is meaningless) — the whole node (vals,
idx, scales) passes through bit-identical with scalar moment
placeholders. No dtype sniffing is involved, so an unrelated integer
leaf elsewhere in the params keeps its historical behavior (no state,
passed through; its gradient arrives as float0 from
`jax.grad(..., allow_int=True)`).

Optimizer-state sharding: moments mirror the parameter PartitionSpecs, so
under the 2D (fsdp x tp) parameter layout the optimizer state is fully
sharded across the mesh (ZeRO-equivalent storage).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.nmweight import NMWeight
from repro.quant import QNMWeight


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _is_trainable(leaf) -> bool:
    """Plain-leaf rule: float leaves train, integer leaves pass through.
    NMWeight nodes never reach this — they are excluded structurally
    (see ``_is_weight_node`` call sites), not by dtype."""
    return jnp.issubdtype(leaf.dtype, jnp.inexact)


def _is_weight_node(x) -> bool:
    return isinstance(x, (NMWeight, QNMWeight))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    def zeros(p):
        if isinstance(p, QNMWeight):
            # frozen as one unit: no trainable leaves, scalar
            # placeholders keep the moment trees congruent.
            return dataclasses.replace(
                p, vals=jnp.zeros((), jnp.int8),
                idx=jnp.zeros((), jnp.int8),
                scales=jnp.zeros((), jnp.float32))
        if _is_weight_node(p):
            # moments for the trainable vals leaf only; the idx leaf is
            # structural metadata — a scalar placeholder keeps the tree
            # shape without allocating idx-sized state.
            return dataclasses.replace(
                p, vals=jnp.zeros_like(p.vals),
                idx=jnp.zeros((), jnp.int8))
        return (jnp.zeros_like(p) if _is_trainable(p)
                else jnp.zeros((), jnp.int8))

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params, is_leaf=_is_weight_node),
        "v": jax.tree.map(zeros, params, is_leaf=_is_weight_node),
    }


def global_norm(grads: Any) -> jax.Array:
    """L2 norm over the gradients that will actually be applied.

    QNMWeight grad nodes are skipped as one unit: the node is
    structurally frozen, so even a real (nonzero) scales gradient never
    updates anything — letting it into the norm would shrink the clip
    scale applied to every trainable leaf.
    """
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(
            grads, is_leaf=lambda x: isinstance(x, QNMWeight))
        if not isinstance(g, QNMWeight)
        and hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.floating)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd_leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    def upd(p, g, m, v):
        if isinstance(p, QNMWeight):
            # structurally frozen: params and placeholders pass through
            # bit-identical (int8 leaves never see an update).
            return p, m, v
        if _is_weight_node(p):
            # structural exclusion: only vals trains; idx (and its scalar
            # moment placeholders) pass through bit-identical.
            nv, nm_, nvv = upd_leaf(p.vals, g.vals, m.vals, v.vals)
            return (dataclasses.replace(p, vals=nv),
                    dataclasses.replace(m, vals=nm_),
                    dataclasses.replace(v, vals=nvv))
        if not _is_trainable(p):
            return p, m, v
        return upd_leaf(p, g, m, v)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                       is_leaf=_is_weight_node)
    # out is a tree of 3-tuples; split it
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, {
        "lr": lr, "grad_norm": gnorm}
