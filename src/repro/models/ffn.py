"""Feed-forward blocks (dense MLP) — sparse-eligible (target "ffn").

``sp`` is init-time routing only; the built weights carry their own
sparsity metadata, so ``ffn_apply`` takes no config."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FFNConfig, SparsityConfig
from repro.models.common import linear_apply, linear_init
from repro.parallel.hints import tp_reduce


def ffn_init(
    key: jax.Array,
    d_model: int,
    cfg: FFNConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
    target: str = "ffn",
) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": linear_init(ks[0], d_model, cfg.d_ff, sp=sp, target=target,
                            param_dtype=param_dtype),
        "w_down": linear_init(ks[1], cfg.d_ff, d_model, sp=sp, target=target,
                              param_dtype=param_dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = linear_init(ks[2], d_model, cfg.d_ff, sp=sp, target=target,
                                  param_dtype=param_dtype)
    return p


def ffn_apply(
    params: dict,
    x: jax.Array,
    cfg: FFNConfig,
) -> jax.Array:
    up = linear_apply(params["w_up"], x)
    if cfg.act in ("swiglu", "geglu"):
        gate = linear_apply(params["w_gate"], x)
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(gate) * up
    elif cfg.act == "gelu":
        h = jax.nn.gelu(up)
    elif cfg.act == "relu_sq":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.act)
    # w_down is row-parallel under TP serving: per-shard output is a
    # partial sum over the sharded d_ff — reduced here, identity elsewhere
    return tp_reduce(linear_apply(params["w_down"], h), "ffn_down")
