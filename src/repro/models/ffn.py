"""Feed-forward blocks (dense MLP) — sparse-eligible (target "ffn").

``sp`` is init-time routing only; the built weights carry their own
sparsity metadata, so ``ffn_apply`` takes no config."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FFNConfig, SparsityConfig
from repro.kernels.epilogue import Epilogue
from repro.models.common import linear_apply, linear_init
from repro.parallel.hints import tp_reduce


def ffn_init(
    key: jax.Array,
    d_model: int,
    cfg: FFNConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
    target: str = "ffn",
) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": linear_init(ks[0], d_model, cfg.d_ff, sp=sp, target=target,
                            param_dtype=param_dtype),
        "w_down": linear_init(ks[1], cfg.d_ff, d_model, sp=sp, target=target,
                              param_dtype=param_dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = linear_init(ks[2], d_model, cfg.d_ff, sp=sp, target=target,
                                  param_dtype=param_dtype)
    return p


def ffn_apply(
    params: dict,
    x: jax.Array,
    cfg: FFNConfig,
) -> jax.Array:
    # the activation rides as an Epilogue on its projection: decode-shaped
    # sparse GEMMs fuse it into the kernel writeback (one launch), every
    # other path applies the identical f32 composition after the GEMM.
    if cfg.act in ("swiglu", "geglu"):
        up = linear_apply(params["w_up"], x)
        act = "silu" if cfg.act == "swiglu" else "gelu"
        gate = linear_apply(params["w_gate"], x,
                            epilogue=Epilogue(activation=act))
        h = gate * up
    elif cfg.act == "gelu":
        h = linear_apply(params["w_up"], x, epilogue=Epilogue(activation="gelu"))
    elif cfg.act == "relu_sq":
        h = linear_apply(params["w_up"], x,
                         epilogue=Epilogue(activation="relu_sq"))
    else:
        raise ValueError(cfg.act)
    # w_down is row-parallel under TP serving: per-shard output is a
    # partial sum over the sharded d_ff — reduced here, identity elsewhere
    return tp_reduce(linear_apply(params["w_down"], h), "ffn_down")
