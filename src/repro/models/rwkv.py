"""RWKV-6 (Finch) mixer: time-mix with data-dependent decay + channel-mix.

The block owns both sublayers (time-mix plays the attention role,
channel-mix the FFN role) because both need the token-shift state; the
transformer assembly passes mlp=None for RWKV blocks.

State per layer: time-mix wkv state (B, H, dk, dv) fp32 + the last token
for each of the two shift gates — O(1) in sequence length, which is why
rwkv6-3b is a `long_500k` runner (DESIGN.md §6).

All projections (r/k/v/g/o, channel-mix) are GEMMs -> sparse-eligible.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig, SparsityConfig
from repro.models.common import (
    linear_apply,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
)


def rwkv_init(
    key: jax.Array,
    d_model: int,
    cfg: RWKVConfig,
    *,
    d_ff: int,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
) -> dict:
    h = d_model // cfg.head_dim
    ks = jax.random.split(key, 12)
    u = jax.random.uniform(ks[0], (h, cfg.head_dim), minval=-1.0, maxval=1.0)
    return {
        # time-mix
        "mu": jax.random.uniform(ks[1], (5, d_model)).astype(param_dtype),
        "mix_lora_a": (jax.random.normal(ks[2], (d_model, 5 * cfg.mix_lora))
                       * d_model ** -0.5).astype(param_dtype),
        "mix_lora_b": jnp.zeros((5, cfg.mix_lora, d_model), param_dtype),
        "w_r": linear_init(ks[3], d_model, d_model, sp=sp, target="attn_proj",
                           param_dtype=param_dtype),
        "w_k": linear_init(ks[4], d_model, d_model, sp=sp, target="attn_proj",
                           param_dtype=param_dtype),
        "w_v": linear_init(ks[5], d_model, d_model, sp=sp, target="attn_proj",
                           param_dtype=param_dtype),
        "w_g": linear_init(ks[6], d_model, d_model, sp=sp, target="attn_proj",
                           param_dtype=param_dtype),
        "w_o": linear_init(ks[7], d_model, d_model, sp=sp, target="attn_proj",
                           param_dtype=param_dtype),
        "decay_base": jnp.full((d_model,), -5.0, param_dtype),
        "decay_lora_a": (jax.random.normal(ks[8], (d_model, cfg.decay_lora))
                         * d_model ** -0.5).astype(param_dtype),
        "decay_lora_b": jnp.zeros((cfg.decay_lora, d_model), param_dtype),
        "bonus": u.astype(param_dtype),
        "wkv_norm": rmsnorm_init(cfg.head_dim, param_dtype),
        # channel-mix
        "cm_mu": jax.random.uniform(ks[9], (2, d_model)).astype(param_dtype),
        "cm_norm": rmsnorm_init(d_model, param_dtype),
        "w_cm_k": linear_init(ks[10], d_model, d_ff, sp=sp, target="ffn",
                              param_dtype=param_dtype),
        "w_cm_v": linear_init(ks[11], d_ff, d_model, sp=sp, target="ffn",
                              param_dtype=param_dtype),
        "w_cm_r": linear_init(jax.random.fold_in(key, 99), d_model, d_model,
                              sp=sp, target="ffn", param_dtype=param_dtype),
    }


def rwkv_empty_cache(batch: int, d_model: int, cfg: RWKVConfig,
                     dtype=jnp.float32) -> dict:
    h = d_model // cfg.head_dim
    return {
        "wkv": jnp.zeros((batch, h, cfg.head_dim, cfg.head_dim), jnp.float32),
        "tm_last": jnp.zeros((batch, d_model), dtype),
        "cm_last": jnp.zeros((batch, d_model), dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """Previous token's activation (zeros / cache at position 0)."""
    prev = jnp.roll(x, 1, axis=1)
    first = last[:, None, :] if last is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first.astype(x.dtype), prev[:, 1:]], axis=1)


def _ddlerp(params, x, prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    xx = prev - x
    mu = params["mu"].astype(x.dtype)  # (5, D)
    base = x[:, :, None, :] + xx[:, :, None, :] * mu[None, None]
    lora = jnp.tanh(
        jnp.einsum("bsd,dk->bsk", x + xx * mu[0], params["mix_lora_a"].astype(x.dtype))
    )
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    adj = jnp.einsum("bsik,ikd->bsid", lora, params["mix_lora_b"].astype(x.dtype))
    mixed = base + xx[:, :, None, :] * adj
    return [mixed[:, :, i] for i in range(5)]


def rwkv_time_mix(params, x, cfg: RWKVConfig, *, state, last):
    b, s, d = x.shape
    h = d // cfg.head_dim
    dk = cfg.head_dim
    prev = _token_shift(x, last)
    xr, xk, xv, xw, xg = _ddlerp(params, x, prev)
    r = linear_apply(params["w_r"], xr).reshape(b, s, h, dk)
    k = linear_apply(params["w_k"], xk).reshape(b, s, h, dk)
    v = linear_apply(params["w_v"], xv).reshape(b, s, h, dk)
    g = jax.nn.silu(linear_apply(params["w_g"], xg))
    dlora = jnp.tanh(
        jnp.einsum("bsd,dk->bsk", xw, params["decay_lora_a"].astype(x.dtype))
    )
    wraw = params["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsk,kd->bsd", dlora, params["decay_lora_b"].astype(x.dtype)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wraw)).reshape(b, s, h, dk)  # decay in (0,1)
    u = params["bonus"].astype(jnp.float32)  # (h, dk)

    rf = r.astype(jnp.float32).swapaxes(0, 1)  # (S,B,h,dk)
    kf = k.astype(jnp.float32).swapaxes(0, 1)
    vf = v.astype(jnp.float32).swapaxes(0, 1)
    wf = w.swapaxes(0, 1)

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp  # (B,h,dk)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, st + u[None] [..., None] * kv)
        st = w_t[..., None] * st + kv
        return st, y

    st0 = state if state is not None else jnp.zeros((b, h, dk, dk), jnp.float32)
    stT, ys = jax.lax.scan(step, st0, (rf, kf, vf, wf))
    y = ys.swapaxes(0, 1)  # (B,S,h,dk)
    y = rmsnorm_apply(params["wkv_norm"], y.astype(x.dtype))
    y = (y.reshape(b, s, d) * g)
    out = linear_apply(params["w_o"], y)
    return out, stT, x[:, -1]


def rwkv_channel_mix(params, x, *, last):
    prev = _token_shift(x, last)
    mu = params["cm_mu"].astype(x.dtype)
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = linear_apply(params["w_cm_k"], xk)
    v = linear_apply(params["w_cm_v"], jnp.square(jax.nn.relu(k)))
    r = jax.nn.sigmoid(linear_apply(params["w_cm_r"], xr))
    return r * v, x[:, -1]


def rwkv_apply(
    params: dict,
    x: jax.Array,  # (B, S, D) — already layer-normed by the block wrapper
    cfg: RWKVConfig,
    *,
    mode: str,
    cache: Optional[dict] = None,
    **_,
):
    """Time-mix sublayer only; channel-mix is exposed separately so the
    block wrapper can put its own norm + residual around each."""
    state = cache["wkv"] if cache is not None else None
    last = cache["tm_last"] if cache is not None else None
    y, st, tm_last = rwkv_time_mix(params, x, cfg, state=state, last=last)
    new_cache = None
    if mode in ("prefill", "decode"):
        assert cache is not None
        new_cache = dict(cache)
        new_cache["wkv"] = st
        new_cache["tm_last"] = tm_last.astype(cache["tm_last"].dtype)
    return y, new_cache
