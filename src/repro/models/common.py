"""Shared model building blocks: linear (dense / N:M sparse), norms,
rotary embeddings, token embedding.

Parameters are pytrees (nested dicts of jnp arrays, plus typed weight
nodes); every layer is a pair of pure functions
`*_init(key, ...) -> params` / `*_apply(params, x)`.

Sparsity is integrated at the linear layer: a linear created with a
target tag that the model's SparsityConfig covers stores a typed weight
node — :class:`repro.core.nmweight.NMWeight` (compressed (vals, idx)
pair) or :class:`MaskedNMWeight` (dense storage, mask re-derived each
forward) — whose static metadata carries its own ``NMConfig`` and kernel
policy. Apply paths dispatch on the node type; nothing threads an
``sp=`` config through forward calls (the weight is self-describing),
and nothing sniffs ``{"vals", "idx"}`` dict keys. Dense linears remain
plain ``{"w": ...}`` dicts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SparsityConfig
from repro.core.nmweight import KernelPolicy, MaskedNMWeight, NMWeight
from repro.core.sparsity import (
    apply_mask,
    compress_nm,
    prune_mask_nm,
)
from repro.kernels.epilogue import Epilogue, apply_epilogue_f32, resolve_epilogue
from repro.kernels.indexmac.ops import nm_matmul
from repro.quant.qnmweight import QNMWeight

DEFAULT_PARAM_DTYPE = jnp.float32
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16

_COMPUTE = {"dtype": DEFAULT_COMPUTE_DTYPE}


def get_compute_dtype():
    return _COMPUTE["dtype"]


def set_compute_dtype(dt) -> None:
    """Process-wide activation dtype (tests flip to f32 to separate
    numerics from logic; training/serving use bf16)."""
    _COMPUTE["dtype"] = dt


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


def sparse_applies(sp: Optional[SparsityConfig], target: str, in_dim: int) -> bool:
    return (
        sp is not None
        and target in sp.targets
        and in_dim % sp.nm_for(target).m == 0
    )


def linear_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    sp: Optional[SparsityConfig] = None,
    target: str = "dense",
    param_dtype=DEFAULT_PARAM_DTYPE,
    scale: Optional[float] = None,
):
    """Returns ``{"w": ...}`` (dense) or a typed sparse weight node.

    ``sp`` routes *initialization only*: which targets are sparsified,
    at which N:M pattern (per-target overrides allowed), in which mode.
    The resulting node carries all of that as its own metadata — apply
    paths never see the SparsityConfig again.
    """
    scale = scale if scale is not None else in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    if not sparse_applies(sp, target, in_dim):
        return {"w": w.astype(param_dtype)}
    nm = sp.nm_for(target)
    mask = prune_mask_nm(w, nm, axis=0)
    if sp.mode == "masked":
        # dense storage; forward re-derives the top-N:M mask (SR-STE style)
        return MaskedNMWeight(
            w=apply_mask(w, mask).astype(param_dtype), nm=nm, axis=0
        )
    vals, idx = compress_nm(apply_mask(w, mask), nm, axis=0)
    return NMWeight(
        vals=vals.astype(param_dtype), idx=idx, nm=nm, axis=0,
        kernel_policy=KernelPolicy("auto" if sp.use_kernel else "off"),
    )


def linear_apply(
    params,
    x: jax.Array,
    *,
    compute_dtype=None,
    epilogue: Optional[Epilogue] = None,
) -> jax.Array:
    """y = epilogue(x @ W). Dispatches on the weight node's type:
    NMWeight goes to the indexmac kernel path (its own nm/policy),
    QNMWeight to the int8 dequantizing kernel family, MaskedNMWeight
    re-projects onto the N:M constraint set (straight-through grads),
    ``{"w": ...}`` is a plain dense GEMM.

    ``epilogue`` (an :class:`repro.kernels.epilogue.Epilogue`: bias +
    activation name) rides through to ``nm_matmul`` for the compressed
    types — decode-shaped calls fuse it into the kernel writeback — and
    is applied with the identical f32 composition for the dense/masked
    kinds, so swapping a layer's weight representation never changes the
    epilogue arithmetic."""
    compute_dtype = compute_dtype or get_compute_dtype()
    xc = x.astype(compute_dtype)
    if isinstance(params, QNMWeight):
        # int8 payload stays int8 — dequantization happens in-register
        # inside the kernel (scales at accumulator writeback); only the
        # activation follows the compute dtype.
        return nm_matmul(xc, params, epilogue=epilogue)
    if isinstance(params, NMWeight):
        return nm_matmul(xc, params.astype(compute_dtype), epilogue=epilogue)
    if isinstance(params, MaskedNMWeight):
        # re-project every forward; gradients flow to all entries
        # (straight-through), pruned entries can revive.
        y = jnp.einsum("...k,kn->...n", xc,
                       params.project().astype(compute_dtype))
        return _dense_epilogue(y, epilogue)
    if not isinstance(params, dict) or "w" not in params:
        raise TypeError(
            "linear_apply expects an NMWeight, a MaskedNMWeight, or dense "
            f"{{'w': ...}} params; got {type(params).__name__}. Legacy "
            "compressed dicts must be upgraded to the typed representation "
            "(repro.api.sparsify; checkpoints migrate on restore)."
        )
    y = jnp.einsum("...k,kn->...n", xc, params["w"].astype(compute_dtype))
    return _dense_epilogue(y, epilogue)


def _dense_epilogue(y: jax.Array, epilogue: Optional[Epilogue]) -> jax.Array:
    bias, activation = resolve_epilogue(epilogue)
    if bias is None and activation is None:
        return y
    return apply_epilogue_f32(
        y.astype(jnp.float32), bias, activation).astype(y.dtype)


def linear_weight_dense(params) -> jax.Array:
    """Materialize the *effective* dense weight (tests / export): what
    the forward pass multiplies by. For masked weights that is the N:M
    projection, matching ``repro.api.densify`` — the raw (unpruned)
    training storage is ``params.w``."""
    if isinstance(params, (NMWeight, QNMWeight)):
        return params.to_dense()
    if isinstance(params, MaskedNMWeight):
        return params.project()
    return params["w"]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, param_dtype=DEFAULT_PARAM_DTYPE) -> dict:
    return {"scale": jnp.ones((d,), dtype=param_dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * params["scale"].astype(x.dtype)


def layernorm_init(d: int, param_dtype=DEFAULT_PARAM_DTYPE) -> dict:
    return {
        "scale": jnp.ones((d,), dtype=param_dtype),
        "bias": jnp.zeros((d,), dtype=param_dtype),
    }


def layernorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * params["scale"].astype(x.dtype) + params[
        "bias"
    ].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., s, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def embedding_init(
    key: jax.Array, vocab: int, d: int, param_dtype=DEFAULT_PARAM_DTYPE
) -> dict:
    e = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * (d ** -0.5)
    return {"embedding": e.astype(param_dtype)}


def embedding_apply(params: dict, tokens: jax.Array, compute_dtype=None):
    return params["embedding"].astype(compute_dtype or get_compute_dtype())[tokens]


def embedding_attend(params: dict, x: jax.Array) -> jax.Array:
    """Tied output head: logits = x @ E^T (fp32 logits)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32),
        params["embedding"].astype(jnp.float32),
    )
