"""Shared model building blocks: linear (dense / N:M sparse), norms,
rotary embeddings, token embedding.

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of pure functions `*_init(key, ...) -> params` / `*_apply(params, x)`.
Sparsity is integrated at the linear layer: a linear created with a target
tag that the model's SparsityConfig covers stores compressed (vals, idx)
parameters and dispatches to the indexmac kernel / XLA reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SparsityConfig
from repro.core.sparsity import (
    NMConfig,
    apply_mask,
    compress_nm,
    decompress_nm,
    prune_mask_nm,
)
from repro.kernels.indexmac.ops import nm_matmul

DEFAULT_PARAM_DTYPE = jnp.float32
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16

_COMPUTE = {"dtype": DEFAULT_COMPUTE_DTYPE}


def get_compute_dtype():
    return _COMPUTE["dtype"]


def set_compute_dtype(dt) -> None:
    """Process-wide activation dtype (tests flip to f32 to separate
    numerics from logic; training/serving use bf16)."""
    _COMPUTE["dtype"] = dt


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


def sparse_applies(sp: Optional[SparsityConfig], target: str, in_dim: int) -> bool:
    return (
        sp is not None
        and target in sp.targets
        and in_dim % sp.nm.m == 0
    )


def linear_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    sp: Optional[SparsityConfig] = None,
    target: str = "dense",
    param_dtype=DEFAULT_PARAM_DTYPE,
    scale: Optional[float] = None,
) -> dict:
    scale = scale if scale is not None else in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    if not sparse_applies(sp, target, in_dim):
        return {"w": w.astype(param_dtype)}
    mask = prune_mask_nm(w, sp.nm, axis=0)
    if sp.mode == "masked":
        # dense storage; forward re-derives the top-N:M mask (SR-STE style)
        return {"w": apply_mask(w, mask).astype(param_dtype)}
    vals, idx = compress_nm(apply_mask(w, mask), sp.nm, axis=0)
    return {"vals": vals.astype(param_dtype), "idx": idx}


def linear_apply(
    params: dict,
    x: jax.Array,
    *,
    sp: Optional[SparsityConfig] = None,
    compute_dtype=None,
) -> jax.Array:
    compute_dtype = compute_dtype or get_compute_dtype()
    xc = x.astype(compute_dtype)
    if "vals" in params:  # compressed N:M
        assert sp is not None
        return nm_matmul(
            xc, params["vals"].astype(compute_dtype), params["idx"],
            sp.nm, sp.use_kernel,
        )
    w = params["w"]
    if sp is not None and sp.mode == "masked" and w.ndim == 2 and (
        w.shape[0] % sp.nm.m == 0
    ):
        # re-project onto the N:M constraint set every forward; gradients
        # flow to all entries (straight-through), pruned entries can revive.
        w = apply_mask(w, prune_mask_nm(w, sp.nm, axis=0))
    return jnp.einsum("...k,kn->...n", xc, w.astype(compute_dtype))


def linear_weight_dense(params: dict, nm: Optional[NMConfig] = None) -> jax.Array:
    """Materialize the dense weight (tests / export)."""
    if "vals" in params:
        return decompress_nm(params["vals"], params["idx"], nm, axis=0)
    return params["w"]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, param_dtype=DEFAULT_PARAM_DTYPE) -> dict:
    return {"scale": jnp.ones((d,), dtype=param_dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * params["scale"].astype(x.dtype)


def layernorm_init(d: int, param_dtype=DEFAULT_PARAM_DTYPE) -> dict:
    return {
        "scale": jnp.ones((d,), dtype=param_dtype),
        "bias": jnp.zeros((d,), dtype=param_dtype),
    }


def layernorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * params["scale"].astype(x.dtype) + params[
        "bias"
    ].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., s, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def embedding_init(
    key: jax.Array, vocab: int, d: int, param_dtype=DEFAULT_PARAM_DTYPE
) -> dict:
    e = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * (d ** -0.5)
    return {"embedding": e.astype(param_dtype)}


def embedding_apply(params: dict, tokens: jax.Array, compute_dtype=None):
    return params["embedding"].astype(compute_dtype or get_compute_dtype())[tokens]


def embedding_attend(params: dict, x: jax.Array) -> jax.Array:
    """Tied output head: logits = x @ E^T (fp32 logits)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32),
        params["embedding"].astype(jnp.float32),
    )
