"""Mixture-of-Experts with sort-based token dispatch and fixed capacity.

Dispatch is built from XLA-native sort/scatter/gather so it partitions
under pjit: the expert buffer (E, C, D) is sharded over the "model" axis
(expert parallelism); token movement between the data-sharded token axis
and the expert-sharded buffer lowers to all-to-all style collectives chosen
by the SPMD partitioner.

Expert FFN weights are sparse-eligible (target "expert") — for DeepSeek-V2
expert weights dominate total bytes, making them the paper technique's
biggest beneficiary (DESIGN.md §6).

Routing follows DeepSeek-V2: softmax scores, top-k selection, no renorm,
plus n_shared always-active shared experts; aux load-balance loss returned
to the caller.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FFNConfig, MoEConfig, SparsityConfig
from repro.models.common import linear_apply, linear_init
from repro.models.ffn import ffn_apply, ffn_init
from repro.parallel.hints import shard_hint


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def capacity(tokens: int, cfg: MoEConfig) -> int:
    return max(8, _round_up(int(tokens * cfg.top_k / cfg.n_experts
                                 * cfg.capacity_factor), 8))


def moe_init(
    key: jax.Array,
    d_model: int,
    cfg: MoEConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_experts)
    router = linear_init(ks[0], d_model, cfg.n_experts, sp=None,
                         target="router", param_dtype=jnp.float32)
    expert_keys = jnp.stack(list(ks[2:]))
    experts = jax.vmap(
        lambda k: ffn_init(
            k, d_model, FFNConfig(d_ff=cfg.d_expert, act=cfg.act),
            sp=sp, param_dtype=param_dtype, target="expert",
        )
    )(expert_keys)
    p = {"router": router, "experts": experts}
    if cfg.n_shared:
        p["shared"] = ffn_init(
            ks[1], d_model, FFNConfig(d_ff=cfg.n_shared * cfg.d_expert, act=cfg.act),
            sp=sp, param_dtype=param_dtype, target="expert",
        )
    return p


def _expert_ffn(params, xe: jax.Array, cfg: MoEConfig):
    """xe: (E, C, D) -> (E, C, D), vmapped over the expert axis."""
    fcfg = FFNConfig(d_ff=cfg.d_expert, act=cfg.act)
    return jax.vmap(lambda pp, xx: ffn_apply(pp, xx, fcfg))(params, xe)


def moe_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: MoEConfig,
):
    """Returns (y, aux_loss). Dispatches to the shard_map expert-parallel
    path under an active multi-device mesh, else the single-device path."""
    from repro.parallel.hints import _active_mesh

    mesh = _active_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.n_experts % mesh.shape["model"] == 0:
        return _moe_apply_shard_map(params, x, cfg, mesh)
    return _moe_apply_local(params, x, cfg)


def _moe_apply_local(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: MoEConfig,
):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    k = cfg.top_k
    e = cfg.n_experts
    c = capacity(t, cfg)

    xf = shard_hint(xf, ("pod", "data"), None)
    logits = linear_apply(params["router"], xf,
                          compute_dtype=jnp.float32)  # (T, E) fp32
    scores = jax.nn.softmax(logits, axis=-1)
    gate_w, sel = jax.lax.top_k(scores, k)  # (T, k)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = sel.reshape(-1)  # (T*k,) expert id per expanded token
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    # rank within expert: position among same-expert entries
    starts = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype),
                              side="left")
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < c
    token_of = order // k
    # 3D scatter with OOB drop: overflow (rank >= c) lands out of bounds.
    gathered = shard_hint(xf[token_of], ("pod", "data"), None)
    buf = jnp.zeros((e, c, d), dtype=x.dtype).at[sorted_e, rank].set(
        gathered, mode="drop")
    buf = shard_hint(buf, "model", None, None)

    h = _expert_ffn(params["experts"], buf, cfg)  # (E, C, D)
    h = shard_hint(h, "model", None, None)

    out_sorted = jnp.where(
        keep[:, None],
        h[sorted_e, jnp.minimum(rank, c - 1)], 0.0)
    out_sorted = shard_hint(out_sorted, ("pod", "data"), None)
    # unsort and combine with gate weights
    out_flat = jnp.zeros((t * k, d), dtype=h.dtype).at[order].set(out_sorted)
    out_flat = shard_hint(out_flat, ("pod", "data"), None)
    y = (out_flat.reshape(t, k, d)
         * gate_w.astype(h.dtype)[..., None]).sum(axis=1)
    y = shard_hint(y, ("pod", "data"), None)

    if "shared" in params:
        y = y + ffn_apply(
            params["shared"], xf,
            FFNConfig(d_ff=cfg.n_shared * cfg.d_expert, act=cfg.act),
        )

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    dispatch_frac = jnp.mean(
        (jax.nn.one_hot(sel, e, dtype=jnp.float32)).sum(1), axis=0
    ) / k
    router_prob = jnp.mean(scores, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(dispatch_frac * router_prob)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (device-limited routing)
#
# Activations are batch-sharded over ("pod","data") and replicated over
# "model"; experts are sharded over "model" (EP). Every model-rank routes
# its local tokens, computes ONLY its own experts over them, and the
# partial outputs are psum'd over "model" — the same reduction a
# tensor-parallel dense FFN would do. All gathers/sorts are shard-local,
# so the SPMD partitioner never rewrites them (the pure-pjit path
# materializes per-element u32 index maps for cross-shard scatter — the
# dominant memory term before this path existed; see EXPERIMENTS.md §Perf).
#
# Capacity and the balance aux are per data shard (GShard "group"
# semantics): drops are local, and aux equals the global loss up to the
# across-group variance (tests/test_moe_distributed.py).
# ---------------------------------------------------------------------------


def _moe_apply_shard_map(params, x, cfg: MoEConfig, mesh):
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    axes = mesh.axis_names
    # DP axes limited to those dividing the batch (decode may have B=1 ->
    # tokens replicated over data, which is the correct degenerate case)
    dp_list: list = []
    size = 1
    for a in ("pod", "data"):
        if a in axes and b % (size * mesh.shape[a]) == 0:
            dp_list.append(a)
            size *= mesh.shape[a]
    dp = tuple(dp_list)
    tp_size = mesh.shape["model"]
    e_loc = e // tp_size

    def local(x_blk, router, experts, shared):
        # x_blk: (B_loc, S, D) — this device's tokens, full model dim
        bl, sl, dl = x_blk.shape
        t_loc = bl * sl
        xf = x_blk.reshape(t_loc, dl)
        c_loc = capacity(t_loc, cfg)
        r = jax.lax.axis_index("model")

        logits = linear_apply(router, xf,
                              compute_dtype=jnp.float32)
        scores = jax.nn.softmax(logits, axis=-1)
        gate_w, sel = jax.lax.top_k(scores, k)  # (T, k)

        flat_e = sel.reshape(-1)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(
            sorted_e, jnp.arange(e, dtype=sorted_e.dtype), side="left")
        counts = jnp.append(starts[1:], t_loc * k) - starts
        rank = jnp.arange(t_loc * k) - starts[sorted_e]
        sorted_x = xf[order // k]  # local gather
        sorted_x = jnp.concatenate(
            [sorted_x, jnp.zeros((c_loc, dl), sorted_x.dtype)], axis=0)

        own = jnp.arange(e_loc) + r * e_loc  # expert ids on this rank
        own_starts = starts[own]
        own_counts = jnp.minimum(counts[own], c_loc)

        def take(st):  # (C_loc, D) slice of the sorted token stream
            return jax.lax.dynamic_slice(sorted_x, (st, 0), (c_loc, dl))

        buf = jax.vmap(take)(own_starts)  # (E_loc, C_loc, D)
        mask = (jnp.arange(c_loc)[None, :]
                < own_counts[:, None])  # (E_loc, C_loc)
        buf = buf * mask[..., None].astype(buf.dtype)

        h = _expert_ffn(experts, buf, cfg)  # (E_loc, C_loc, D)
        h = (h * mask[..., None].astype(h.dtype)).reshape(e_loc * c_loc, dl)

        # local combine: row for sorted slot i lives at
        # (sorted_e[i]-r*e_loc)*C_loc + rank[i] when this rank owns it
        owned = (sorted_e >= r * e_loc) & (sorted_e < (r + 1) * e_loc) \
            & (rank < c_loc)
        hidx = jnp.clip((sorted_e - r * e_loc) * c_loc + rank, 0,
                        e_loc * c_loc - 1)
        out_sorted = jnp.where(owned[:, None], h[hidx], 0)
        inv = jnp.argsort(order)  # unsort by inverse permutation (gather)
        out_flat = out_sorted[inv]
        y = (out_flat.reshape(t_loc, k, dl)
             * gate_w.astype(out_flat.dtype)[..., None]).sum(axis=1)

        if shared is not None:
            # shared experts run TP-style: hidden dim pre-sharded over
            # "model" in the param specs -> partial sums here
            y = y + ffn_apply(
                shared, xf,
                FFNConfig(d_ff=cfg.n_shared * cfg.d_expert // tp_size,
                          act=cfg.act))

        y = jax.lax.psum(y, "model")

        dispatch_frac = jnp.mean(
            jax.nn.one_hot(sel, e, dtype=jnp.float32).sum(1), axis=0) / k
        router_prob = jnp.mean(scores, axis=0)
        aux = cfg.router_aux_coef * e * jnp.sum(dispatch_frac * router_prob)
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return y.reshape(bl, sl, dl), aux

    shared = params.get("shared")
    # param blocks: experts sharded over model on E; router replicated;
    # shared-expert hidden sharded over model (column/row parallel pair)
    expert_specs = jax.tree.map(lambda _: P("model"), params["experts"])
    shared_specs = None
    if shared is not None:
        shared_specs = {
            k_: jax.tree.map(
                lambda _: P("model", None) if k_ == "w_down"
                else P(None, "model"), v)
            for k_, v in shared.items()
        }
    in_specs = (P(dp, None, None),
                jax.tree.map(lambda _: P(), params["router"]),
                expert_specs, shared_specs)
    from repro import compat

    fn = compat.shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=(P(dp, None, None), P()), check_vma=False)
    return fn(x, params["router"], params["experts"], shared)
