"""CacheView: the typed cache-addressing struct threaded through
``LM.forward`` -> ``group_apply`` -> ``block_apply`` -> the attention
mixers.

One PR ago every apply surface took five loose keywords (``mode``,
``positions``, ``cache_len``, ``block_table``, ``write_mask``) whose
validity rules lived in asserts scattered across call sites. A
:class:`CacheView` carries them as one registered pytree node: the
execution ``mode`` is static treedef metadata (it selects traced
branches), the addressing arrays are leaves (they jit/vmap/shard like
any array).

Modes:

  train    no cache; positions default to arange(S).
  prefill  positions from 0; the cache is overwritten from slot 0.
  decode   one token per slot at offset ``cache_len``.
  chunk    an s-token prompt piece at offset ``cache_len`` (continuous
           batching); causal masking via absolute ``positions``.

``block_table`` (+ ``write_mask``) switches decode/chunk addressing to
the paged cache layout. ``positions`` is derived inside ``LM.forward``
from ``cache_len`` — callers building views by hand normally leave it
None.

Migration: the old keywords still work for one release through
:func:`view_from_legacy_kwargs` (every public apply surface routes its
``**kw`` here); they emit a ``DeprecationWarning`` whose message starts
with ``repro.models.cache`` — escalated to an error for first-party
code via pytest filterwarnings — and are banned at internal call sites
by the API-freeze test.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

from repro import compat

_MODES = ("train", "prefill", "decode", "chunk")

LEGACY_KEYS = ("mode", "positions", "cache_len", "block_table", "write_mask")


class AttnKwargError(TypeError):
    """An attention apply surface received a keyword it does not accept
    (or one that is invalid for the resolved cache kind). Raised instead
    of the old silent ``**kw`` drop."""


@dataclasses.dataclass(frozen=True)
class CacheView:
    """How this forward call addresses the KV cache (see module doc).

    ``mode`` is static (branch selection); the rest are array leaves
    (or None). Prefer the classmethods — they validate presence rules;
    the raw constructor stays permissive for internal threading (e.g.
    cross-attention re-views with a different mode).
    """

    mode: str = "train"
    cache_len: Optional[Any] = None
    block_table: Optional[Any] = None
    write_mask: Optional[Any] = None
    positions: Optional[Any] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"CacheView.mode must be one of {_MODES}, got {self.mode!r}")

    # ---- constructors ----------------------------------------------------

    @classmethod
    def train(cls, positions=None) -> "CacheView":
        return cls(mode="train", positions=positions)

    @classmethod
    def prefill(cls) -> "CacheView":
        return cls(mode="prefill")

    @classmethod
    def decode(cls, cache_len, *, block_table=None,
               write_mask=None) -> "CacheView":
        return cls._offset("decode", cache_len, block_table, write_mask)

    @classmethod
    def chunk(cls, cache_len, *, block_table=None,
              write_mask=None) -> "CacheView":
        return cls._offset("chunk", cache_len, block_table, write_mask)

    @classmethod
    def _offset(cls, mode, cache_len, block_table, write_mask):
        if cache_len is None:
            raise AttnKwargError(
                f"CacheView.{mode} needs cache_len (the per-slot write "
                f"offset)")
        if (block_table is None) != (write_mask is None):
            raise AttnKwargError(
                "paged addressing needs block_table AND write_mask "
                "(masked slots must write the null page)")
        return cls(mode=mode, cache_len=cache_len,
                   block_table=block_table, write_mask=write_mask)

    # ---- helpers ---------------------------------------------------------

    @property
    def offset_mode(self) -> bool:
        return self.mode in ("decode", "chunk")

    @property
    def paged(self) -> bool:
        return self.block_table is not None

    def with_positions(self, positions) -> "CacheView":
        return dataclasses.replace(self, positions=positions)


compat.register_dataclass(
    CacheView,
    data_fields=("cache_len", "block_table", "write_mask", "positions"),
    meta_fields=("mode",),
)


def view_from_legacy_kwargs(view: Optional[CacheView], kw: dict, *,
                            caller: str) -> Optional[CacheView]:
    """The one-release keyword shim. Pops the legacy addressing keywords
    out of ``kw`` (whatever the caller leaves in ``kw`` afterwards is a
    genuinely unknown keyword -> :class:`AttnKwargError` at the call
    surface), warns, and builds the equivalent view. Mixing ``view=``
    with legacy keywords is an error — two sources of truth."""
    legacy = {k: kw.pop(k) for k in LEGACY_KEYS if k in kw}
    if not legacy:
        return view
    if view is not None:
        raise AttnKwargError(
            f"{caller}: pass either view=CacheView(...) or the deprecated "
            f"keywords {sorted(legacy)}, not both")
    warnings.warn(
        f"repro.models.cache: {caller}({', '.join(sorted(legacy))}) "
        f"keywords are deprecated; pass view=CacheView(...) instead "
        f"(one-release shim)",
        DeprecationWarning, stacklevel=3)
    return CacheView(
        mode=legacy.get("mode", "train"),
        cache_len=legacy.get("cache_len"),
        block_table=legacy.get("block_table"),
        write_mask=legacy.get("write_mask"),
        positions=legacy.get("positions"),
    )
