"""Sparse 2D convolution on the indexmac kernel path, via im2col.

The paper's entire evaluation (§IV) is structured-sparse *CNN* layers
mapped to sparse-dense GEMMs: a conv with HWIO weights ``(kh, kw, C_in,
C_out)`` becomes ``A(M=C_out, K=C_in*kh*kw) x B(K, N=H_out*W_out)``.
This module is that mapping executed on the real kernels:

* :func:`im2col` lowers NHWC activations to patch rows whose feature
  layout ``(kh, kw, C_in)`` matches ``w_hwio.reshape(K, C_out)`` — so a
  conv is exactly ``patches @ W2d``.
* :class:`SparseConv2D` holds its weight as the same typed node a linear
  does (:class:`NMWeight` / int8 :class:`QNMWeight` / dense ``{"w"}``),
  compressed along the K = C_in*kh*kw contraction axis. Both value
  families, autotune, shape padding and kernel-policy dispatch apply to
  convs unchanged because the forward *is* ``linear_apply`` on patches.
* :class:`SparseCNN` runs a whole backbone (ResNet-bottleneck or
  DenseNet dense-block topology from a :class:`CNNConfig`), and
  :func:`cnn_layer_specs` / :func:`cnn_layer_gemms` derive the per-layer
  conv list and the paper's im2col GEMM table from the same config —
  ``benchmarks/cnn_specs.py`` and the measured fig4/5/6 benchmarks both
  consume it.

Gradients work end-to-end: im2col is pure (differentiable) slicing and
``nm_matmul`` brings its custom VJP, so :class:`SparseConv2D` trains the
compressed representation directly (straight-through on idx).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import (
    BottleneckStage,
    CNNConfig,
    ConvSpec,
    DenseStage,
    SparsityConfig,
)
from repro.models.common import linear_apply, linear_init

__all__ = [
    "im2col",
    "conv2d",
    "SparseConv2D",
    "SparseCNN",
    "ConvLayer",
    "cnn_layer_specs",
    "cnn_layer_gemms",
]


# ---------------------------------------------------------------------------
# im2col lowering
# ---------------------------------------------------------------------------


def _same_pads(size: int, k: int, s: int) -> tuple[int, int]:
    """XLA 'SAME' split: total = max((ceil(size/s)-1)*s + k - size, 0)."""
    out = -(-size // s)
    pad = max((out - 1) * s + k - size, 0)
    return pad // 2, pad - pad // 2


def _out_dim(size: int, k: int, s: int, padding: str) -> int:
    if padding == "SAME":
        return -(-size // s)
    return (size - k) // s + 1


def im2col(
    x: jax.Array,
    kh: int,
    kw: int,
    *,
    stride: Union[int, tuple[int, int]] = 1,
    padding: str = "SAME",
) -> jax.Array:
    """NHWC activations -> im2col patch rows.

    x: (..., H, W, C) -> (..., H_out, W_out, kh*kw*C). The patch feature
    layout is ``(kh, kw, C)`` — exactly ``w_hwio.reshape(kh*kw*C, C_out)``
    — so ``im2col(x) @ W2d == lax.conv_general_dilated(x, w_hwio)`` with
    NHWC/HWIO dimension numbers. Pure slicing: differentiable, jit-safe.
    """
    sh, sw = (stride, stride) if isinstance(stride, int) else stride
    if padding not in ("SAME", "VALID"):
        raise ValueError(f"padding must be 'SAME' or 'VALID', got {padding!r}")
    *_, h, w, _c = x.shape
    ho = _out_dim(h, kh, sh, padding)
    wo = _out_dim(w, kw, sw, padding)
    if ho <= 0 or wo <= 0:
        raise ValueError(
            f"conv window ({kh}x{kw}, stride {sh}x{sw}, {padding}) does not "
            f"fit the {h}x{w} input")
    if padding == "SAME":
        pt, pb = _same_pads(h, kh, sh)
        pl, pr = _same_pads(w, kw, sw)
        pad = [(0, 0)] * (x.ndim - 3) + [(pt, pb), (pl, pr), (0, 0)]
        x = jnp.pad(x, pad)
    cols = [
        x[..., i: i + (ho - 1) * sh + 1: sh, j: j + (wo - 1) * sw + 1: sw, :]
        for i in range(kh)
        for j in range(kw)
    ]
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=-1)


def conv2d(
    x: jax.Array,
    w,
    *,
    kh: int,
    kw: int,
    stride: Union[int, tuple[int, int]] = 1,
    padding: str = "SAME",
    compute_dtype=None,
) -> jax.Array:
    """y = conv(x, W) through the im2col GEMM on the kernel path.

    ``w`` is any linear-weight node over the flattened contraction axis:
    an :class:`NMWeight`/:class:`QNMWeight` compressed along
    K = C_in*kh*kw (axis 0), or dense ``{"w": (K, C_out)}``. Dispatch
    (reference vs Pallas, block triple, float vs int8 family) follows the
    weight's own metadata, exactly as for a linear layer.
    """
    patches = im2col(x, kh, kw, stride=stride, padding=padding)
    return linear_apply(w, patches, compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# SparseConv2D layer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparseConv2D:
    """A conv layer whose weight is the typed sparse node of a linear.

    ``init`` produces the weight node (the params *are* the node — same
    convention as ``linear_init``); ``apply`` is im2col + linear_apply.
    int8 execution needs no support here: ``repro.api.quantize`` /
    ``quantize_tree`` turn the NMWeight into a QNMWeight and ``apply``
    dispatches on the type unchanged.
    """

    spec: ConvSpec

    def init(
        self,
        key: jax.Array,
        *,
        sp: Optional[SparsityConfig] = None,
        param_dtype=jnp.float32,
    ):
        return linear_init(
            key, self.spec.k_gemm, self.spec.c_out,
            sp=sp, target=self.spec.target, param_dtype=param_dtype,
        )

    def apply(self, params, x: jax.Array, *, compute_dtype=None) -> jax.Array:
        s = self.spec
        return conv2d(x, params, kh=s.kh, kw=s.kw, stride=s.stride,
                      padding=s.padding, compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# per-layer walker: the conv list / GEMM table of a CNNConfig
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """A :class:`ConvSpec` placed at its resolved input resolution."""

    spec: ConvSpec
    h_in: int
    w_in: int

    @property
    def h_out(self) -> int:
        return self.spec.out_hw(self.h_in, self.w_in)[0]

    @property
    def w_out(self) -> int:
        return self.spec.out_hw(self.h_in, self.w_in)[1]

    @property
    def gemm(self) -> tuple[str, int, int, int]:
        """(name, M=C_out, K=C_in*kh*kw, N=H_out*W_out) — paper §IV."""
        from repro.core.cost_model import conv_gemm_dims  # lazy, no cycle

        s = self.spec
        return (s.name, *conv_gemm_dims(s.c_out, s.c_in, s.kh, s.kw,
                                        self.h_out, self.w_out))


def cnn_layer_specs(cfg: CNNConfig) -> list[ConvLayer]:
    """Every conv of the backbone in execution order, with resolved
    channel counts and spatial resolutions."""
    layers = [ConvLayer(cfg.stem, cfg.input_hw, cfg.input_hw)]
    hw = layers[0].h_out
    if cfg.stem_pool > 1:
        hw = -(-hw // cfg.stem_pool)
    ch = cfg.stem.c_out

    def conv(name, c_in, c_out, k=1, stride=1, at=None, target="conv"):
        layers.append(ConvLayer(
            ConvSpec(name, c_in, c_out, k, k, stride, target=target),
            at, at))

    if cfg.kind == "resnet":
        for si, st in enumerate(cfg.stages):
            assert isinstance(st, BottleneckStage), st
            for b in range(st.blocks):
                tag = f"s{si + 2}b{b + 1}"
                stride = st.stride if b == 0 else 1
                conv(f"{tag}_1x1a", ch, st.mid, 1, stride, at=hw)
                hw_out = -(-hw // stride)
                conv(f"{tag}_3x3", st.mid, st.mid, 3, at=hw_out)
                conv(f"{tag}_1x1b", st.mid, st.out, 1, at=hw_out)
                if b == 0:
                    conv(f"{tag}_proj", ch, st.out, 1, stride, at=hw,
                         target="proj")
                ch = st.out
                hw = hw_out
    elif cfg.kind == "densenet":
        for bi, st in enumerate(cfg.stages):
            assert isinstance(st, DenseStage), st
            for li in range(st.layers):
                tag = f"d{bi + 1}l{li + 1}"
                conv(f"{tag}_1x1", ch, 4 * st.growth, 1, at=hw)
                conv(f"{tag}_3x3", 4 * st.growth, st.growth, 3, at=hw)
                ch += st.growth
            if bi < len(cfg.stages) - 1:
                conv(f"t{bi + 1}_1x1", ch, ch // 2, 1, at=hw)
                ch //= 2
                hw = -(-hw // 2)  # ceil: matches the SAME-padded avg-pool
    else:
        raise ValueError(f"unknown CNN kind {cfg.kind!r}")
    return layers


def cnn_layer_gemms(cfg: CNNConfig) -> list[tuple[str, int, int, int]]:
    """The paper's im2col GEMM table: (name, M=C_out, K, N=H_out*W_out)."""
    return [layer.gemm for layer in cnn_layer_specs(cfg)]


def cnn_final_channels(cfg: CNNConfig) -> int:
    """Channel count entering the classifier head."""
    if cfg.kind == "resnet":
        return cfg.stages[-1].out
    ch = cfg.stem.c_out
    for bi, st in enumerate(cfg.stages):
        ch += st.layers * st.growth
        if bi < len(cfg.stages) - 1:
            ch //= 2
    return ch


# ---------------------------------------------------------------------------
# SparseCNN forward model
# ---------------------------------------------------------------------------


def _max_pool(x: jax.Array, k: int = 3, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating)
        else jnp.iinfo(x.dtype).min,
        jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "SAME")


def _avg_pool(x: jax.Array, k: int = 2, stride: int = 2) -> jax.Array:
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        (1, k, k, 1), (1, stride, stride, 1), "SAME")
    return (s / (k * k)).astype(x.dtype)


class SparseCNN:
    """A CNN backbone executing every conv through the sparse GEMM path.

    Params are ``{"convs": {layer_name: weight_node}, "head": {"w"}}`` —
    conv weight nodes are exactly what ``linear_init`` produces over the
    flattened K = C_in*kh*kw axis, so ``repro.api.quantize_tree``, the
    optimizer, sharding and checkpointing all treat a CNN like any other
    model. Topology (residual adds, dense-block concats, transitions)
    comes from the :class:`CNNConfig`.
    """

    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg
        self.layers = cnn_layer_specs(cfg)
        self._conv = {l.spec.name: SparseConv2D(l.spec) for l in self.layers}

    def init(self, key: jax.Array, *, param_dtype=jnp.float32):
        sp = self.cfg.sparsity
        keys = jax.random.split(key, len(self.layers) + 1)
        convs = {
            l.spec.name: self._conv[l.spec.name].init(
                k, sp=sp, param_dtype=param_dtype)
            for k, l in zip(keys[:-1], self.layers)
        }
        head = linear_init(
            keys[-1], cnn_final_channels(self.cfg), self.cfg.num_classes,
            sp=None, target="head", param_dtype=param_dtype,
        )
        return {"convs": convs, "head": head}

    def _run(self, convs, name, x, *, compute_dtype):
        return self._conv[name].apply(convs[name], x,
                                      compute_dtype=compute_dtype)

    def apply(self, params, x: jax.Array, *, compute_dtype=None) -> jax.Array:
        """x: (B, H, W, 3) NHWC -> logits (B, num_classes)."""
        cfg = self.cfg
        convs = params["convs"]
        x = jax.nn.relu(self._run(convs, cfg.stem.name, x,
                                  compute_dtype=compute_dtype))
        if cfg.stem_pool > 1:
            x = _max_pool(x, 3, cfg.stem_pool)
        if cfg.kind == "resnet":
            for si, st in enumerate(cfg.stages):
                for b in range(st.blocks):
                    tag = f"s{si + 2}b{b + 1}"
                    h = jax.nn.relu(self._run(convs, f"{tag}_1x1a", x,
                                              compute_dtype=compute_dtype))
                    h = jax.nn.relu(self._run(convs, f"{tag}_3x3", h,
                                              compute_dtype=compute_dtype))
                    h = self._run(convs, f"{tag}_1x1b", h,
                                  compute_dtype=compute_dtype)
                    short = (self._run(convs, f"{tag}_proj", x,
                                       compute_dtype=compute_dtype)
                             if b == 0 else x)
                    x = jax.nn.relu(h + short)
        else:
            for bi, st in enumerate(cfg.stages):
                for li in range(st.layers):
                    tag = f"d{bi + 1}l{li + 1}"
                    h = jax.nn.relu(self._run(convs, f"{tag}_1x1", x,
                                              compute_dtype=compute_dtype))
                    h = self._run(convs, f"{tag}_3x3", h,
                                  compute_dtype=compute_dtype)
                    x = jnp.concatenate([x, h], axis=-1)
                if bi < len(cfg.stages) - 1:
                    x = self._run(convs, f"t{bi + 1}_1x1", jax.nn.relu(x),
                                  compute_dtype=compute_dtype)
                    x = _avg_pool(x, 2, 2)
        x = jnp.mean(x.astype(jnp.float32), axis=(-3, -2))  # global avg pool
        return linear_apply(params["head"], x, compute_dtype=jnp.float32)
