"""Mamba-1 selective-state-space mixer (Jamba's SSM layers).

Training/prefill run the selective scan with lax.scan over time (state
(B, d_inner, d_state) carried in fp32); decode is a single state update.
The in/out/x/dt projections are GEMMs and therefore sparse-eligible
(target "attn_proj" — they play the mixer-projection role); the recurrence
itself is not a GEMM and is left dense (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, SparsityConfig
from repro.models.common import linear_apply, linear_init


def mamba_init(
    key: jax.Array,
    d_model: int,
    cfg: MambaConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
) -> dict:
    d_in = cfg.expand * d_model
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "w_in": linear_init(ks[0], d_model, 2 * d_in, sp=sp, target="attn_proj",
                            param_dtype=param_dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, d_in)) *
                   cfg.d_conv ** -0.5).astype(param_dtype),
        "conv_b": jnp.zeros((d_in,), param_dtype),
        "w_x": linear_init(ks[2], d_in, cfg.dt_rank + 2 * cfg.d_state, sp=sp,
                           target="attn_proj", param_dtype=param_dtype),
        "w_dt": linear_init(ks[3], cfg.dt_rank, d_in, sp=None,
                            param_dtype=param_dtype),
        "dt_bias": jnp.zeros((d_in,), param_dtype),
        "a_log": jnp.log(a).astype(param_dtype),
        "d_skip": jnp.ones((d_in,), param_dtype),
        "w_out": linear_init(ks[4], d_in, d_model, sp=sp, target="attn_proj",
                             param_dtype=param_dtype),
    }


def mamba_empty_cache(batch: int, d_model: int, cfg: MambaConfig,
                      dtype=jnp.float32) -> dict:
    d_in = cfg.expand * d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, cfg.d_state), jnp.float32),
    }


def _ssm_params(params, xc, cfg: MambaConfig):
    """xc: (..., d_in) post-conv activations -> (dt, b, c) selective params."""
    proj = linear_apply(params["w_x"], xc)
    dt_raw, b, c = jnp.split(
        proj, [cfg.dt_rank, cfg.dt_rank + cfg.d_state], axis=-1
    )
    dt = jax.nn.softplus(
        linear_apply(params["w_dt"], dt_raw)
        + params["dt_bias"].astype(dt_raw.dtype)
    )
    return dt, b, c


def mamba_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: MambaConfig,
    *,
    mode: str,
    cache: Optional[dict] = None,
    **_,
):
    bsz, s, d_model = x.shape
    d_in = cfg.expand * d_model
    xz = linear_apply(params["w_in"], x)
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_w = params["conv_w"].astype(xin.dtype)  # (d_conv, d_in)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (d_in, n)
    d_skip = params["d_skip"].astype(jnp.float32)

    if mode == "decode":
        assert cache is not None
        hist = jnp.concatenate([cache["conv"].astype(xin.dtype), xin], axis=1)
        xc = jnp.einsum("bkd,kd->bd", hist, conv_w) + params["conv_b"].astype(
            xin.dtype
        )
        xc = jax.nn.silu(xc)
        dt, b, c = _ssm_params(params, xc, cfg)
        dtf = dt.astype(jnp.float32)
        da = jnp.exp(dtf[:, :, None] * a[None])  # (B, d_in, n)
        dbx = (dtf * xc.astype(jnp.float32))[:, :, None] * b.astype(jnp.float32)[
            :, None, :
        ]
        ssm = cache["ssm"] * da + dbx
        y = jnp.einsum("bdn,bn->bd", ssm, c.astype(jnp.float32)) + d_skip * xc.astype(
            jnp.float32
        )
        y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0])).reshape(bsz, 1, d_in)
        new_cache = {"conv": hist[:, 1:], "ssm": ssm}
    else:
        # causal depthwise conv over time
        pad = jnp.zeros((bsz, cfg.d_conv - 1, d_in), xin.dtype)
        xin_p = jnp.concatenate([pad, xin], axis=1)
        xc = sum(
            xin_p[:, i : i + s] * conv_w[i] for i in range(cfg.d_conv)
        ) + params["conv_b"].astype(xin.dtype)
        xc = jax.nn.silu(xc)
        dt, b, c = _ssm_params(params, xc, cfg)
        dtf = dt.astype(jnp.float32)
        da = jnp.exp(dtf[..., None] * a[None, None])  # (B,S,d_in,n)
        dbx = (dtf * xc.astype(jnp.float32))[..., None] * b.astype(jnp.float32)[
            :, :, None, :
        ]

        def step(h, inp):
            da_t, dbx_t, c_t = inp
            h = h * da_t + dbx_t
            y_t = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y_t

        h0 = (cache["ssm"] if (cache is not None and mode == "prefill")
              else jnp.zeros((bsz, d_in, cfg.d_state), jnp.float32))
        hT, ys = jax.lax.scan(
            step, h0,
            (da.swapaxes(0, 1), dbx.swapaxes(0, 1),
             c.astype(jnp.float32).swapaxes(0, 1)),
        )
        y = ys.swapaxes(0, 1) + d_skip * xc.astype(jnp.float32)
        y = y.astype(x.dtype) * jax.nn.silu(z)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": xin_p[:, s:][:, -(cfg.d_conv - 1):].astype(
                jnp.float32) if cfg.d_conv > 1 else xin[:, :0],
                "ssm": hT}
            new_cache["conv"] = jnp.concatenate(
                [pad.astype(jnp.float32), xin.astype(jnp.float32)], axis=1
            )[:, -(cfg.d_conv - 1):]
    out = linear_apply(params["w_out"], y)
    return out, new_cache
