"""Attention mixers: GQA (with RoPE, sliding window, qk-norm) and MLA
(DeepSeek-V2 multi-head latent attention), with three execution modes:

  train    — full-sequence, chunked flash-style softmax (lax.scan over KV
             chunks, fp32 running max/denominator) so the Sq x Skv score
             matrix is never materialized; O(Sq * chunk) memory.
  prefill  — same math as train; additionally returns the KV cache laid
             out (B, S, ...) so decode can shard S over the model axis.
  decode   — single new token against the cache. Written as plain reductions
             over the (sharded) cache axis so SPMD lowers them to
             all-reduces; MLA uses the weight-absorbed form and attends
             directly over the compressed c_kv cache.

All projections are sparse-eligible (target "attn_proj") — the paper's
technique applied to attention GEMMs. Sparsity routing happens at init;
the typed weight nodes are self-describing, so apply paths take no
sparsity config.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, SparsityConfig
from repro.kernels.blocksparse_attn import ops as bs_ops
from repro.kernels.blocksparse_attn.ref import jnp_token_mask
from repro.models.cache import (
    AttnKwargError,
    CacheView,
    view_from_legacy_kwargs,
)
from repro.models.common import (
    DEFAULT_COMPUTE_DTYPE,
    apply_rope,
    linear_apply,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
)

from repro.core.dots import acc_einsum  # noqa: E402  (shared dot policy)
from repro.parallel.hints import tp_reduce

NEG_INF = -1e30


class CacheLenError(ValueError):
    """A concrete ``cache_len`` would write outside the cache bounds."""


def _check_cache_len(cache_len, s: int, max_seq: int) -> None:
    """Bounds-check concrete offsets; traced values can't be inspected,
    so inside jit the scatters below carry an explicit ``mode="drop"``
    (out-of-range writes are discarded, never wrapped around)."""
    if isinstance(cache_len, jax.core.Tracer):
        return
    cl = np.asarray(cache_len)
    if (cl < 0).any() or (cl + s > max_seq).any():
        raise CacheLenError(
            f"cache_len={cl.tolist()} with a {s}-token write exceeds "
            f"cache bounds [0, {max_seq}]")


def _write_cache(cache_arr: jax.Array, new: jax.Array,
                 cache_len: jax.Array) -> jax.Array:
    """Write an s-token update starting at position cache_len.

    cache_len scalar: same position for the whole batch (dry-run shapes).
    cache_len (B,): per-slot positions (continuous batching / chunked
    prefill — each slot's chunk lands at its own offset).
    new: (B, s, ...) slice to write into cache (B, S, ...).

    Concrete out-of-range offsets raise :class:`CacheLenError`; traced
    ones drop the out-of-range rows (scatter mode="drop") rather than
    silently wrapping around.
    """
    s = new.shape[1]
    _check_cache_len(cache_len, s, cache_arr.shape[1])
    if cache_len.ndim == 0:
        start = (0, cache_len) + (0,) * (cache_arr.ndim - 2)
        return jax.lax.dynamic_update_slice(cache_arr,
                                            new.astype(cache_arr.dtype), start)
    b = new.shape[0]
    if s == 1:
        return cache_arr.at[jnp.arange(b), cache_len].set(
            new[:, 0].astype(cache_arr.dtype), mode="drop")
    rows = jnp.arange(b)[:, None]
    cols = cache_len[:, None] + jnp.arange(s)[None, :]
    return cache_arr.at[rows, cols].set(new.astype(cache_arr.dtype),
                                        mode="drop")


# ---------------------------------------------------------------------------
# paged cache: gather/scatter through a block table
# ---------------------------------------------------------------------------


def paged_write(pool: jax.Array, new: jax.Array, cache_len: jax.Array,
                table: jax.Array, write_mask: jax.Array) -> jax.Array:
    """Scatter an s-token update into the page pool via the block table.

    pool: (rows, page_size, ...) — local page pool; row 0 is the null
    page. table: (B, pages_per_slot) int32 of *global* page ids (``%
    rows`` recovers the local row on every shard — the host allocator
    guarantees a slot's pages live in its own shard's sub-pool).
    write_mask: (B,) — masked-off slots (idle, or mid-prefill during a
    decode step) land their writes in the null page instead of page 0 of
    their table row, which may be a *shared prefix* page.
    """
    rows, ps = pool.shape[0], pool.shape[1]
    b, s = new.shape[:2]
    n_pages = table.shape[1]
    pos = cache_len[:, None] + jnp.arange(s)[None, :]           # (B, s)
    page_idx = pos // ps
    ok = (page_idx < n_pages) & write_mask[:, None]
    local = jnp.take_along_axis(
        table, jnp.minimum(page_idx, n_pages - 1), axis=1) % rows
    local = jnp.where(ok, local, 0)                             # null page
    flat = local * ps + pos % ps                                # (B, s)
    pool_flat = pool.reshape((rows * ps,) + pool.shape[2:])
    pool_flat = pool_flat.at[flat].set(new.astype(pool.dtype), mode="drop")
    return pool_flat.reshape(pool.shape)


def paged_gather(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Assemble each slot's logical cache view from its pages.

    (rows, page_size, ...) gathered through (B, pages_per_slot) ->
    (B, pages_per_slot * page_size, ...): a drop-in replacement for the
    slot cache's (B, S, ...) that downstream length masks treat
    identically (positions past ``cache_len`` read unwritten/null pages
    and are masked to exact zeros by the softmax)."""
    rows = pool.shape[0]
    b, n_pages = table.shape
    g = jnp.take(pool, table % rows, axis=0)    # (B, n_pages, ps, ...)
    return g.reshape((b, n_pages * pool.shape[1]) + pool.shape[2:])


def _len_mask(length: jax.Array, s: int) -> jax.Array:
    """valid-position mask; (s,) for scalar length, (B, s) for vector."""
    pos = jnp.arange(s)
    if length.ndim == 0:
        return pos < length
    return pos[None, :] < length[:, None]


def _apply_len_mask(logits: jax.Array, valid: jax.Array) -> jax.Array:
    """logits: (b, ..., s); valid: (s,) scalar-length or (b, s) per-slot."""
    if valid.ndim == 1:
        shape = (1,) * (logits.ndim - 1) + (valid.shape[-1],)
    else:
        shape = (valid.shape[0],) + (1,) * (logits.ndim - 2) + (valid.shape[-1],)
    return jnp.where(valid.reshape(shape), logits, NEG_INF)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention core
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Skv, Hkv, Dk)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    *,
    causal: bool,
    window: Optional[int],
    chunk: int,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks."""
    b, sq, hq, dk = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else dk ** -0.5
    chunk = min(chunk, skv)
    valid_kv = skv
    if skv % chunk:  # pad KV to a chunk multiple; pad keys are masked off
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv += pad
    n_chunks = skv // chunk

    # operands stay in the model dtype; accumulation is f32 via
    # preferred_element_type — casting k/v to f32 materializes full f32
    # copies of the KV stream (measured 2x memory term; EXPERIMENTS §Perf)
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(b, sq, hkv, g, dk)
    kc = k.reshape(b, n_chunks, chunk, hkv, dk).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, hkv, dv).swapaxes(0, 1)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, o = carry  # (b,sq,hkv,g), same, (b,sq,hkv,g,dv)
        kb, vb, c0 = inp
        s = acc_einsum("bqhgd,bchd->bqhgc", qf, kb)  # (b,sq,hkv,g,chunk)
        kv_pos = c0 + jnp.arange(chunk)
        mask = jnp.broadcast_to((kv_pos < valid_kv)[None, :], (sq, chunk))
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        o_new = o * corr[..., None] + acc_einsum(
            "bqhgc,bchd->bqhgd", p.astype(v.dtype), vb)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), dtype=jnp.float32)
    o0 = jnp.zeros((b, sq, hkv, g, dv), dtype=jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, starts))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, sq, Hq, Dk) — sq > 1 only with q_positions
    k: jax.Array,  # (B, S, Hkv, Dk) — S may be sharded over 'model'
    v: jax.Array,  # (B, S, Hkv, Dv)
    *,
    length: jax.Array,  # valid cache length (scalar int32)
    window: Optional[int],
    scale: Optional[float] = None,
    q_positions: Optional[jax.Array] = None,  # (sq,) or (B, sq)
) -> jax.Array:
    """One-token attention as plain (SPMD-friendly) reductions over S.

    With ``q_positions`` (absolute position of every query token) the same
    math serves chunked prefill: each query attends causally — cache slot
    ``j`` is visible iff ``j <= q_pos`` — so a multi-token chunk against
    an already-partially-filled cache reproduces full-prefill masking.
    """
    b, sq, hq, dk = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else dk ** -0.5
    # bf16 operands + f32 accumulation: casting the (sharded, huge) cache
    # to f32 would materialize f32 copies of it every step
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(b, sq, hkv, g, dk)
    logits = acc_einsum("bqhgd,bshd->bqhgs", qf, k.astype(q.dtype))
    pos = jnp.arange(s)
    if q_positions is not None:
        qp = (q_positions if q_positions.ndim == 2
              else q_positions[None, :])  # (B|1, sq)
        valid = pos[None, None, :] <= qp[..., None]  # causal vs cache slots
        if window is not None:
            valid &= pos[None, None, :] > qp[..., None] - window
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
    else:
        valid = _len_mask(length, s)
        if window is not None:
            if length.ndim == 0:
                valid &= pos >= length - window
            else:
                valid &= pos[None, :] >= (length - window)[:, None]
        logits = _apply_len_mask(logits, valid)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(-1, keepdims=True)
    out = acc_einsum("bqhgs,bshd->bqhgd", p.astype(q.dtype),
                     v.astype(q.dtype))
    return out.reshape(b, sq, hq, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(
    key: jax.Array,
    d_model: int,
    cfg: AttnConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
    qk_norm: bool = False,
) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d_model, cfg.q_heads * cfg.head_dim,
                          sp=sp, target="attn_proj", param_dtype=param_dtype),
        "wk": linear_init(ks[1], d_model, cfg.kv_heads * cfg.head_dim,
                          sp=sp, target="attn_proj", param_dtype=param_dtype),
        "wv": linear_init(ks[2], d_model, cfg.kv_heads * cfg.head_dim,
                          sp=sp, target="attn_proj", param_dtype=param_dtype),
        "wo": linear_init(ks[3], cfg.q_heads * cfg.head_dim, d_model,
                          sp=sp, target="attn_proj", param_dtype=param_dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, param_dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, param_dtype)
    return p


def gqa_empty_cache(
    batch: int, max_seq: int, cfg: AttnConfig, dtype=DEFAULT_COMPUTE_DTYPE
) -> dict:
    shape = (batch, max_seq, cfg.kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: AttnConfig,
    *,
    view: Optional[CacheView] = None,
    cache: Optional[dict] = None,
    rope_theta: float = 10_000.0,
    chunk: int = 512,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
    **kw,
):
    """Returns (y, new_cache). ``view`` is the typed cache-addressing
    struct (:class:`repro.models.cache.CacheView`) — mode, positions,
    cache_len and paged addressing in one pytree; None means train.
    cross_kv supplies precomputed encoder K/V for cross-attention
    (whisper); cache is then unused. ``view.block_table`` switches
    decode/chunk to the paged cache: ``cache`` leaves are page pools
    (rows, page_size, ...), writes scatter through the table (masked
    slots into the null page) and reads gather each slot's logical view
    — per-slot ``cache_len`` semantics are unchanged. With ``cfg.mask``
    set, self-attention routes through the block-sparse families.

    The old loose keywords (mode/positions/cache_len/block_table/
    write_mask) still work for one release via the deprecation shim."""
    view = view_from_legacy_kwargs(view, kw, caller="gqa_apply")
    if kw:
        raise AttnKwargError(
            f"gqa_apply got unknown keyword(s) {sorted(kw)}")
    if view is None:
        view = CacheView.train()
    mode = view.mode
    cache_len = view.cache_len
    block_table = view.block_table
    write_mask = view.write_mask
    b, s, _ = x.shape
    positions = view.positions
    if positions is None:
        positions = jnp.arange(s)
    q = linear_apply(params["wq"], x).reshape(b, s, cfg.q_heads, cfg.head_dim)
    if cross_kv is None:
        k = linear_apply(params["wk"], x).reshape(b, s, cfg.kv_heads, cfg.head_dim)
        v = linear_apply(params["wv"], x).reshape(b, s, cfg.kv_heads, cfg.head_dim)
    else:
        k, v = cross_kv
    if "q_norm" in params:
        q = rmsnorm_apply(params["q_norm"], q)
        if cross_kv is None:
            k = rmsnorm_apply(params["k_norm"], k)
    if cfg.rope and cross_kv is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    new_cache = cache
    if mode in ("decode", "chunk") and cross_kv is None:
        assert cache is not None and cache_len is not None
        if block_table is not None:
            assert write_mask is not None
            k_cache = paged_write(cache["k"], k, cache_len,
                                  block_table, write_mask)
            v_cache = paged_write(cache["v"], v, cache_len,
                                  block_table, write_mask)
            new_cache = {"k": k_cache, "v": v_cache}
            k_view = paged_gather(k_cache, block_table)
            v_view = paged_gather(v_cache, block_table)
        else:
            k_view = k_cache = _write_cache(cache["k"], k, cache_len)
            v_view = v_cache = _write_cache(cache["v"], v, cache_len)
            new_cache = {"k": k_cache, "v": v_cache}
        # chunk (multi-token prefill piece): causal masking via absolute
        # query positions; decode (s=1) keeps the plain length mask.
        # cfg.mask swaps in the mask-aware decode family (the spec's own
        # causal/window semantics replace cfg.window).
        if cfg.mask is not None:
            out = bs_ops.bs_attention_decode(
                q, k_view, v_view, spec=cfg.mask, length=cache_len + s,
                q_positions=positions if mode == "chunk" else None,
            )
        else:
            out = decode_attention(
                q, k_view, v_view, length=cache_len + s, window=cfg.window,
                q_positions=positions if mode == "chunk" else None,
            )
    elif mode == "decode":  # cross-attention decode: static KV, full attend
        out = decode_attention(
            q, k, v, length=jnp.int32(k.shape[1]), window=None
        )
    elif cfg.mask is not None and cross_kv is None:
        # block-sparse prefill/train: dispatch the bs_attention family
        # (pair-list kernel / block gather; dense fallback under budgets)
        out = bs_ops.bs_attention(q, k, v, spec=cfg.mask)
    else:
        out = chunked_attention(
            q, k, v, causal=cfg.causal and cross_kv is None,
            window=cfg.window, chunk=chunk,
        )
    if mode == "prefill" and cross_kv is None:
        assert cache is not None
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        )
        new_cache = {"k": k_cache, "v": v_cache}
    # wo is row-parallel under TP serving (local heads in, full d_model
    # out): per-shard output is a partial sum — reduced here only when the
    # serving engine declared the in-axis sharded, identity elsewhere
    y = tp_reduce(linear_apply(params["wo"], out.reshape(b, s, -1)),
                  "attn_out")
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(
    key: jax.Array,
    d_model: int,
    cfg: AttnConfig,
    *,
    sp: Optional[SparsityConfig] = None,
    param_dtype=jnp.float32,
) -> dict:
    ks = jax.random.split(key, 8)
    h = cfg.q_heads
    qk_dim = cfg.nope_head_dim + cfg.rope_head_dim
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = linear_init(ks[0], d_model, cfg.q_lora_rank, sp=sp,
                                target="attn_proj", param_dtype=param_dtype)
        p["q_a_norm"] = rmsnorm_init(cfg.q_lora_rank, param_dtype)
        p["wq_b"] = linear_init(ks[1], cfg.q_lora_rank, h * qk_dim, sp=sp,
                                target="attn_proj", param_dtype=param_dtype)
    else:
        p["wq"] = linear_init(ks[0], d_model, h * qk_dim, sp=sp,
                              target="attn_proj", param_dtype=param_dtype)
    p["wkv_a"] = linear_init(ks[2], d_model, cfg.kv_lora_rank, sp=sp,
                             target="attn_proj", param_dtype=param_dtype)
    p["kv_a_norm"] = rmsnorm_init(cfg.kv_lora_rank, param_dtype)
    p["wk_rope"] = linear_init(ks[3], d_model, cfg.rope_head_dim, sp=sp,
                               target="attn_proj", param_dtype=param_dtype)
    # up-projections from the latent: stored per-head for absorbed decode
    p["w_uk"] = (
        jax.random.normal(ks[4], (h, cfg.kv_lora_rank, cfg.nope_head_dim))
        * cfg.kv_lora_rank ** -0.5
    ).astype(param_dtype)
    p["w_uv"] = (
        jax.random.normal(ks[5], (h, cfg.kv_lora_rank, cfg.v_head_dim))
        * cfg.kv_lora_rank ** -0.5
    ).astype(param_dtype)
    p["wo"] = linear_init(ks[6], h * cfg.v_head_dim, d_model, sp=sp,
                          target="attn_proj", param_dtype=param_dtype)
    return p


def mla_empty_cache(
    batch: int, max_seq: int, cfg: AttnConfig, dtype=DEFAULT_COMPUTE_DTYPE
) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
    }


def _mla_q(params, x, cfg, positions, rope_theta):
    b, s, _ = x.shape
    h = cfg.q_heads
    qk_dim = cfg.nope_head_dim + cfg.rope_head_dim
    if "wq_a" in params:
        cq = rmsnorm_apply(params["q_a_norm"], linear_apply(params["wq_a"], x))
        q = linear_apply(params["wq_b"], cq)
    else:
        q = linear_apply(params["wq"], x)
    q = q.reshape(b, s, h, qk_dim)
    q_nope = q[..., : cfg.nope_head_dim]
    q_rope = apply_rope(q[..., cfg.nope_head_dim:], positions, rope_theta)
    return q_nope, q_rope


def mla_apply(
    params: dict,
    x: jax.Array,
    cfg: AttnConfig,
    *,
    view: Optional[CacheView] = None,
    cache: Optional[dict] = None,
    rope_theta: float = 10_000.0,
    chunk: int = 512,
    **kw,
):
    """MLA self-attention over a :class:`~repro.models.cache.CacheView`
    (same contract as :func:`gqa_apply`; legacy keywords shimmed for one
    release). With ``cfg.mask`` set, train/prefill routes the
    ``bs_attention`` family over the materialized per-head K/V; the
    absorbed decode/chunk path — whose two-term latent logits never form
    (B, S, H, D) K/V operands — applies the spec's token predicate
    inline on the logits instead (no dispatch-family record)."""
    view = view_from_legacy_kwargs(view, kw, caller="mla_apply")
    if kw:
        raise AttnKwargError(
            f"mla_apply got unknown keyword(s) {sorted(kw)}")
    if view is None:
        view = CacheView.train()
    mode = view.mode
    cache_len = view.cache_len
    block_table = view.block_table
    write_mask = view.write_mask
    b, s, _ = x.shape
    positions = view.positions
    if positions is None:
        positions = jnp.arange(s)
    h = cfg.q_heads
    q_nope, q_rope = _mla_q(params, x, cfg, positions, rope_theta)
    ckv = rmsnorm_apply(params["kv_a_norm"], linear_apply(params["wkv_a"], x))
    kr = apply_rope(
        linear_apply(params["wk_rope"], x)[:, :, None, :], positions, rope_theta
    )[:, :, 0, :]  # (b, s, rope_dim), shared across heads

    w_uk = params["w_uk"].astype(q_nope.dtype)  # (h, lora, nope)
    w_uv = params["w_uv"].astype(q_nope.dtype)  # (h, lora, v)
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5

    new_cache = cache
    if mode in ("decode", "chunk"):
        assert cache is not None and cache_len is not None
        if block_table is not None:
            assert write_mask is not None
            ckv_c = paged_write(cache["ckv"], ckv, cache_len,
                                block_table, write_mask)
            kr_c = paged_write(cache["kr"], kr, cache_len,
                               block_table, write_mask)
            new_cache = {"ckv": ckv_c, "kr": kr_c}
            ckv_v = paged_gather(ckv_c, block_table)
            kr_v = paged_gather(kr_c, block_table)
        else:
            ckv_v = ckv_c = _write_cache(cache["ckv"], ckv, cache_len)
            kr_v = kr_c = _write_cache(cache["kr"], kr, cache_len)
            new_cache = {"ckv": ckv_c, "kr": kr_c}
        # absorbed attention over the compressed cache (MLA decode):
        #   logits = q_nope W_uk . ckv + q_rope . kr
        # operands stay bf16 (f32 casts of the cache would materialize f32
        # copies of it); accumulation is f32 via preferred_element_type
        dt = x.dtype
        q_abs = acc_einsum("bqhd,hcd->bqhc", q_nope, w_uk).astype(dt)
        logits = acc_einsum("bqhc,bsc->bqhs", q_abs, ckv_v.astype(dt))
        logits += acc_einsum("bqhr,bsr->bqhs", q_rope, kr_v.astype(dt))
        logits *= scale
        if cfg.mask is not None:
            # absorbed path: the spec's token predicate applied inline —
            # positions are the queries' absolute positions in both
            # decode and chunk modes, so one expression covers both.
            # Cache-validity (slot j written iff j <= q position) rides
            # along as the causal term of the predicate intersection.
            S = ckv_v.shape[1]
            qp = positions if positions.ndim == 2 else positions[None, :]
            kp = jnp.arange(S)
            cvalid = jnp_token_mask(
                cfg.mask, qp[..., None], kp[None, None, :],
                max_q=S, max_k=S)
            cvalid &= kp[None, None, :] <= qp[..., None]  # (B|1, sq, S)
            logits = jnp.where(cvalid[:, :, None, :], logits, NEG_INF)
        elif mode == "chunk":
            # multi-token prefill piece: cache slot j visible to query
            # token i iff j <= position(i) — logits are (b, sq, h, S)
            qp = positions if positions.ndim == 2 else positions[None, :]
            cvalid = (jnp.arange(ckv_v.shape[1])[None, None, :]
                      <= qp[..., None])  # (B|1, sq, S)
            logits = jnp.where(cvalid[:, :, None, :], logits, NEG_INF)
        else:
            valid = _len_mask(cache_len + s, ckv_v.shape[1])
            logits = _apply_len_mask(logits, valid)
        m = logits.max(-1, keepdims=True)
        p = jnp.exp(logits - m)
        p = p / p.sum(-1, keepdims=True)
        o_abs = acc_einsum("bqhs,bsc->bqhc", p.astype(dt),
                           ckv_v.astype(dt)).astype(dt)
        out = acc_einsum("bqhc,hcv->bqhv", o_abs, w_uv)
        out = out.astype(x.dtype)
    else:
        # train/prefill: materialize per-head K/V from the latent, use the
        # chunked flash path. K = [k_nope | kr broadcast], V = v.
        k_nope = jnp.einsum("bsc,hcd->bshd", ckv, w_uk)  # (b,s,h,nope)
        vfull = jnp.einsum("bsc,hcv->bshv", ckv, w_uv)  # (b,s,h,v)
        kr_b = jnp.broadcast_to(kr[:, :, None, :], (b, s, h, cfg.rope_head_dim))
        k = jnp.concatenate([k_nope, kr_b], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        if cfg.mask is not None:
            out = bs_ops.bs_attention(q, k, vfull, spec=cfg.mask,
                                      scale=scale)
        else:
            out = chunked_attention(
                q, k, vfull, causal=True, window=cfg.window, chunk=chunk,
                scale=scale
            )
        if mode == "prefill":
            assert cache is not None
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)
            )
            kr_c = jax.lax.dynamic_update_slice(
                cache["kr"], kr.astype(cache["kr"].dtype), (0, 0, 0)
            )
            new_cache = {"ckv": ckv_c, "kr": kr_c}
    y = tp_reduce(
        linear_apply(params["wo"], out.reshape(b, s, h * cfg.v_head_dim)),
        "attn_out")
    return y, new_cache


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def attn_init(key, d_model, cfg: AttnConfig, *, sp=None, param_dtype=jnp.float32,
              qk_norm: bool = False):
    if cfg.kind == "mla":
        return mla_init(key, d_model, cfg, sp=sp, param_dtype=param_dtype)
    return gqa_init(key, d_model, cfg, sp=sp, param_dtype=param_dtype,
                    qk_norm=qk_norm)


def attn_apply(params, x, cfg: AttnConfig, *, view: Optional[CacheView] = None,
               cache: Optional[dict] = None, rope_theta: float = 10_000.0,
               chunk: int = 512, cross_kv=None, **kw):
    """Kind dispatch with a *typed* keyword surface: every keyword is
    validated against the resolved cache kind before the apply runs —
    unknown keys raise :class:`~repro.models.cache.AttnKwargError`
    instead of the old silent ``**kw`` passthrough (where a typo like
    ``cache_length=`` was dropped on the floor). Legacy addressing
    keywords route through the one-release shim first."""
    view = view_from_legacy_kwargs(view, kw, caller="attn_apply")
    if kw:
        valid = "view, cache, rope_theta, chunk" + (
            ", cross_kv" if cfg.kind == "gqa" else "")
        raise AttnKwargError(
            f"attn_apply got unknown keyword(s) {sorted(kw)} for cache "
            f"kind {cfg.kind!r}; valid keywords: {valid}")
    if cfg.kind == "mla":
        if cross_kv is not None:
            raise AttnKwargError(
                "cross_kv is only valid for the 'gqa' cache kind; 'mla' "
                "is self-attention only")
        return mla_apply(params, x, cfg, view=view, cache=cache,
                         rope_theta=rope_theta, chunk=chunk)
    return gqa_apply(params, x, cfg, view=view, cache=cache,
                     rope_theta=rope_theta, chunk=chunk, cross_kv=cross_kv)


def attn_empty_cache(batch, max_seq, cfg: AttnConfig, dtype=DEFAULT_COMPUTE_DTYPE):
    if cfg.kind == "mla":
        return mla_empty_cache(batch, max_seq, cfg, dtype)
    return gqa_empty_cache(batch, max_seq, cfg, dtype)
