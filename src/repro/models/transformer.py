"""Model assembly: blocks -> groups -> LM (decoder-only or enc-dec backbone).

A model's `plan` is a tuple of (Block, repeat) groups. Groups with
repeat > 1 execute under lax.scan over stacked parameters — compile time
and HLO size stay O(#distinct block types), not O(depth), which is what
keeps the 512-device dry-run (and 1000+ node compiles) tractable.

Execution modes thread a per-layer cache pytree with the same group
structure (stacked leading dim for scanned groups).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AttnConfig,
    Block,
    FFNConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    RWKVConfig,
)
from repro.models import attention, mamba, moe, rwkv
from repro.models.cache import CacheView, view_from_legacy_kwargs
from repro.models.common import (
    DEFAULT_COMPUTE_DTYPE,
    get_compute_dtype,
    embedding_apply,
    embedding_attend,
    embedding_init,
    linear_apply,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
)
from repro.models.ffn import ffn_apply, ffn_init
from repro.parallel.hints import shard_hint

# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(key: jax.Array, d_model: int, block: Block, cfg: ModelConfig,
               param_dtype=jnp.float32) -> dict:
    sp = cfg.sparsity
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": rmsnorm_init(d_model, param_dtype)}
    mx = block.mixer
    if isinstance(mx, AttnConfig):
        p["mixer"] = attention.attn_init(
            ks[0], d_model, mx, sp=sp, param_dtype=param_dtype,
            qk_norm=mx.qk_norm,
        )
    elif isinstance(mx, MambaConfig):
        p["mixer"] = mamba.mamba_init(ks[0], d_model, mx, sp=sp,
                                      param_dtype=param_dtype)
    elif isinstance(mx, RWKVConfig):
        assert isinstance(block.mlp, FFNConfig)
        p["mixer"] = rwkv.rwkv_init(ks[0], d_model, mx, d_ff=block.mlp.d_ff,
                                    sp=sp, param_dtype=param_dtype)
    else:
        raise TypeError(mx)
    if block.cross_attn:
        assert isinstance(mx, AttnConfig)
        p["norm_cross"] = rmsnorm_init(d_model, param_dtype)
        p["cross"] = attention.gqa_init(ks[1], d_model, mx, sp=sp,
                                        param_dtype=param_dtype)
    if block.mlp is not None and not isinstance(mx, RWKVConfig):
        p["norm2"] = rmsnorm_init(d_model, param_dtype)
        if isinstance(block.mlp, MoEConfig):
            p["mlp"] = moe.moe_init(ks[2], d_model, block.mlp, sp=sp,
                                    param_dtype=param_dtype)
        else:
            p["mlp"] = ffn_init(ks[2], d_model, block.mlp, sp=sp,
                                param_dtype=param_dtype)
    return p


def block_empty_cache(block: Block, batch: int, max_seq: int, cfg: ModelConfig,
                      dtype=DEFAULT_COMPUTE_DTYPE) -> dict:
    mx = block.mixer
    c: dict[str, Any] = {}
    if isinstance(mx, AttnConfig):
        c = attention.attn_empty_cache(batch, max_seq, mx, dtype)
    elif isinstance(mx, MambaConfig):
        c = mamba.mamba_empty_cache(batch, cfg.d_model, mx)
    elif isinstance(mx, RWKVConfig):
        c = rwkv.rwkv_empty_cache(batch, cfg.d_model, mx, dtype)
    if block.cross_attn:
        assert isinstance(mx, AttnConfig)
        c["cross_k"] = jnp.zeros(
            (batch, cfg.encoder_seq, mx.kv_heads, mx.head_dim), dtype)
        c["cross_v"] = jnp.zeros(
            (batch, cfg.encoder_seq, mx.kv_heads, mx.head_dim), dtype)
    return c


def block_apply(
    params: dict,
    x: jax.Array,
    block: Block,
    cfg: ModelConfig,
    *,
    view: CacheView,
    cache: Optional[dict],
    enc_out: Optional[jax.Array] = None,
):
    """Returns (x, new_cache, aux). Sparse weights are self-describing
    typed nodes, so no sparsity config threads through apply calls.
    ``view`` carries mode/positions/cache addressing as one typed pytree
    (internal surfaces take it exclusively — no legacy keywords here);
    ``view.block_table`` switches attention caches to the paged layout
    (see attention.paged_write); only AttnConfig mixers use it."""
    mx = block.mixer
    mode = view.mode
    positions = view.positions
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    mixer_cache = None
    if cache is not None:
        mixer_cache = {k: v for k, v in cache.items()
                       if not k.startswith("cross_")} or None
    if isinstance(mx, AttnConfig):
        y, new_mc = attention.attn_apply(
            params["mixer"], h, mx, view=view, cache=mixer_cache,
            rope_theta=mx.rope_theta or cfg.rope_theta,
            chunk=cfg.attn_chunk,
        )
    elif isinstance(mx, MambaConfig):
        y, new_mc = mamba.mamba_apply(params["mixer"], h, mx, mode=mode,
                                      cache=mixer_cache)
    else:
        y, new_mc = rwkv.rwkv_apply(params["mixer"], h, mx, mode=mode,
                                    cache=mixer_cache)
    x = x + y
    new_cache = dict(cache) if cache is not None else None
    if new_cache is not None and new_mc is not None:
        new_cache.update(new_mc)

    if block.cross_attn:
        hc = rmsnorm_apply(params["norm_cross"], x, cfg.norm_eps)
        if mode in ("train", "prefill"):
            assert enc_out is not None
            amx = dataclasses.replace(mx, rope=False, causal=False)
            b = enc_out.shape[0]
            kx = linear_apply(params["cross"]["wk"], enc_out)
            vx = linear_apply(params["cross"]["wv"], enc_out)
            kx = kx.reshape(b, -1, mx.kv_heads, mx.head_dim)
            vx = vx.reshape(b, -1, mx.kv_heads, mx.head_dim)
            yc, _ = attention.gqa_apply(
                params["cross"], hc, amx,
                view=CacheView.train(positions=positions),
                rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk,
                cross_kv=(kx, vx),
            )
            if new_cache is not None:
                new_cache["cross_k"] = kx.astype(new_cache["cross_k"].dtype)
                new_cache["cross_v"] = vx.astype(new_cache["cross_v"].dtype)
        else:  # decode: static cross KV from cache
            amx = dataclasses.replace(mx, rope=False, causal=False)
            yc, _ = attention.gqa_apply(
                params["cross"], hc, amx,
                view=CacheView(mode="decode", positions=positions),
                rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk,
                cross_kv=(cache["cross_k"], cache["cross_v"]),
            )
        x = x + yc

    if isinstance(mx, RWKVConfig):
        # channel-mix sublayer (token-shifted FFN) with its own state
        hm = rmsnorm_apply(params["mixer"]["cm_norm"], x, cfg.norm_eps)
        last = cache["cm_last"] if cache is not None else None
        y2, cm_last = rwkv.rwkv_channel_mix(params["mixer"], hm, last=last)
        x = x + y2
        if new_cache is not None:
            new_cache["cm_last"] = cm_last.astype(new_cache["cm_last"].dtype)
    elif block.mlp is not None:
        hm = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if isinstance(block.mlp, MoEConfig):
            y2, aux = moe.moe_apply(params["mlp"], hm, block.mlp)
        else:
            y2 = ffn_apply(params["mlp"], hm, block.mlp)
        x = x + y2
    x = shard_hint(x, ("pod", "data"), None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# group (scan) execution — a group is (super_block, repeat) where the
# super_block is one Block or a tuple of Blocks (a repeating period, e.g.
# gemma3's 5 local + 1 global, jamba's 8-layer mamba/attn/moe period).
# Scanning the period keeps HLO size O(#distinct blocks).
# ---------------------------------------------------------------------------


def _as_blocks(entry) -> tuple[Block, ...]:
    return entry if isinstance(entry, tuple) else (entry,)


def _super_init(key, blocks: tuple[Block, ...], cfg: ModelConfig, param_dtype):
    ks = jax.random.split(key, len(blocks))
    return [block_init(k, cfg.d_model, b, cfg, param_dtype)
            for k, b in zip(ks, blocks)]


def group_init(key, entry, repeat: int, cfg: ModelConfig, param_dtype):
    blocks = _as_blocks(entry)
    if repeat == 1:
        return _super_init(key, blocks, cfg, param_dtype)
    keys = jax.random.split(key, repeat)
    return jax.vmap(lambda k: _super_init(k, blocks, cfg, param_dtype))(keys)


def group_empty_cache(entry, repeat: int, batch: int, max_seq: int,
                      cfg: ModelConfig, dtype):
    blocks = _as_blocks(entry)
    c = [block_empty_cache(b, batch, max_seq, cfg, dtype) for b in blocks]
    if repeat > 1:
        c = jax.tree.map(lambda a: jnp.broadcast_to(a, (repeat, *a.shape)).copy(), c)
    return c


def group_apply(params, x, entry, repeat: int, cfg: ModelConfig, *,
                view: CacheView, cache, enc_out, remat: str):
    blocks = _as_blocks(entry)

    def one(p_list, x, c_list):
        aux = jnp.zeros((), jnp.float32)
        new_cs = []
        for p, b, c in zip(p_list, blocks,
                           c_list if c_list is not None else [None] * len(blocks)):
            x, nc, a = block_apply(p, x, b, cfg, view=view, cache=c,
                                   enc_out=enc_out)
            new_cs.append(nc)
            aux = aux + a
        return x, new_cs, aux

    if remat != "none" and view.mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else None)
        one = jax.checkpoint(one, policy=policy)

    if repeat == 1:
        return one(params, x, cache)

    def body(carry, xs):
        x, aux = carry
        p, c = xs
        x, new_c, a = one(p, x, c)
        return (x, aux + a), new_c

    cache_xs = cache if cache is not None else None
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params, cache_xs)
    )
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# language model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ---- init -----------------------------------------------------------
    def init(self, key: jax.Array, param_dtype=jnp.float32) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 5 + len(cfg.plan)
                              + len(cfg.encoder_plan or ()))
        p: dict[str, Any] = {
            "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                    param_dtype),
            "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
        }
        if cfg.pos_embed == "learned":
            p["pos"] = (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model))
                        * 0.02).astype(param_dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab_size,
                                       sp=None, param_dtype=param_dtype)
        p["groups"] = [
            group_init(ks[5 + i], blk, rep, cfg, param_dtype)
            for i, (blk, rep) in enumerate(cfg.plan)
        ]
        if cfg.encoder_plan is not None:
            off = 5 + len(cfg.plan)
            p["enc_groups"] = [
                group_init(ks[off + i], blk, rep, cfg, param_dtype)
                for i, (blk, rep) in enumerate(cfg.encoder_plan)
            ]
            p["enc_final_norm"] = rmsnorm_init(cfg.d_model, param_dtype)
            p["enc_pos"] = (jax.random.normal(ks[3],
                            (cfg.encoder_seq, cfg.d_model)) * 0.02
                            ).astype(param_dtype)
            if cfg.encoder_inputs == "tokens":
                p["enc_embed"] = embedding_init(ks[4], cfg.vocab_size,
                                                cfg.d_model, param_dtype)
        return p

    # ---- caches ----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> list:
        cfg = self.cfg
        dtype = dtype or get_compute_dtype()
        return [group_empty_cache(blk, rep, batch, max_seq, cfg, dtype)
                for blk, rep in cfg.plan]

    # ---- encoder ---------------------------------------------------------
    def encode(self, params, enc_input, *, remat="none"):
        cfg = self.cfg
        if cfg.encoder_inputs == "tokens":
            x = embedding_apply(params["enc_embed"], enc_input)
        else:
            x = enc_input.astype(get_compute_dtype())
        s = x.shape[1]
        x = x + params["enc_pos"][:s].astype(x.dtype)
        positions = jnp.arange(s)
        for gp, (blk, rep) in zip(params["enc_groups"], cfg.encoder_plan):
            x, _, _ = group_apply(gp, x, blk, rep, cfg,
                                  view=CacheView.train(positions=positions),
                                  cache=None, enc_out=None, remat=remat)
        return rmsnorm_apply(params["enc_final_norm"], x, cfg.norm_eps)

    # ---- forward ---------------------------------------------------------
    def forward(
        self,
        params: dict,
        tokens: jax.Array,  # (B, S)
        *,
        view: Optional[CacheView] = None,
        caches: Optional[list] = None,
        enc_input: Optional[jax.Array] = None,
        remat: str = "none",
        **kw,
    ):
        """``view`` (:class:`repro.models.cache.CacheView`) is the typed
        cache-addressing struct; None means train. The legacy keywords
        (mode/cache_len/block_table/write_mask) still work for one
        release via the deprecation shim. ``view.positions`` is derived
        here from ``cache_len`` when not already set."""
        view = view_from_legacy_kwargs(view, kw, caller="LM.forward")
        if kw:
            raise TypeError(
                f"LM.forward got unknown keyword(s) {sorted(kw)}")
        if view is None:
            view = CacheView.train()
        cfg = self.cfg
        mode = view.mode
        cache_len = view.cache_len
        b, s = tokens.shape
        enc_out = None
        if cfg.encoder_plan is not None and mode in ("train", "prefill"):
            assert enc_input is not None
            enc_out = self.encode(params, enc_input, remat=remat)
        x = embedding_apply(params["embed"], tokens)
        # "chunk" = one prefill_chunk-sized piece of a prompt against a
        # partially-filled cache: positions/cache writes offset by cache_len
        # exactly like decode, but s > 1 tokens at a time (causal masking
        # within the chunk happens in the attention mixers)
        offset_mode = view.offset_mode
        vec_len = (offset_mode and cache_len is not None
                   and getattr(cache_len, "ndim", 0) == 1)
        if cfg.pos_embed == "learned":
            pos_table = params["pos"].astype(x.dtype)
            if not offset_mode:
                x = x + pos_table[:s]
            elif vec_len:
                x = x + pos_table[cache_len[:, None]
                                  + jnp.arange(s)[None, :]]
            else:
                x = x + jax.lax.dynamic_slice(
                    pos_table, (cache_len, 0), (s, cfg.d_model))
        if view.positions is not None:
            positions = view.positions
        elif offset_mode:
            if vec_len:
                positions = cache_len[:, None] + jnp.arange(s)[None, :]
            else:
                positions = jnp.arange(s) + cache_len
        else:
            positions = jnp.arange(s)
        view = view.with_positions(positions)
        x = shard_hint(x, ("pod", "data"), None, None)

        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, (gp, (blk, rep)) in enumerate(zip(params["groups"], cfg.plan)):
            c = caches[i] if caches is not None else None
            x, new_c, aux = group_apply(
                gp, x, blk, rep, cfg, view=view, cache=c, enc_out=enc_out,
                remat=remat)
            new_caches.append(new_c)
            aux_total = aux_total + aux

        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = embedding_attend(params["embed"], x)
        else:
            logits = linear_apply(params["lm_head"], x,
                                  compute_dtype=jnp.float32)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits, new_caches, aux_total

    # ---- loss ------------------------------------------------------------
    def loss(self, params, batch: dict, *, remat: str = "none"):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-100 = pad),
        optional enc_input for enc-dec models."""
        logits, _, aux = self.forward(
            params, batch["tokens"], view=CacheView.train(),
            enc_input=batch.get("enc_input"), remat=remat)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (logz - ll) * mask
        loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# parameter counting (MODEL_FLOPS)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape of init (no allocation).

    active_only: count MoE experts at top_k (+ shared) instead of all —
    the N_active used for MoE MODEL_FLOPS.
    """
    import math

    lm = LM(cfg)
    shapes = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    # float leaves only: the int8 idx arrays are pattern metadata, not
    # parameters (they carry no FLOPs and no gradients)
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes)
                if jnp.issubdtype(l.dtype, jnp.inexact))
    if not active_only:
        return total
    # subtract the inactive routed-expert fraction analytically
    inactive = 0
    for entry, rep in cfg.plan:
        for blk in _as_blocks(entry):
            if isinstance(blk.mlp, MoEConfig):
                me = blk.mlp
                per_expert = 3 * cfg.d_model * me.d_expert  # swiglu
                if me.act == "gelu":
                    per_expert = 2 * cfg.d_model * me.d_expert
                if cfg.sparsity is not None and "expert" in cfg.sparsity.targets \
                   and cfg.sparsity.mode == "compressed":
                    per_expert = int(
                        per_expert * cfg.sparsity.nm_for("expert").density)
                inactive += rep * per_expert * (me.n_experts - me.top_k)
    return total - inactive
