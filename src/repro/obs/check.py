"""Schema checks for exported observability artifacts.

Validates (1) a Chrome-trace JSON file against the subset of the Trace
Event Format the tracer emits — required keys, monotonic ``ts``,
matched ``B``/``E`` pairs per thread, matched async ``b``/``e`` pairs
per (cat, id) — and (2) a Prometheus text exposition file, optionally
requiring sample coverage for a set of subsystem namespaces.

CLI (the CI serve lane fails the job on a bad artifact)::

    python -m repro.obs.check trace.json metrics.prom \\
        --require-subsystems engine,scheduler,paging,dispatch,autotune

Library use: :func:`validate_chrome_trace` / :func:`validate_metrics`
raise :class:`TraceValidationError` with a specific message; tests call
them directly on exported files.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.metrics import parse_prometheus

__all__ = [
    "TraceValidationError",
    "validate_chrome_trace",
    "validate_metrics",
    "SUBSYSTEM_PREFIXES",
]

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")
_KNOWN_PHASES = {"B", "E", "X", "i", "I", "b", "e", "n", "M", "C"}

# metric-name prefixes per instrumented subsystem (the catalog lives in
# the README "Observability" section; keep both in sync)
SUBSYSTEM_PREFIXES = {
    "engine": ("serve_",),
    "scheduler": ("sched_",),
    "paging": ("page_", "prefix_"),
    "dispatch": ("kernel_dispatch",),
    "autotune": ("autotune_",),
}


class TraceValidationError(ValueError):
    """The artifact violates the expected schema."""


def validate_chrome_trace(path: str, *, require_nonempty: bool = True
                          ) -> dict:
    """Validate an exported Chrome trace; returns summary stats
    (event/span/request counts) on success."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise TraceValidationError(f"{path}: not readable JSON: {e}") from e
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise TraceValidationError(
            f"{path}: expected the JSON-object trace form with a "
            "'traceEvents' key")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise TraceValidationError(f"{path}: traceEvents is not a list")
    if require_nonempty and not events:
        raise TraceValidationError(f"{path}: trace is empty")

    last_ts = float("-inf")
    open_sync: dict[tuple, list[str]] = {}
    open_async: dict[tuple, int] = {}
    counts = {"events": 0, "sync_spans": 0, "async_spans": 0,
              "instants": 0}
    for i, ev in enumerate(events):
        for key in _REQUIRED_KEYS:
            if key not in ev:
                raise TraceValidationError(
                    f"{path}: event {i} missing required key {key!r}: "
                    f"{ev}")
        ph = ev["ph"]
        if ph not in _KNOWN_PHASES:
            raise TraceValidationError(
                f"{path}: event {i} has unknown phase {ph!r}")
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            raise TraceValidationError(
                f"{path}: event {i} has invalid ts {ts!r}")
        if ts < last_ts:
            raise TraceValidationError(
                f"{path}: ts goes backwards at event {i} "
                f"({ts} < {last_ts})")
        last_ts = ts
        counts["events"] += 1
        if ph == "B":
            open_sync.setdefault((ev["pid"], ev["tid"]), []).append(
                ev["name"])
        elif ph == "E":
            stack = open_sync.get((ev["pid"], ev["tid"]), [])
            if not stack:
                raise TraceValidationError(
                    f"{path}: event {i}: 'E' ({ev['name']}) with no "
                    "open 'B' on its thread")
            stack.pop()
            counts["sync_spans"] += 1
        elif ph == "b":
            key = (ev.get("cat"), ev.get("id"))
            open_async[key] = open_async.get(key, 0) + 1
        elif ph == "e":
            key = (ev.get("cat"), ev.get("id"))
            if open_async.get(key, 0) <= 0:
                raise TraceValidationError(
                    f"{path}: event {i}: async 'e' ({ev['name']}, "
                    f"id={ev.get('id')}) with no open 'b'")
            open_async[key] -= 1
            counts["async_spans"] += 1
        elif ph in ("i", "I", "n"):
            counts["instants"] += 1
    unclosed = [f"{names[-1]} (tid {tid})"
                for (_, tid), names in open_sync.items() if names]
    if unclosed:
        raise TraceValidationError(
            f"{path}: unmatched 'B' events at end of trace: {unclosed}")
    return counts


def validate_metrics(path: str, *, require_subsystems: tuple = ()
                     ) -> dict:
    """Validate a Prometheus text exposition file; optionally require at
    least one sample for every named subsystem (keys of
    :data:`SUBSYSTEM_PREFIXES`)."""
    try:
        with open(path) as f:
            parsed = parse_prometheus(f.read())
    except OSError as e:
        raise TraceValidationError(f"{path}: unreadable: {e}") from e
    except ValueError as e:
        raise TraceValidationError(f"{path}: {e}") from e
    if not parsed["samples"]:
        raise TraceValidationError(f"{path}: no metric samples")
    missing = []
    for subsystem in require_subsystems:
        prefixes = SUBSYSTEM_PREFIXES.get(subsystem)
        if prefixes is None:
            raise TraceValidationError(
                f"unknown subsystem {subsystem!r}; known: "
                f"{sorted(SUBSYSTEM_PREFIXES)}")
        if not any(name.startswith(prefixes) for name in parsed["samples"]):
            missing.append(subsystem)
    if missing:
        raise TraceValidationError(
            f"{path}: no samples from subsystem(s) {missing} — expected "
            f"prefixes {[SUBSYSTEM_PREFIXES[s] for s in missing]}")
    return {"samples": len(parsed["samples"]), "types": parsed["types"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("trace", help="Chrome-trace JSON file")
    ap.add_argument("metrics", nargs="?", default=None,
                    help="Prometheus text exposition file")
    ap.add_argument("--require-subsystems", default="",
                    help="comma-separated subsystem names whose metrics "
                         "must be present (engine,scheduler,paging,"
                         "dispatch,autotune)")
    args = ap.parse_args(argv)
    try:
        stats = validate_chrome_trace(args.trace)
        print(f"{args.trace}: OK — {stats['events']} events, "
              f"{stats['sync_spans']} sync spans, "
              f"{stats['async_spans']} request spans, "
              f"{stats['instants']} instants")
        if args.metrics:
            req = tuple(s for s in args.require_subsystems.split(",") if s)
            mstats = validate_metrics(args.metrics,
                                      require_subsystems=req)
            print(f"{args.metrics}: OK — {mstats['samples']} samples"
                  + (f", subsystems {list(req)} covered" if req else ""))
    except TraceValidationError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
