"""Unified observability layer: metrics registry + structured tracer.

One process-global :class:`Obs` bundle (a :class:`MetricsRegistry` and a
:class:`Tracer`) that every instrumented subsystem — serving engine,
scheduler, page manager, kernel-dispatch registry, autotuner — consults
through :func:`get_obs`. The contract is **zero overhead when off**:

  * ``get_obs()`` returns ``None`` unless observability was enabled, so
    every instrumentation site is a single ``is not None`` check; no
    registries, tracers, or event dicts are ever allocated.
  * Enabling is explicit (:func:`enable`) or environment-driven:
    ``REPRO_OBS=1`` auto-enables on first :func:`get_obs` call.
    ``REPRO_OBS`` unset, empty, or ``0`` keeps observability off —
    the serve token streams are byte-identical either way (tested).

Typical wiring::

    import repro.obs as obs

    handle = obs.enable()                 # or REPRO_OBS=1 in the env
    eng = ServeEngine(lm, params, ...)    # picks up the global bundle
    eng.run()
    handle.tracer.export_chrome("trace.json")
    open("metrics.prom", "w").write(handle.metrics.to_prometheus())

``ServeEngine(obs=...)`` also accepts an explicit bundle for isolated
collection (e.g. per-cell snapshots in the serve bench). Trace buffer
capacity comes from ``REPRO_OBS_TRACE_CAP`` (default 65536 events).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Optional

from repro.obs.metrics import (  # noqa: F401 (re-export)
    DEFAULT_SECONDS_BUCKETS,
    MetricsRegistry,
    parse_prometheus,
)
from repro.obs.trace import (  # noqa: F401 (re-export)
    DEFAULT_TRACE_CAPACITY,
    Tracer,
)

__all__ = [
    "Obs", "MetricsRegistry", "Tracer", "enable", "disable", "get_obs",
    "enabled_by_env", "null_span", "parse_prometheus",
]


@dataclasses.dataclass
class Obs:
    """The observability bundle every instrumented subsystem shares."""

    metrics: MetricsRegistry
    tracer: Tracer

    @classmethod
    def create(cls, trace_capacity: Optional[int] = None) -> "Obs":
        return cls(metrics=MetricsRegistry(),
                   tracer=Tracer(capacity=trace_capacity))


class _NullSpan:
    """Reusable no-op stand-in for ``tracer.span`` when obs is off: one
    module-level instance, callable with any signature, usable as a
    context manager — the off path allocates nothing per call."""

    __slots__ = ()

    def __call__(self, name, **args):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()

_LOCK = threading.Lock()
_GLOBAL: Optional[Obs] = None
_ENV_CHECKED = False


def enabled_by_env() -> bool:
    """True when ``REPRO_OBS`` requests observability (any value except
    unset / empty / "0")."""
    return os.environ.get("REPRO_OBS", "0") not in ("", "0")


def enable(obs: Optional[Obs] = None) -> Obs:
    """Install (and return) the process-global bundle. Idempotent when
    already enabled and no explicit bundle is passed."""
    global _GLOBAL, _ENV_CHECKED
    with _LOCK:
        if obs is not None:
            _GLOBAL = obs
        elif _GLOBAL is None:
            _GLOBAL = Obs.create()
        _ENV_CHECKED = True
        return _GLOBAL


def disable() -> None:
    """Drop the global bundle (tests; long-lived processes that want a
    fresh collection window should prefer a new explicit bundle)."""
    global _GLOBAL, _ENV_CHECKED
    with _LOCK:
        _GLOBAL = None
        _ENV_CHECKED = True


def reset_for_tests() -> None:
    """Forget both the bundle and the env decision, so the next
    :func:`get_obs` re-reads ``REPRO_OBS``."""
    global _GLOBAL, _ENV_CHECKED
    with _LOCK:
        _GLOBAL = None
        _ENV_CHECKED = False


def get_obs() -> Optional[Obs]:
    """The global bundle, or None when observability is off.

    The first call consults ``REPRO_OBS`` once; after that the decision
    is process-state (``enable`` / ``disable`` flip it explicitly).
    """
    global _GLOBAL, _ENV_CHECKED
    if _ENV_CHECKED:
        return _GLOBAL
    with _LOCK:
        if not _ENV_CHECKED:
            if _GLOBAL is None and enabled_by_env():
                _GLOBAL = Obs.create()
            _ENV_CHECKED = True
        return _GLOBAL


def null_span():
    """The shared no-op span factory (see :class:`_NullSpan`)."""
    return _NULL_SPAN
