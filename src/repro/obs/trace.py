"""Structured tracer: typed span/instant events in a thread-safe ring
buffer, exported as Chrome-trace JSON (Perfetto-loadable).

Event model (a tight subset of the Trace Event Format that both
``chrome://tracing`` and https://ui.perfetto.dev consume):

  * **Sync spans** (``ph="B"`` / ``ph="E"``) — duration events on the
    emitting thread; :meth:`Tracer.span` is a context manager that
    always emits the matched pair (the ``E`` fires even on exceptions).
    Used for engine-step work: ``engine.prefill`` / ``engine.decode``.
  * **Async spans** (``ph="b"`` / ``ph="e"``, ``cat="request"``,
    ``id=rid``) — request lifetimes that cross many engine steps.
    :meth:`async_begin` / :meth:`async_end`; :meth:`async_instant`
    (``ph="n"``) marks points inside one (``prefill_chunk``,
    ``first_token``, ``preempted``).
  * **Instants** (``ph="i"``) — per-step occupancy snapshots and
    scheduler decisions.

Timestamps are ``time.perf_counter()`` (monotonic) converted to
microseconds relative to tracer creation, so ``ts`` starts near 0 and
never goes backwards. The buffer is a bounded deque (capacity from
``REPRO_OBS_TRACE_CAP``, default 65536 events) — a long-running server
keeps the most recent window instead of growing without bound.

``export_chrome(path)`` writes the JSON-object form
(``{"traceEvents": [...]}``) which Perfetto opens directly.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Optional

__all__ = ["TraceEvent", "Tracer", "DEFAULT_TRACE_CAPACITY"]

DEFAULT_TRACE_CAPACITY = 65536


class TraceEvent(dict):
    """A trace event is a plain dict (kept JSON-shaped on purpose); the
    subclass exists so tests can assert type without schema drift."""

    __slots__ = ()


def trace_capacity() -> int:
    try:
        return int(os.environ.get("REPRO_OBS_TRACE_CAP",
                                  DEFAULT_TRACE_CAPACITY))
    except ValueError:
        return DEFAULT_TRACE_CAPACITY


class _SpanCtx:
    """Context manager emitting a matched B/E pair around a block."""

    __slots__ = ("_tracer", "_name", "_args")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._tracer._emit("B", self._name, args=self._args)
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer._emit("E", self._name)
        return False


class Tracer:
    """Thread-safe ring buffer of trace events with Chrome-JSON export."""

    def __init__(self, capacity: Optional[int] = None):
        self._cap = capacity if capacity is not None else trace_capacity()
        self._buf: collections.deque[TraceEvent] = collections.deque(
            maxlen=self._cap)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._dropped = 0

    # ---- emission ---------------------------------------------------------

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, ph: str, name: str, *, cat: str = "serve",
              args: Optional[dict] = None, id: Optional[int] = None,
              ts: Optional[float] = None) -> None:
        ev = TraceEvent(
            name=name, ph=ph, cat=cat,
            ts=self.now_us() if ts is None else ts,
            pid=self._pid, tid=threading.get_ident(),
        )
        if args:
            ev["args"] = args
        if id is not None:
            ev["id"] = str(id)
        with self._lock:
            if len(self._buf) == self._cap:
                self._dropped += 1
            self._buf.append(ev)

    def span(self, name: str, **args) -> _SpanCtx:
        """``with tracer.span("engine.decode", slots=3): ...`` — emits a
        matched B/E pair on this thread."""
        return _SpanCtx(self, name, args)

    def instant(self, name: str, **args) -> None:
        self._emit("i", name, args=args or None)

    def async_begin(self, name: str, id: int, **args) -> None:
        self._emit("b", name, cat="request", id=id, args=args or None)

    def async_instant(self, name: str, id: int, **args) -> None:
        self._emit("n", name, cat="request", id=id, args=args or None)

    def async_end(self, name: str, id: int, **args) -> None:
        self._emit("e", name, cat="request", id=id, args=args or None)

    # ---- export -----------------------------------------------------------

    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._buf)

    @property
    def dropped(self) -> int:
        """Events evicted by the ring buffer since creation."""
        with self._lock:
            return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def export_chrome(self, path: str) -> int:
        """Write the Chrome-trace JSON object form; returns the event
        count. Events are sorted by ``ts`` (the buffer is append-ordered
        already; sorting makes the monotonic-ts contract explicit even
        across threads)."""
        events = sorted(self.events(), key=lambda e: e["ts"])
        payload = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.obs",
                "dropped_events": self._dropped,
            },
        }
        with open(path, "w") as f:
            json.dump(payload, f)
        return len(events)
