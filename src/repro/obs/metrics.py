"""Process-local metrics registry: counters, gauges, histograms.

The registry is the numeric half of the observability layer (the
:mod:`repro.obs.trace` tracer is the temporal half). It is deliberately
small and dependency-free:

  * **Counter** — monotonically increasing float (``inc``).
  * **Gauge** — last-write-wins float (``set``).
  * **Histogram** — fixed bucket edges chosen at creation; ``observe``
    increments the first bucket whose upper edge is >= the sample
    (cumulative at export, like Prometheus ``le`` buckets) and tracks
    ``sum`` / ``count``.

Metrics are keyed by ``(name, sorted label items)`` — the same name may
carry many label sets (e.g.
``kernel_dispatch_total{op=...,impl=...,backend=...}``).
All mutation goes through one lock; every hot-path call is a dict lookup
plus a float add, and nothing here is ever invoked unless observability
is enabled (see :mod:`repro.obs`).

``snapshot()`` returns a plain-dict view (JSON-serializable, attached to
``BENCH_results.json`` by the serve bench); ``to_prometheus()`` renders
the Prometheus text exposition format, which round-trips through
:func:`parse_prometheus` (used by the CI schema check and tests).
"""
from __future__ import annotations

import threading
from typing import Mapping, Optional, Sequence

__all__ = [
    "MetricsRegistry",
    "DEFAULT_SECONDS_BUCKETS",
    "parse_prometheus",
]

# duration buckets (seconds): 10us .. 30s, roughly log-spaced — wide
# enough for CPU-interpret serving steps and TPU microsecond kernels
DEFAULT_SECONDS_BUCKETS = (
    1e-5, 1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 30.0,
)


def _label_key(labels: Mapping[str, str]) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Histogram:
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float]):
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(self.edges) or not self.edges:
            raise ValueError(f"histogram edges must be sorted+non-empty: "
                             f"{edges}")
        self.counts = [0] * (len(self.edges) + 1)  # +1: +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        for i, e in enumerate(self.edges):
            if v <= e:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> list[tuple[float, int]]:
        """Prometheus-style cumulative (le, count) pairs incl +Inf."""
        out, running = [], 0
        for e, c in zip(self.edges, self.counts):
            running += c
            out.append((e, running))
        out.append((float("inf"), running + self.counts[-1]))
        return out


class MetricsRegistry:
    """Counters, gauges and fixed-bucket histograms behind one lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple[str, tuple], float] = {}
        self._gauges: dict[tuple[str, tuple], float] = {}
        self._hists: dict[tuple[str, tuple], _Histogram] = {}
        self._hist_edges: dict[str, tuple] = {}

    # ---- mutation ---------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._gauges[key] = float(value)

    def define_histogram(self, name: str,
                         edges: Sequence[float] = DEFAULT_SECONDS_BUCKETS
                         ) -> None:
        """Pin bucket edges for ``name`` (before the first observe)."""
        with self._lock:
            if name in self._hist_edges and \
                    self._hist_edges[name] != tuple(edges):
                raise ValueError(
                    f"histogram {name!r} already defined with different "
                    f"edges {self._hist_edges[name]}")
            self._hist_edges[name] = tuple(edges)

    def observe(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = _Histogram(
                    self._hist_edges.get(name, DEFAULT_SECONDS_BUCKETS))
                self._hists[key] = h
            h.observe(float(value))

    # ---- read -------------------------------------------------------------

    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get((name, _label_key(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        with self._lock:
            return self._gauges.get((name, _label_key(labels)))

    def snapshot(self) -> dict:
        """JSON-serializable view of everything recorded so far."""
        with self._lock:
            counters = {f"{n}{_label_str(lk)}": v
                        for (n, lk), v in sorted(self._counters.items())}
            gauges = {f"{n}{_label_str(lk)}": v
                      for (n, lk), v in sorted(self._gauges.items())}
            hists = {}
            for (n, lk), h in sorted(self._hists.items()):
                hists[f"{n}{_label_str(lk)}"] = {
                    # +Inf spelled as a string so the snapshot stays
                    # strict-JSON (it is embedded in BENCH_results.json)
                    "buckets": [["+Inf" if le == float("inf") else le, c]
                                for le, c in h.cumulative()],
                    "sum": h.sum,
                    "count": h.count,
                }
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def to_prometheus(self) -> str:
        """Prometheus text exposition (0.0.4) of the current state."""
        lines: list[str] = []
        with self._lock:
            by_name: dict[str, list[str]] = {}
            for (n, lk), v in sorted(self._counters.items()):
                by_name.setdefault(f"{n}\tcounter", []).append(
                    f"{n}{_label_str(lk)} {_fmt(v)}")
            for (n, lk), v in sorted(self._gauges.items()):
                by_name.setdefault(f"{n}\tgauge", []).append(
                    f"{n}{_label_str(lk)} {_fmt(v)}")
            for (n, lk), h in sorted(self._hists.items()):
                samples = by_name.setdefault(f"{n}\thistogram", [])
                for le, c in h.cumulative():
                    le_s = "+Inf" if le == float("inf") else _fmt(le)
                    key = _label_key(dict(lk, le=le_s)) if lk else \
                        ((("le", le_s),))
                    samples.append(f"{n}_bucket{_label_str(tuple(key))} {c}")
                samples.append(f"{n}_sum{_label_str(lk)} {_fmt(h.sum)}")
                samples.append(f"{n}_count{_label_str(lk)} {h.count}")
        for name_type, samples in sorted(by_name.items()):
            name, mtype = name_type.split("\t")
            lines.append(f"# TYPE {name} {mtype}")
            lines.extend(samples)
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def parse_prometheus(text: str) -> dict:
    """Parse :meth:`MetricsRegistry.to_prometheus` output back into
    ``{"types": {name: type}, "samples": {name{labels}: value}}``.
    Strict enough to validate the exposition in CI and to round-trip a
    snapshot in tests; not a general Prometheus parser."""
    types: dict[str, str] = {}
    samples: dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        try:
            key, val = line.rsplit(" ", 1)
            samples[key] = float(val) if val != "+Inf" else float("inf")
        except ValueError as e:
            raise ValueError(
                f"malformed exposition line {lineno}: {line!r}") from e
    return {"types": types, "samples": samples}
