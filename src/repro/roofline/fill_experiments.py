"""Fill EXPERIMENTS.md placeholders from dry-run / hillclimb JSONs.

  PYTHONPATH=src python -m repro.roofline.fill_experiments
"""
from __future__ import annotations

import json
import os

from repro.roofline.report import SHAPE_ORDER, load

EXP = "EXPERIMENTS.md"


def _table(rows, mesh):
    sel = [r for r in rows if r["mesh"] == mesh and r["sparse"]]
    sel.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "useful FLOPs | roofline frac | GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sel:
        mem = r.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} | "
            f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
            f"{r['bottleneck'][:4]} | {r['useful_flops_frac']:.2f} | "
            f"{r['roofline_frac']:.3f} | {gib:.1f} |")
    return "\n".join(out)


def _dryrun_summary(rows):
    sel = [r for r in rows if r["sparse"]]
    n = len(sel) + sum(1 for r in rows if not r["sparse"])
    worst = max(sel, key=lambda r: (r.get("memory", {})
                                    .get("temp_size_in_bytes", 0)))
    wm = worst.get("memory", {})
    lines = [
        f"- {len(sel)} sparse cells across both meshes compiled "
        f"(+ dense variants in §Perf); every compile includes "
        f"memory_analysis + cost/collective analysis.",
        f"- tightest cell: {worst['arch']}|{worst['shape']}|{worst['mesh']} "
        f"at {(wm.get('argument_size_in_bytes',0)+wm.get('temp_size_in_bytes',0))/2**30:.1f} "
        f"GiB/dev (args+temp).",
    ]
    return "\n".join(lines)


def _hillclimb_table(path):
    if not os.path.exists(path):
        return "(pending)"
    rows = [json.loads(l) for l in open(path)]
    seen = {}
    for r in rows:
        seen[r["variant"]] = r  # last run wins
    out = ["| variant | t_comp ms | t_mem ms | t_coll ms | bound | "
           "GiB/dev (args+temp) |",
           "|---|---|---|---|---|---|"]
    for tag, r in seen.items():
        mem = r.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        out.append(f"| {tag} | {r['t_compute']*1e3:.1f} | "
                   f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
                   f"{r['bottleneck'][:4]} | {gib:.1f} |")
    return "\n".join(out)


def main():
    rows = load("experiments/dryrun")
    text = open(EXP).read()
    subs = {
        "<!-- DRYRUN_SUMMARY -->": _dryrun_summary(rows),
        "<!-- ROOFLINE_TABLE_SINGLE -->": _table(rows, "single"),
        "<!-- ROOFLINE_TABLE_MULTI -->": _table(rows, "multi"),
        "<!-- PERF_DSV2_TABLE -->":
            _hillclimb_table("experiments/hillclimb/dsv2-train.jsonl"),
        "<!-- PERF_YI_TABLE -->":
            _hillclimb_table("experiments/hillclimb/yi-decode.jsonl"),
        "<!-- PERF_GEMMA_TABLE -->":
            _hillclimb_table("experiments/hillclimb/gemma3-prefill.jsonl"),
    }
    for k, v in subs.items():
        if k in text:
            text = text.replace(k, v)
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
