"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str) -> list[dict]:
    rows = []
    for f in sorted(os.listdir(out_dir)):
        if f.endswith(".json"):
            with open(os.path.join(out_dir, f)) as fh:
                rows.append(json.load(fh))
    return rows


def fmt_table(rows: list[dict], mesh: str = "single",
              sparse: bool | None = True) -> str:
    sel = [r for r in rows if r["mesh"] == mesh
           and (sparse is None or r["sparse"] == sparse)]
    sel.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "useful FLOPs | roofline frac | GiB/dev (args+temp) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sel:
        mem = r.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['bottleneck'][:4]} | {r['useful_flops_frac']:.3f} | "
            f"{r['roofline_frac']:.3f} | {gib:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.dir)
    print(fmt_table(rows, args.mesh))


if __name__ == "__main__":
    main()
