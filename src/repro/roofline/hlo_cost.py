"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's HloCostAnalysis (compiled.cost_analysis()) counts every computation
ONCE — `while` bodies (jax.lax.scan over layers / microbatches / KV chunks
/ recurrences) are not multiplied by their trip counts, which undercounts
FLOPs/bytes/collectives by orders of magnitude on scan-structured models
(verified: a 10-step scanned matmul reports the FLOPs of one matmul).

This module re-derives the three roofline inputs by walking the HLO text:

FLOPs   — dot ops (2 * prod(out) * contracted), including dots inside
          fused computations; elementwise FLOPs are ignored (dots dominate
          model FLOPs; documented approximation).
bytes   — materialized-buffer traffic: every scheduled (top-level) op's
          OUTPUT is charged twice (written once, read ~once by its
          consumers). Counting operand lists directly triple-counts
          multi-consumer tensors and charges whole stacked per-layer
          arrays to every loop iteration; the output-centric convention
          matches buffer-assignment reality within ~2x. Exceptions:
            * dynamic-update-slice: 2x the update slice (RMW of a region,
              not the whole buffer);
            * scatter: 2x the updates;
            * fusion params whose only internal uses are
              (dynamic-)slice/gather additionally charge the slice read
              (their producer is a loop-carried buffer nobody else counts);
            * reshape/bitcast/tuple/gte/constant/iota: free.
          Fusion internals are never byte-counted (registers/VMEM).
collect — operand bytes per collective kind (all-gather scaled by
          1/group_size, reduce-scatter by group_size).

Totals multiply every `while` body by its trip count (parsed from the
condition's `compare(.., constant(N)), direction=LT` — how jax scans
lower), recursively. Unknown conditions count once.

Validated in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_LHS_RE = re.compile(r"^(?:ROOT )?%([\w\.\-]+) = ")
_CALLSITE_RE = re.compile(r"\b(while|fusion|call|conditional)\(")
_CALLED_RE = re.compile(
    r"(?:body|to_apply|calls|branch_computations)=\{?%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_SLICING_OPS = ("dynamic-slice", "slice", "gather")  # exact op names


def _nelems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dt: str, dims: str) -> float:
    return _nelems(dims) * _DTYPE_BYTES.get(dt, 0)


def _first_shape_bytes(seg: str) -> float:
    m = _SHAPE_RE.search(seg)
    return _shape_bytes(m.group(1), m.group(2)) if m else 0.0


def _all_shapes_bytes(seg: str) -> float:
    return sum(_shape_bytes(dt, d) for dt, d in _SHAPE_RE.findall(seg))


def _group_size(line: str) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def _lhs_bytes(line: str) -> float:
    """Output bytes: shapes between '=' and the op name's '('."""
    rhs = line.split("= ", 1)
    if len(rhs) < 2:
        return 0.0
    head = rhs[1].split("(", 1)[0]
    return _all_shapes_bytes(head)


def _op_of(line: str) -> str:
    rhs = line.split("= ", 1)
    if len(rhs) < 2:
        return ""
    m = re.search(r"([a-z0-9\-]+)\(", rhs[1])
    return m.group(1) if m else ""


def _operands(line: str) -> list[str]:
    if "(" not in line:
        return []
    args = line.split("(", 1)[1]
    # cut at the matching close paren
    depth = 1
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return _OPND_RE.findall(args)


@dataclasses.dataclass
class FusionInfo:
    dot_flops: float = 0.0
    # param name -> True if every use is a slicing op (charge slice size)
    sliced_params: dict = dataclasses.field(default_factory=dict)
    # param name -> largest slice-output bytes observed
    slice_bytes: dict = dataclasses.field(default_factory=dict)
    param_order: list = dataclasses.field(default_factory=list)
    # root is dynamic-update-slice: charge 2x update, not 2x buffer (the
    # buffer aliases in place; XLA "DUS fusion" pattern)
    root_dus_update_bytes: Optional[float] = None
    # fusion body is only converts/bitcasts: a CPU-backend materialization
    # of a dtype cast (free on TPU — fuses into the consumer)
    pure_convert: bool = False


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if not s:
            continue
        if not line.startswith(" ") and " -> " in s and s.endswith("{"):
            m = _COMP_HDR_RE.match(s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def analyze_hlo(hlo: str, detail: bool = False) -> dict:
    comps = _parse_computations(hlo)

    # global symbol table: op name -> (dtype, dims) of its (first) output
    shapes: dict[str, tuple[str, str]] = {}
    for lines in comps.values():
        for ln in lines:
            m = _LHS_RE.match(ln)
            if m:
                sm = _SHAPE_RE.search(ln[m.end():].split("(", 1)[0])
                if sm:
                    shapes[m.group(1)] = (sm.group(1), sm.group(2))

    def dot_flops(line: str) -> float:
        out_elems = 0
        m = _SHAPE_RE.search(line.split("= ", 1)[1])
        if m:
            out_elems = _nelems(m.group(2))
        ops = _operands(line)
        lhs_shape = shapes.get(ops[0]) if ops else None
        contracted = 1
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        if lhs_shape:
            lhs_dims = [int(x) for x in lhs_shape[1].split(",") if x]
            if cm:
                for d in (int(x) for x in cm.group(1).split(",") if x):
                    if d < len(lhs_dims):
                        contracted *= lhs_dims[d]
            elif lhs_dims:
                contracted = lhs_dims[-1]
        return 2.0 * out_elems * contracted

    # ---- per-fusion info (internal dots + sliced-param detection) --------
    fusion_info: dict[str, FusionInfo] = {}
    for name, lines in comps.items():
        fi = FusionInfo()
        uses: dict[str, list[str]] = {}
        body_ops = {_op_of(ln) for ln in lines
                    if " parameter(" not in ln and "= " in ln}
        fi.pure_convert = bool(body_ops) and body_ops <= {"convert",
                                                          "bitcast", ""}
        for ln in lines:
            if " parameter(" in ln:
                m = _LHS_RE.match(ln)
                if m:
                    fi.param_order.append(m.group(1))
                continue
            op = _op_of(ln)
            if op == "dot":
                fi.dot_flops += dot_flops(ln)
            if "dynamic-update-slice(" in ln:
                # in-place DUS (possibly behind a root convert): the buffer
                # aliases; only the updated region moves
                ops_ = _operands(ln)
                upd = (_shape_bytes(*shapes[ops_[1]])
                       if len(ops_) > 1 and ops_[1] in shapes else 0.0)
                fi.root_dus_update_bytes = max(
                    fi.root_dus_update_bytes or 0.0, upd)
            for o in _operands(ln):
                uses.setdefault(o, []).append(ln)
        for p in fi.param_order:
            plines = uses.get(p, [])
            if plines and all(_op_of(ln) in _SLICING_OPS for ln in plines):
                fi.sliced_params[p] = True
                fi.slice_bytes[p] = max(_lhs_bytes(ln) for ln in plines)
        fusion_info[name] = fi

    # ---- per-computation own costs + call edges ---------------------------
    @dataclasses.dataclass
    class CompCost:
        flops: float = 0.0
        bytes: float = 0.0
        coll: dict = dataclasses.field(default_factory=dict)
        calls: list = dataclasses.field(default_factory=list)

    costs: dict[str, CompCost] = {}
    line_charges: dict[str, list] = {}
    for name, lines in comps.items():
        c = CompCost()
        charges = line_charges.setdefault(name, [])
        for ln in lines:
            _b0 = c.bytes
            if " parameter(" in ln or "get-tuple-element(" in ln \
                    or " constant(" in ln or " iota(" in ln \
                    or " tuple(" in ln or " bitcast(" in ln:
                continue
            op = _op_of(ln)
            is_coll = False
            for coll in _COLLECTIVES:
                if re.search(rf"\b{coll}(?:-start)?\(", ln):
                    ob = _lhs_bytes(ln)
                    gs = _group_size(ln)
                    if coll == "all-gather":
                        ob /= max(gs, 1)
                    elif coll == "reduce-scatter":
                        ob *= gs
                    c.coll[coll] = c.coll.get(coll, 0.0) + ob
                    c.bytes += 2 * ob  # in + out at the op boundary
                    charges.append((2 * ob, ln[:140]))
                    is_coll = True
                    break
            if is_coll:
                continue
            m = _CALLSITE_RE.search(ln)
            if m and m.group(1) in ("while", "call", "conditional"):
                called = _CALLED_RE.search(ln)
                cond = _COND_RE.search(ln)
                if called:
                    c.calls.append((m.group(1), called.group(1),
                                    cond.group(1) if cond else None))
                continue
            if op == "fusion":
                called = _CALLED_RE.search(ln)
                fi = fusion_info.get(called.group(1)) if called else None
                extra = 0.0
                if fi:
                    opnds = _operands(ln)
                    for i, _o in enumerate(opnds):
                        if i < len(fi.param_order) and \
                                fi.param_order[i] in fi.sliced_params:
                            extra += fi.slice_bytes[fi.param_order[i]]
                    c.flops += fi.dot_flops
                if fi and fi.pure_convert:
                    pass  # dtype-cast materialization: free on TPU
                elif fi and fi.root_dus_update_bytes is not None:
                    c.bytes += 2 * fi.root_dus_update_bytes + extra
                else:
                    c.bytes += 2 * _lhs_bytes(ln) + extra
                charges.append((c.bytes - _b0, ln[:140]))
                continue
            if op == "dot":
                c.flops += dot_flops(ln)
                c.bytes += 2 * _lhs_bytes(ln)
            elif op in ("dynamic-slice", "slice", "gather"):
                c.bytes += 2 * _lhs_bytes(ln)  # reads+writes a slice's worth
            elif op == "dynamic-update-slice":
                ops_ = _operands(ln)
                upd = (_shape_bytes(*shapes[ops_[1]])
                       if len(ops_) > 1 and ops_[1] in shapes else 0.0)
                c.bytes += 2 * upd
            elif op == "scatter":
                ops_ = _operands(ln)
                upd = sum(_shape_bytes(*shapes[o]) for o in ops_[1:]
                          if o in shapes)
                c.bytes += 2 * upd
            elif op in ("reshape", "copy-start", "copy-done", "convert"):
                # convert: on TPU dtype casts fuse into the consuming op
                # (mixed-precision dots are MXU-native); the CPU backend
                # materializes them — a lowering artifact, not charged
                pass
            elif op:
                c.bytes += 2 * _lhs_bytes(ln)
            if c.bytes - _b0 > 0:
                charges.append((c.bytes - _b0, ln[:140]))
        costs[name] = c

    # ---- while trip counts -------------------------------------------------
    def trip_count(cond_name: str) -> int:
        consts = []
        for ln in comps.get(cond_name, []):
            mm = _CONST_RE.search(ln)
            if mm:
                consts.append(int(mm.group(1)))
        return max(consts) if consts else 1

    memo: dict[str, tuple[float, float, dict]] = {}

    def total(name: str) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        c = costs.get(name, CompCost())
        memo[name] = (c.flops, c.bytes, dict(c.coll))  # cycle guard
        f, b = c.flops, c.bytes
        coll = dict(c.coll)
        for kind, called, cond in c.calls:
            cf, cb, cc = total(called)
            mult = trip_count(cond) if kind == "while" and cond else 1
            f += cf * mult
            b += cb * mult
            for k2, v in cc.items():
                coll[k2] = coll.get(k2, 0.0) + v * mult
        memo[name] = (f, b, coll)
        return memo[name]

    entry = next((n for n in comps if n.startswith("main")), None)
    if entry is None:
        called_set = {c2 for cc in costs.values() for _, c2, _ in cc.calls}
        entry = next((n for n in comps if n not in called_set),
                     next(iter(comps)))
    f, b, coll = total(entry)
    coll["total"] = sum(coll.values())
    out = {"flops": f, "bytes": b, "collectives": coll, "entry": entry}
    if detail:
        mults: dict[str, float] = {}

        def walk(name, m):
            mults[name] = mults.get(name, 0) + m
            for kind, called, cond in costs.get(name, CompCost()).calls:
                walk(called,
                     m * (trip_count(cond) if kind == "while" and cond
                          else 1))

        walk(entry, 1)
        out["percomp"] = {
            n: {"flops": costs[n].flops, "bytes": costs[n].bytes,
                "mult": mults.get(n, 0),
                "top_lines": sorted(line_charges.get(n, []),
                                    reverse=True)[:6]}
            for n in comps
        }
        out["_costs"] = costs
    return out
