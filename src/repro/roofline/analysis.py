"""Roofline terms from a compiled dry-run artifact.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_operand_bytes_per_chip / link_bw

(cost_analysis and the post-optimization HLO are per-device programs, so
the per-chip forms above are identical to the task's global/(chips*rate)
formulas.)

Hardware constants (task spec, TPU v5e-class): 197 bf16 TFLOP/s per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s/link (conservative single-link form)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <output-type> <op>(" — output-type may be a tuple of shapes
_LINE_RE = re.compile(
    r"= ((?:\([^)]*\))|(?:\S+)) "
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[...]
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Per-device collective *operand* bytes from post-SPMD HLO.

    Post-optimization HLO names operands without inline types, so operand
    bytes are derived from the output shapes on the LHS:
      all-gather:     operand = output / group_size
      reduce-scatter: operand = output * group_size
      all-reduce / all-to-all / collective-permute: operand = output
    `-done` ops are skipped (their `-start` was already counted).
    """
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m is None:
            continue
        outtype, coll = m.group(1), m.group(2)
        ob = sum(_shape_bytes(dt, dims)
                 for dt, dims in _SHAPE_RE.findall(outtype))
        gs = _group_size(line)
        if coll == "all-gather":
            ob = ob / max(gs, 1)
        elif coll == "reduce-scatter":
            ob = ob * gs
        out[coll] += ob
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: dict
    model_flops: float  # analytic 6ND (or decode 2ND) GLOBAL
    memory: dict  # memory_analysis fields (per chip)

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — remat/redundancy/decompress waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the bound spent on useful model FLOPs: the score.
        (model_flops/chips/peak) / t_bound."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) / self.t_bound

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_frac=self.useful_flops_frac,
                 roofline_frac=self.roofline_frac)
        return d


def analyze(name: str, compiled, chips: int, model_flops: float,
            hlo_text: Optional[str] = None) -> RooflineReport:
    """Roofline terms via the trip-count-aware HLO analyzer.

    XLA's own cost_analysis counts while (scan) bodies once — orders of
    magnitude off for scan-over-layers models (tests/test_hlo_cost.py) —
    so flops/bytes/collectives come from roofline.hlo_cost instead.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    h = analyze_hlo(text)
    flops = float(h["flops"])
    byts = float(h["bytes"])
    colls = dict(h["collectives"])
    for c in _COLLECTIVES:
        colls.setdefault(c, 0.0)
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            mem[f] = int(v)
    return RooflineReport(
        name=name, chips=chips, flops_per_chip=flops, bytes_per_chip=byts,
        collective_bytes_per_chip=colls["total"],
        collective_breakdown={k: v for k, v in colls.items() if k != "total"},
        model_flops=model_flops, memory=mem,
    )


def model_flops_for(cfg, shape, n_params_active: int, n_params_total: int,
                    sparse_density: float = 1.0) -> float:
    """Analytic MODEL_FLOPS for the cell.

    train:   6 * N_active * tokens     (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch      (one token per sequence)
    """
    n = n_params_active
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch


def fmt_row(r: RooflineReport) -> str:
    return (f"| {r.name} | {r.chips} | {r.t_compute*1e3:.2f} | "
            f"{r.t_memory*1e3:.2f} | {r.t_collective*1e3:.2f} | "
            f"{r.bottleneck} | {r.useful_flops_frac:.2f} | "
            f"{r.roofline_frac:.2f} |")
