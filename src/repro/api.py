"""Public facade for the compressed N:M representation.

The verbs most users need:

  sparsify(w, nm)   dense (K, N) array -> NMWeight (prune + compress)
  quantize(w)       NMWeight / dense array -> int8 QNMWeight (+ scales)
  dequantize(qw)    QNMWeight -> float NMWeight (fallback path)
  densify(w)        any typed weight node / {"w": ...} -> dense array
  nm_matmul(x, w, epilogue=...)
                    y = epilogue(x @ densify(w)), dispatched by w's own
                    metadata and *type* (QNMWeight -> the int8 kernel
                    family); skinny-M calls route to the fused decode
                    kernel family
  explain_dispatch(x_shape, w)
                    the DispatchRecord nm_matmul *would* produce —
                    family, kernel, block, pad plan — without running
  is_sparse(obj)    True for typed sparse weight nodes
  attention(q, k, v, mask=..., cache=...)
                    block-sparse attention under a declared MaskSpec;
                    prefill vs cache-view decode/chunk dispatch decided
                    by the CacheView argument (None = prefill/train);
                    explain_dispatch_attention is its dry-run twin

An :class:`NMWeight` is a registered JAX pytree: ``vals``/``idx`` are
leaves (jit/vmap/grad/shard like any array), while the ``NMConfig``, the
compressed ``axis`` and the :class:`KernelPolicy` ride as static treedef
metadata — the weight is self-describing, so nothing threads a sparsity
config through apply paths, and different layers of one model can carry
different N:M patterns.

Kernel policy semantics (``KernelPolicy.mode``):

  off    always the XLA reference implementation (default).
  auto   padded Pallas kernel when the shape normalizes within the
         padding waste limit (REPRO_PAD_WASTE_LIMIT), else reference.
  force  Pallas whenever the shape normalizes at all; the waste limit
         is ignored.

``KernelPolicy.block`` optionally pins the (block_m, block_n, block_k)
tile triple (``decode_block`` likewise for the decode family); ``None``
consults the autotune cache.

Kernel backends (``KernelPolicy.backend`` / the ``backend=`` kwarg on
``nm_matmul`` / ``explain_dispatch`` / ``indexmac_gather``):

  auto   (default) ``$REPRO_BACKEND`` if set, else the device platform
         — a GPU host resolves to ``gpu``, everything else to ``tpu``.
  tpu    the Pallas-on-Mosaic kernel family (interprets off-TPU).
  gpu    the Pallas-on-Triton family (:mod:`repro.kernels.indexmac_gpu`)
         — available on a GPU host, or anywhere under
         ``REPRO_GPU_INTERPRET=1`` (interpreter; CI parity lane).

Forcing a backend the host cannot execute raises the typed
:class:`KernelForceError` naming the backend; ``explain_dispatch``
dry-runs the identical resolution without executing a kernel, and the
:class:`DispatchRecord` it returns (like every record the real calls
write) carries the resolved ``backend`` field.

Epilogues: :class:`Epilogue` is a (bias, activation-name) spec.
``nm_matmul(x, w, epilogue=Epilogue(bias=b, activation="silu"))``
computes ``silu(x @ densify(w) + b)`` with one composition contract on
every path — fused into the decode kernels' f32 accumulator writeback,
applied identically outside the prefill-shaped kernels — so outputs are
bit-exact against the reference composition on the integer lattice.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.nmweight import (
    KernelPolicy,
    MaskedNMWeight,
    NMWeight,
    is_weight_node,
)
from repro.core.sparsity import (
    NMConfig,
    apply_mask,
    compress_nm,
    decompress_nm,
    prune_mask_nm,
)
from repro.kernels.backend import resolve_backend  # noqa: F401 (re-export)
from repro.kernels.blocksparse_attn.mask import MaskSpec
from repro.kernels.blocksparse_attn.ops import (
    MaskForceError,
    bs_attention as _bs_attention,
    bs_attention_decode as _bs_attention_decode,
    explain_dispatch_attention as _explain_dispatch_attention,
)
from repro.kernels.epilogue import Epilogue
from repro.kernels.indexmac.ops import (
    explain_dispatch as _explain_dispatch,
)
from repro.kernels.indexmac.ops import nm_matmul as _nm_matmul_typed
from repro.kernels.indexmac_gather.ops import (
    indexmac_gather as _indexmac_gather,
)
import repro.kernels.indexmac_gpu.ops  # noqa: F401 (gpu-backend registrations)
from repro.kernels.registry import DispatchRecord, KernelForceError
from repro.models.cache import CacheView
from repro.quant import QNMWeight
from repro.quant import dequantize as _dequantize
from repro.quant import quantize_nm as _quantize_nm
from repro.quant import quantize_tree, dequantize_tree  # noqa: F401 (re-export)

__all__ = [
    "CacheView",
    "DispatchRecord",
    "Epilogue",
    "KernelForceError",
    "KernelPolicy",
    "MaskForceError",
    "MaskSpec",
    "MaskedNMWeight",
    "NMConfig",
    "NMWeight",
    "QNMWeight",
    "attention",
    "conv2d",
    "densify",
    "dequantize",
    "dequantize_tree",
    "explain_dispatch",
    "explain_dispatch_attention",
    "indexmac_gather",
    "is_sparse",
    "nm_matmul",
    "quantize",
    "quantize_tree",
    "resolve_backend",
    "sparsify",
    "sparsify_conv",
]


def _as_policy(kernel_policy) -> KernelPolicy:
    if isinstance(kernel_policy, KernelPolicy):
        return kernel_policy
    if isinstance(kernel_policy, str):
        return KernelPolicy(mode=kernel_policy)
    raise TypeError(
        f"kernel_policy must be a KernelPolicy or a mode string "
        f"('off' | 'auto' | 'force'), got {type(kernel_policy).__name__}"
    )


def sparsify(
    w: jax.Array,
    nm: NMConfig,
    *,
    axis: int = 0,
    kernel_policy: Union[KernelPolicy, str] = KernelPolicy("auto"),
) -> NMWeight:
    """Prune a dense weight to top-|w| N:M along ``axis`` and compress.

    An already N:M-sparse ``w`` passes through losslessly (its non-zeros
    are the per-block top-n by construction). ``axis=0`` is the
    contraction dim of ``y = x @ W`` — what ``nm_matmul`` consumes.
    """
    if w.ndim != 2:
        raise ValueError(f"sparsify expects a 2D weight, got shape {w.shape}")
    if w.shape[axis] % nm.m != 0:
        raise ValueError(
            f"axis {axis} size {w.shape[axis]} not divisible by M={nm.m}")
    pruned = apply_mask(w, prune_mask_nm(w, nm, axis=axis))
    vals, idx = compress_nm(pruned, nm, axis=axis)
    return NMWeight(vals=vals, idx=idx, nm=nm, axis=axis,
                    kernel_policy=_as_policy(kernel_policy))


def quantize(w, nm=None, *, method="absmax", axis: int = 0,
             kernel_policy=None) -> QNMWeight:
    """int8-quantize a weight (symmetric, per output channel).

    ``w`` is an :class:`NMWeight` (the common case — quantize after
    sparsify) or a dense 2D array (``nm`` required; pruned + compressed
    first). ``method`` is ``"absmax"`` | ``"percentile"`` or a
    pre-populated observer from :mod:`repro.quant.calibrate`. For whole
    param trees use :func:`quantize_tree`.
    """
    return _quantize_nm(w, nm, method=method, axis=axis,
                        kernel_policy=kernel_policy)


def dequantize(qw: QNMWeight, dtype=None) -> NMWeight:
    """Float :class:`NMWeight` with the same pattern — the fallback for
    consumers that cannot take the int8 path."""
    return _dequantize(qw, dtype=dtype or jnp.float32)


def densify(w) -> jax.Array:
    """Materialize the dense array behind any linear-weight node."""
    if isinstance(w, NMWeight):
        return decompress_nm(w.vals, w.idx, w.nm, axis=w.axis)
    if isinstance(w, QNMWeight):
        return w.to_dense()
    if isinstance(w, MaskedNMWeight):
        return w.project()
    if isinstance(w, dict) and "w" in w:
        return w["w"]
    return w  # already a dense array


def is_sparse(obj) -> bool:
    """True for the typed sparse weight nodes (compressed or masked)."""
    return is_weight_node(obj)


def nm_matmul(x: jax.Array, w, *,
              block: Optional[tuple[int, int, int]] = None,
              epilogue: Optional[Epilogue] = None,
              backend: Optional[str] = None) -> jax.Array:
    """y = epilogue(x @ densify(w)) for an :class:`NMWeight` or int8
    :class:`QNMWeight`; dispatch (reference vs Pallas, decode vs prefill
    family, tile sizes, kernel backend, and the float-vs-int8 kernel
    family) is decided by ``w.kernel_policy``, the weight's type and the
    flattened row count — see the module docstring. ``epilogue`` is an
    :class:`Epilogue` (bias + activation) fused into the decode kernels'
    writeback; ``backend`` (``"auto"``/``"tpu"``/``"gpu"``) overrides
    the policy's kernel backend for this call."""
    return _nm_matmul_typed(x, w, block=block, epilogue=epilogue,
                            backend=backend)


def explain_dispatch(x_shape, w, *, epilogue: Optional[Epilogue] = None,
                     dtype=None, backend: Optional[str] = None,
                     ) -> DispatchRecord:
    """The :class:`DispatchRecord` that ``nm_matmul(x, w)`` (or, for an
    axis-1 weight, ``indexmac_gather(w, b)``) *would* produce for an
    operand of shape ``x_shape`` — dispatch family, chosen kernel,
    resolved backend, block triple and padded geometry — without
    executing anything. ``backend`` overrides the policy's kernel
    backend, same contract as :func:`nm_matmul`. Raises the same typed
    errors as the real call, including :class:`KernelForceError` for a
    forced weight whose shape cannot normalize or a forced backend this
    host cannot execute."""
    return _explain_dispatch(x_shape, w, epilogue=epilogue, dtype=dtype,
                             backend=backend)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, mask: MaskSpec,
              cache: Optional[CacheView] = None, scale=None,
              policy="auto", backend: Optional[str] = None,
              tile: Optional[tuple[int, int]] = None) -> jax.Array:
    """Block-sparse attention under a declared :class:`MaskSpec` — the
    attention sibling of :func:`nm_matmul`: one typed entry, family
    dispatch by shape and cache view.

    ``cache=None`` is the prefill/train case (q and k/v cover the same
    absolute positions from 0): routes the ``bs_attention`` family —
    pair-list Pallas kernel on TPU, gather kernel on the gpu lane,
    XLA block-gather elsewhere, dense fallback under the density/waste
    budgets (``REPRO_BS_DENSITY_LIMIT`` / ``REPRO_BS_WASTE_LIMIT``).

    A :class:`CacheView` in decode/chunk mode means k/v are fixed-size
    cache views: routes ``bs_attention_decode`` with the valid extent
    ``cache_len + Sq`` (chunk mode masks by the queries' absolute
    positions). ``policy``/``backend``/``tile`` follow the
    :class:`KernelPolicy` contract; ``KernelPolicy("force")`` on an
    untileable mask raises the typed :class:`MaskForceError`."""
    if cache is None:
        return _bs_attention(q, k, v, spec=mask, scale=scale, policy=policy,
                             backend=backend, tile=tile)
    if not isinstance(cache, CacheView):
        raise TypeError(
            f"cache must be a CacheView (or None for prefill/train), got "
            f"{type(cache).__name__}")
    if not cache.offset_mode:
        raise ValueError(
            f"a {cache.mode!r} CacheView carries no cache offset — pass "
            f"cache=None for prefill/train attention")
    sq = q.shape[1]
    q_positions = cache.positions
    if cache.mode == "chunk" and q_positions is None:
        cl = cache.cache_len
        q_positions = (cl[:, None] + jnp.arange(sq) if cl.ndim == 1
                       else jnp.arange(sq) + cl)
    if cache.mode == "decode":
        q_positions = None
    return _bs_attention_decode(
        q, k, v, spec=mask, length=cache.cache_len + sq,
        q_positions=q_positions, scale=scale, policy=policy,
        backend=backend)


def explain_dispatch_attention(q_shape, kv_shape, *, mask: MaskSpec,
                               decode: bool = False, dtype=None,
                               policy="auto", backend: Optional[str] = None,
                               tile: Optional[tuple[int, int]] = None,
                               ) -> DispatchRecord:
    """The :class:`DispatchRecord` that :func:`attention` *would* write
    for operands of these shapes (``decode=True`` for the cache-view
    family) — it shares the route function with the executing call, so
    the explanation cannot drift from the real dispatch. Raises the same
    typed errors, including :class:`MaskForceError` for a forced
    untileable mask."""
    return _explain_dispatch_attention(
        q_shape, kv_shape, mask=mask, decode=decode,
        dtype=dtype if dtype is not None else jnp.float32, policy=policy,
        backend=backend, tile=tile)


def indexmac_gather(w, b: jax.Array, *,
                    block: Optional[tuple[int, int, int]] = None,
                    backend: Optional[str] = None) -> jax.Array:
    """C = densify(w) @ b for a row-compressed A (``w.axis == 1``) — the
    literal gather-port orientation of the paper. Accepts an
    :class:`NMWeight` or int8 :class:`QNMWeight`; ``backend`` overrides
    the policy's kernel backend."""
    return _indexmac_gather(w, b, block=block, backend=backend)


def sparsify_conv(
    w: jax.Array,
    nm: NMConfig,
    *,
    kernel_policy: Union[KernelPolicy, str] = KernelPolicy("auto"),
) -> NMWeight:
    """Prune + compress a conv kernel for the im2col GEMM path.

    ``w`` is HWIO ``(kh, kw, C_in, C_out)``; the N:M pattern is applied
    along the flattened contraction axis K = kh*kw*C_in (the axis
    :func:`conv2d` contracts over), so the result is exactly the weight
    node a :class:`repro.models.conv.SparseConv2D` holds.
    """
    if w.ndim != 4:
        raise ValueError(
            f"sparsify_conv expects an HWIO (kh, kw, C_in, C_out) kernel, "
            f"got shape {w.shape}")
    kh, kw, c_in, c_out = w.shape
    return sparsify(w.reshape(kh * kw * c_in, c_out), nm, axis=0,
                    kernel_policy=kernel_policy)


def conv2d(x: jax.Array, w, *, kh: int, kw: int, stride=1,
           padding: str = "SAME", compute_dtype=None) -> jax.Array:
    """y = conv(x, densify(w)) through the im2col GEMM on the kernel
    path; ``w`` is a node from :func:`sparsify_conv` (or its quantized /
    dense sibling). See :mod:`repro.models.conv` for layers and whole
    backbones."""
    from repro.models.conv import conv2d as _conv2d  # lazy: api <-> models

    return _conv2d(x, w, kh=kh, kw=kw, stride=stride, padding=padding,
                   compute_dtype=compute_dtype)
