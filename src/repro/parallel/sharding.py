"""Sharding rules: params, optimizer state, caches, and batches -> PartitionSpecs.

Mesh axes (launch/mesh.py):
  single-pod: ("data"=16, "model"=16)          — 256 chips
  multi-pod:  ("pod"=2, "data"=16, "model"=16) — 512 chips

Strategy (MaxText-style 2D param sharding):
  * TP over "model": attention heads / FFN hidden / vocab / experts (EP).
  * FSDP over "data": the other large axis of every 2D+ weight is sharded
    over "data" — parameter and optimizer-state memory scale with the full
    mesh (ZeRO-3-equivalent storage; XLA SPMD inserts the all-gathers).
  * DP over ("pod", "data") for the batch; gradients all-reduce over those
    axes (cross-pod traffic only carries gradient reductions).
  * SP for decode caches: the sequence axis shards over "model" so a 524k
    KV cache fits; softmax reductions over the sharded axis lower to
    all-reduces.

`sharding_mode`:
  fsdp     — as above (default; memory-optimal)
  tp_only  — params replicated over "data" (lower collective volume,
             higher memory) — a hillclimb knob
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import AttnConfig, MoEConfig, ModelConfig
from repro.core.nmweight import MaskedNMWeight, NMWeight, is_weight_node
from repro.quant import QNMWeight

# parameter leaves whose *last-but-one / last* axes are (in, out) of a GEMM,
# keyed by leaf name: value = (spec for in-axis, spec for out-axis)
_COL = ("data", "model")   # column-parallel: out axis = heads/ffn-hidden
_ROW = ("model", "data")   # row-parallel: in axis sharded
_GEMM_RULES: dict[str, tuple] = {
    # attention
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "wq_a": _COL, "wq_b": _COL, "wkv_a": ("data", None), "wk_rope": ("data", None),
    # ffn
    "w_up": _COL, "w_gate": _COL, "w_down": _ROW,
    # mamba / rwkv projections
    "w_in": _COL, "w_x": _ROW, "w_dt": (None, "model"), "w_out": _ROW,
    "w_r": _COL, "w_k": _COL, "w_v": _COL, "w_g": _COL, "w_o": _ROW,
    "w_cm_k": _COL, "w_cm_v": _ROW, "w_cm_r": _COL,
    # heads / embeddings
    "w_lm_head": _COL,
    # router stays replicated (tiny, and gate math wants full logits)
    "w_router": (None, None),
}
# non-GEMM leaves: full spec by name (leading axes listed explicitly)
_NAMED_RULES: dict[str, tuple] = {
    "embedding": ("model", "data"),       # vocab x d_model
    "pos": (None, "data"),
    "enc_pos": (None, "data"),
    "w_uk": ("model", None, None),        # (heads, lora, hd) — heads = TP
    "w_uv": ("model", None, None),
    "conv_w": (None, "model"),
    "a_log": ("model", None),
    "mix_lora_a": ("data", None),
    "mix_lora_b": (None, None, "data"),
    "decay_lora_a": ("data", None),
    "decay_lora_b": (None, "data"),
    "mu": (None, "data"),
    "cm_mu": (None, "data"),
    "bonus": (None, None),
}


def _axis_ok(dim: int, axis, mesh_shape: dict[str, int]) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else axis
    size = int(np.prod([mesh_shape[a] for a in names]))
    return dim % size == 0


def _fit(spec: tuple, shape: tuple, mesh_shape: dict[str, int]) -> P:
    """Drop axes that don't divide; pad/trim spec to the array rank
    (stacked scan params get leading None axes)."""
    spec = tuple(spec)
    if len(spec) < len(shape):
        spec = (None,) * (len(shape) - len(spec)) + spec
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    fixed = tuple(
        s if _axis_ok(d, s, mesh_shape) else None for d, s in zip(shape, spec)
    )
    return P(*fixed)


def _gemm_rule(owner: str) -> tuple:
    rule = _GEMM_RULES.get(owner)
    if rule is None:
        rule = _COL if owner not in ("router",) else (None, None)
    if owner == "router":
        rule = (None, None)
    if owner == "lm_head":
        rule = _COL
    return rule


def _adjust_rule(rule: tuple, names: list, sharding_mode: str) -> tuple:
    if "experts" in names:
        # experts are stacked on a leading E axis -> expert parallelism
        rule = ("model",) + tuple(None if r == "model" else r for r in rule)
    elif "shared" in names:
        # shared experts enter the MoE shard_map as pure TP blocks
        rule = tuple(None if r == "data" else r for r in rule)
    if sharding_mode == "tp_only":
        rule = tuple(None if r == "data" else r for r in rule)
    return rule


def _leaf_spec(path: tuple, leaf, mesh_shape: dict[str, int],
               sharding_mode: str):
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]

    if isinstance(leaf, NMWeight):
        # typed dispatch: the GEMM rule comes from the weight's own slot
        # name; idx is co-sharded with vals (same logical layout — both
        # halves of the compressed operand the FSDP gather must move
        # together).
        rule = _adjust_rule(_gemm_rule(name), names, sharding_mode)
        return dataclasses.replace(
            leaf,
            vals=_fit(rule, leaf.vals.shape, mesh_shape),
            idx=_fit(rule, leaf.idx.shape, mesh_shape),
        )
    if isinstance(leaf, QNMWeight):
        # quantized triple: vals/idx shard like the float pair; the
        # per-output-channel scales are co-sharded with vals' output
        # axis (the channel a scale belongs to must live on the shard
        # that holds its column — the writeback multiply is local).
        # Explicit leading rule axes (expert stacking) carry over too:
        # scales of an expert-sharded (E, ..., N) weight are (E, N) and
        # must shard the E axis with vals, not replicate across it.
        rule = _adjust_rule(_gemm_rule(name), names, sharding_mode)
        out_rule = rule[-1] if leaf.axis == 0 else rule[-2]
        scales_rule = tuple(rule[:-2]) + (out_rule,)
        return dataclasses.replace(
            leaf,
            vals=_fit(rule, leaf.vals.shape, mesh_shape),
            idx=_fit(rule, leaf.idx.shape, mesh_shape),
            scales=_fit(scales_rule, leaf.scales.shape, mesh_shape),
        )
    if isinstance(leaf, MaskedNMWeight):
        rule = _adjust_rule(_gemm_rule(name), names, sharding_mode)
        return dataclasses.replace(
            leaf, w=_fit(rule, leaf.w.shape, mesh_shape))

    if name == "w":
        owner = names[-2] if len(names) >= 2 else ""
        rule = _gemm_rule(owner)
    elif name in _NAMED_RULES:
        rule = _NAMED_RULES[name]
    else:
        rule = (None,) * leaf.ndim

    rule = _adjust_rule(rule, names, sharding_mode)
    return _fit(rule, leaf.shape, mesh_shape)


def param_pspecs(params: Any, mesh: Mesh, sharding_mode: str = "fsdp"):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, mesh_shape, sharding_mode), params,
        is_leaf=is_weight_node,
    )


def param_shardings(params: Any, mesh: Mesh, sharding_mode: str = "fsdp"):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_pspecs(params, mesh, sharding_mode),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

# axis-1-is-sequence cache leaves (sharded over "model" = SP for decode)
_SEQ_CACHE = {"k", "v", "ckv", "kr", "cross_k", "cross_v"}


def _cache_leaf_spec(path, leaf, mesh_shape, batch_axes) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    # stacked scan caches have a leading layer axis; batch is the first
    # axis whose size matches nothing structural — we detect by rank of the
    # known layouts instead:
    base_rank = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4, "ckv": 3,
                 "kr": 3, "conv": 3, "ssm": 3, "wkv": 4, "tm_last": 2,
                 "cm_last": 2}.get(name, leaf.ndim)
    lead = leaf.ndim - base_rank  # 0 or 1 (scan-stacked)
    spec = [None] * leaf.ndim
    b_dim = lead  # batch axis position
    if _axis_ok(leaf.shape[b_dim], batch_axes, mesh_shape):
        # unwrap singleton axis tuples: P("data") and P(("data",)) shard
        # identically but only compare equal on newer JAX
        spec[b_dim] = (batch_axes[0]
                       if isinstance(batch_axes, tuple) and len(batch_axes) == 1
                       else batch_axes)
    if name in _SEQ_CACHE and _axis_ok(leaf.shape[b_dim + 1], "model",
                                       mesh_shape):
        spec[b_dim + 1] = "model"
    elif name in ("conv", "ssm") and _axis_ok(leaf.shape[b_dim + 1], "model",
                                              mesh_shape):
        # mamba states: channel axis over model
        if name == "ssm":
            spec[b_dim + 1] = "model"
        else:
            spec[b_dim + 2] = ("model" if _axis_ok(leaf.shape[b_dim + 2],
                                                   "model", mesh_shape)
                               else None)
    return P(*spec)


def cache_pspecs(caches: Any, mesh: Mesh, batch_axes=("data",)):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _cache_leaf_spec(p, l, mesh_shape, batch_axes), caches
    )


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# tensor-parallel serving (shard_map) specs
#
# The sharded serving engine runs the model *manually* partitioned under
# shard_map: column-parallel q/k/v/up/gate projections (out axis over
# "model"), row-parallel wo/w_down (in axis over "model", partial sums
# psum'd via hints.tp_reduce), KV caches sharded on the head axis, batch
# slots over "data". These rules are head-aware — a projection only
# shards when the *head count* divides the TP degree, not merely the flat
# axis (splitting head_dim would scramble the (B,S,H,D) reshapes) — so
# they live apart from the GSPMD training rules above. NMWeight /
# QNMWeight nodes keep vals+idx(+scales) co-sharded, and row-parallel
# compressed weights additionally require the per-shard slice to land on
# an N:M group boundary (validated here, loudly).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeTPPlan:
    """Which projection families shard over "model" for a given config.

    Uniform across the whole plan by construction (`serve_tp_plan`
    raises otherwise): the psum placement in the model (`tp_reduce`
    tags) is global, so a half-sharded plan would double-count."""

    tp: int
    shard_attn: bool  # wq(+wq_b/w_uk/w_uv) out axis, wo in axis
    shard_kv: bool    # wk/wv out axis + cache head axis (GQA only)
    shard_ffn: bool   # w_up/w_gate out axis, w_down in axis

    @property
    def reduce_tags(self) -> frozenset:
        tags = set()
        if self.shard_attn:
            tags.add("attn_out")
        if self.shard_ffn:
            tags.add("ffn_down")
        return frozenset(tags)


def serve_tp_plan(cfg: ModelConfig, tp: int) -> ServeTPPlan:
    """Decide (and validate) the TP sharding for serving ``cfg``.

    Supported plans: attention mixers (GQA / MLA) with dense-FFN or no
    MLP, no cross-attention. MoE would nest its own shard_map, and
    mamba/rwkv state caches have no head axis — both raise."""
    attn_f: set = set()
    kv_f: set = set()
    ffn_f: set = set()
    for entry, _rep in cfg.plan:
        blocks = entry if isinstance(entry, tuple) else (entry,)
        for blk in blocks:
            mx = blk.mixer
            if not isinstance(mx, AttnConfig) or blk.cross_attn:
                raise NotImplementedError(
                    f"TP serving supports attention-mixer decoder plans; "
                    f"{cfg.name} has {type(mx).__name__}"
                    f"{' + cross_attn' if blk.cross_attn else ''}")
            if isinstance(blk.mlp, MoEConfig):
                raise NotImplementedError(
                    f"TP serving does not support MoE blocks ({cfg.name}):"
                    " moe_apply opens its own shard_map")
            if tp == 1:
                continue
            q_ok = mx.q_heads % tp == 0
            if mx.kind == "mla":
                attn_f.add(q_ok)
                kv_f.add(False)  # latent ckv/kr cache is head-free
            else:
                kv_ok = q_ok and mx.kv_heads % tp == 0
                if q_ok and not kv_ok and mx.kv_heads != 1:
                    # q-sharding over replicated KV is only sound for
                    # MQA (kv_heads == 1): with kv_heads > 1 a shard's
                    # contiguous q-head slice spans one *global* KV
                    # group, but the local (hkv, g) reshape would pair
                    # it round-robin across all KV heads — wrong tokens,
                    # silently. Fall back to replicated attention.
                    q_ok = False
                attn_f.add(q_ok)
                kv_f.add(kv_ok and q_ok)
            if blk.mlp is not None:
                ffn_f.add(blk.mlp.d_ff % tp == 0)
    if tp == 1:
        return ServeTPPlan(1, False, False, False)
    if len(attn_f) > 1 or len(kv_f) > 1 or len(ffn_f) > 1:
        raise ValueError(
            f"{cfg.name}: plan is not uniformly TP-shardable at tp={tp} "
            "(blocks disagree on head/d_ff divisibility); the global psum "
            "tags cannot represent a mixed plan")
    return ServeTPPlan(tp,
                       attn_f.pop() if attn_f else False,
                       kv_f.pop() if kv_f else False,
                       ffn_f.pop() if ffn_f else False)


def serve_local_cfg(cfg: ModelConfig, plan: ServeTPPlan) -> ModelConfig:
    """The per-shard view of ``cfg``: head counts divided by tp so the
    (B, S, H, D) reshapes inside the mixers match the local projections.
    d_ff needs no scaling — ffn_apply derives shapes from the weights."""
    if plan.tp == 1 or not (plan.shard_attn or plan.shard_kv):
        return cfg
    new_plan = []
    for entry, rep in cfg.plan:
        blocks = entry if isinstance(entry, tuple) else (entry,)
        nb = []
        for blk in blocks:
            mx = blk.mixer
            q = mx.q_heads // plan.tp if plan.shard_attn else mx.q_heads
            kv = (mx.kv_heads // plan.tp
                  if plan.shard_kv and mx.kind != "mla" else mx.kv_heads)
            nb.append(dataclasses.replace(
                blk, mixer=dataclasses.replace(mx, q_heads=q, kv_heads=kv)))
        new_plan.append(
            (tuple(nb) if isinstance(entry, tuple) else nb[0], rep))
    return dataclasses.replace(cfg, plan=tuple(new_plan))


_COL_TP = (None, "model")
_ROW_TP = ("model", None)


def _serve_rule(owner: str, ndim: int, plan: ServeTPPlan):
    if plan.shard_attn and owner in ("wq", "wq_b"):
        return _COL_TP
    if plan.shard_attn and owner == "wo":
        return _ROW_TP
    if plan.shard_attn and owner in ("w_uk", "w_uv"):
        return ("model", None, None)  # (heads, lora, hd): heads = TP
    if plan.shard_kv and owner in ("wk", "wv"):
        return _COL_TP
    if plan.shard_ffn and owner in ("w_up", "w_gate"):
        return _COL_TP
    if plan.shard_ffn and owner == "w_down":
        return _ROW_TP
    return (None,) * max(ndim, 2)  # replicated (embed/norms/lm_head/...)


def _check_nm_row_split(leaf, owner: str, tp: int) -> None:
    """Row-parallel compressed weight: the per-shard slice of vals/idx
    must land on an N:M group boundary (idx entries are positions *within*
    a group, so any other cut would orphan half a group)."""
    kc = leaf.vals.shape[-2]
    n = leaf.nm.n
    if kc % tp or (kc // tp) % n:
        raise ValueError(
            f"{owner}: compressed in-axis Kc={kc} ({leaf.nm.tag}) does not "
            f"split into tp={tp} shards on group boundaries")


def _serve_leaf_spec(path, leaf, mesh_shape: dict, plan: ServeTPPlan):
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    owner = names[-2] if name == "w" and len(names) >= 2 else name

    if isinstance(leaf, (NMWeight, QNMWeight)):
        rule = _serve_rule(owner, 2, plan)
        if leaf.axis != 0:
            rule = (None, None)  # out-axis compression: keep replicated
        elif rule == _ROW_TP:
            _check_nm_row_split(leaf, owner, plan.tp)
        if isinstance(leaf, QNMWeight):
            out_rule = rule[-1] if leaf.axis == 0 else rule[-2]
            scales_rule = tuple(rule[:-2]) + (out_rule,)
            return dataclasses.replace(
                leaf,
                vals=_fit(rule, leaf.vals.shape, mesh_shape),
                idx=_fit(rule, leaf.idx.shape, mesh_shape),
                scales=_fit(scales_rule, leaf.scales.shape, mesh_shape),
            )
        return dataclasses.replace(
            leaf,
            vals=_fit(rule, leaf.vals.shape, mesh_shape),
            idx=_fit(rule, leaf.idx.shape, mesh_shape),
        )
    if isinstance(leaf, MaskedNMWeight):
        rule = _serve_rule(owner, leaf.w.ndim, plan)
        return dataclasses.replace(
            leaf, w=_fit(rule, leaf.w.shape, mesh_shape))
    rule = _serve_rule(owner, leaf.ndim, plan)
    return _fit(rule, leaf.shape, mesh_shape)


def serve_param_pspecs(params: Any, mesh: Mesh, plan: ServeTPPlan):
    """TP-serving PartitionSpecs (shard_map in_specs for the param tree)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _serve_leaf_spec(p, l, mesh_shape, plan), params,
        is_leaf=is_weight_node,
    )


def serve_cache_pspecs(caches: Any, mesh: Mesh, plan: ServeTPPlan,
                       batch_axes=("data",)):
    """Decode-cache specs for TP serving: batch slots over "data", GQA
    K/V head axis over "model" when the plan shards KV; everything else
    (MLA latents, positions) replicated beyond the batch axis."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k)))
                 for k in path]
        name = names[-1]
        base_rank = {"k": 4, "v": 4, "ckv": 3, "kr": 3}.get(name, leaf.ndim)
        lead = leaf.ndim - base_rank
        spec = [None] * leaf.ndim
        if _axis_ok(leaf.shape[lead], batch_axes, mesh_shape):
            spec[lead] = (batch_axes[0]
                          if isinstance(batch_axes, tuple)
                          and len(batch_axes) == 1 else batch_axes)
        if plan.shard_kv and name in ("k", "v") \
                and _axis_ok(leaf.shape[lead + 2], "model", mesh_shape):
            spec[lead + 2] = "model"
        # drop trailing Nones: jit outputs come back with the normalized
        # spec, and a device_put'd P(..., None, None) vs an output's
        # P(...) would register as two compiled-step signatures
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, caches)


def batch_pspec(batch_size: int, mesh: Mesh, rank: int = 2) -> P:
    """Shard the batch axis over as many of (pod, data) as divide it."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    cands = [a for a in ("pod", "data") if a in mesh_shape]
    axes: tuple = ()
    size = 1
    for a in cands:
        if batch_size % (size * mesh_shape[a]) == 0:
            axes = axes + (a,)
            size *= mesh_shape[a]
    spec = (axes if axes else None,) + (None,) * (rank - 1)
    return P(*spec)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
