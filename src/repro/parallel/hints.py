"""Sharding hints that degrade to no-ops outside a mesh context.

Model code calls `shard_hint(x, "model", None, ...)` to pin intermediate
layouts (expert buffers, attention activations). Under pjit with an active
mesh the hint becomes a with_sharding_constraint; in single-device smoke
tests it vanishes. Mesh-context discovery goes through `repro.compat` so
the same code runs on jax 0.4.x and 0.5.x.

`tp_reduce` / `tp_serving` are the manual-collective counterpart for
shard_map regions: the sharded serving engine traces the model inside a
``tp_serving(axis, tags)`` context, and the model's row-parallel
projection outputs (``attn_out``, ``ffn_down``) pass through
``tp_reduce`` — a psum over the model axis when the engine declared that
projection sharded, the identity everywhere else (single-device serving,
training, GSPMD paths). Keeping the gate tag-based lets the engine make
the psum placement agree *exactly* with the PartitionSpecs it built: a
projection whose in-axis did not shard must not be reduced (its per-shard
output is already the full sum).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

# (axis_name, frozenset of enabled reduce tags) — set only while the
# sharded serving engine traces its shard_map bodies
_TP_CTX: list[tuple[str, frozenset]] = []


def _active_mesh():
    return compat.active_mesh()


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    mesh = _active_mesh()
    if mesh is None:
        return x
    # inside shard_map regions axes are Manual — constraints are illegal
    # there (the sharding is already explicit); the hint becomes a no-op
    if compat.manual_axis_in(mesh):
        return x
    axes = set(mesh.axis_names)
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in axes)
            clean.append(kept if kept else None)
        else:
            clean.append(s if s in axes else None)
    # drop axes whose size does not divide the dim
    fixed = []
    for dim, s in zip(x.shape, clean):
        names = (s,) if isinstance(s, str) else (s or ())
        size = 1
        for a in names:
            size *= mesh.shape[a]
        fixed.append(s if size > 0 and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


@contextlib.contextmanager
def tp_serving(axis: str, reduce_tags):
    """Enable tensor-parallel psums for the enclosed trace.

    Entered *inside* the shard_map body (so it is active whenever jit
    re-traces the step), with ``reduce_tags`` naming exactly the
    row-parallel projections the engine's specs sharded on their
    contraction axis."""
    _TP_CTX.append((axis, frozenset(reduce_tags)))
    try:
        yield
    finally:
        _TP_CTX.pop()


def tp_reduce(x: jax.Array, tag: str) -> jax.Array:
    """psum ``x`` over the TP axis iff tracing under ``tp_serving`` with
    ``tag`` enabled; the identity otherwise (every non-shard_map path)."""
    if not _TP_CTX:
        return x
    axis, tags = _TP_CTX[-1]
    if tag not in tags:
        return x
    return jax.lax.psum(x, axis)


def tp_context() -> Optional[tuple[str, frozenset]]:
    """The active tp_serving context, or None (diagnostics/tests)."""
    return _TP_CTX[-1] if _TP_CTX else None


def shard_hint_leaves(tree, *spec):
    """Apply one shard_hint to every array leaf of a small pytree.

    The main consumer is the compressed-operand pin in ``nm_matmul``:
    an NMWeight's vals and idx (same shape, same layout role) must be
    co-sharded so the FSDP gather moves the compressed pair together."""
    return jax.tree.map(lambda l: shard_hint(l, *spec), tree)
