"""Sharding hints that degrade to no-ops outside a mesh context.

Model code calls `shard_hint(x, "model", None, ...)` to pin intermediate
layouts (expert buffers, attention activations). Under pjit with an active
mesh the hint becomes a with_sharding_constraint; in single-device smoke
tests it vanishes. Mesh-context discovery goes through `repro.compat` so
the same code runs on jax 0.4.x and 0.5.x.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro import compat


def _active_mesh():
    return compat.active_mesh()


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    mesh = _active_mesh()
    if mesh is None:
        return x
    # inside shard_map regions axes are Manual — constraints are illegal
    # there (the sharding is already explicit); the hint becomes a no-op
    if compat.manual_axis_in(mesh):
        return x
    axes = set(mesh.axis_names)
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in axes)
            clean.append(kept if kept else None)
        else:
            clean.append(s if s in axes else None)
    # drop axes whose size does not divide the dim
    fixed = []
    for dim, s in zip(x.shape, clean):
        names = (s,) if isinstance(s, str) else (s or ())
        size = 1
        for a in names:
            size *= mesh.shape[a]
        fixed.append(s if size > 0 and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def shard_hint_leaves(tree, *spec):
    """Apply one shard_hint to every array leaf of a small pytree.

    The main consumer is the compressed-operand pin in ``nm_matmul``:
    an NMWeight's vals and idx (same shape, same layout role) must be
    co-sharded so the FSDP gather moves the compressed pair together."""
    return jax.tree.map(lambda l: shard_hint(l, *spec), tree)
