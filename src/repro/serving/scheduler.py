"""Host-side continuous-batching scheduler (no device work here).

The scheduler owns every per-request decision the engines make —
admission into free slots, chunked-prefill progress, decode membership,
termination — and hands the engines *fixed-shape* numpy plans to feed
the compiled device steps:

  * ``plan_prefill`` admits queued requests into free slots and returns
    ONE ``(slots, prefill_chunk)`` token block covering every slot that
    still has prompt pieces to prefill — admissions are batched into a
    single prefill call per engine step (the original engine ran one
    full ``slots x prefill_len`` forward *per request* and discarded all
    but one slot's rows), and long prompts advance one ``prefill_chunk``
    piece per step so time-to-first-token stays bounded by the chunk
    compute, not the longest prompt.
  * ``plan_decode`` covers every slot whose prefill completed.

Admission semantics match the original engine exactly (the batched-admit
regression test pins this): prompts are truncated to their *last*
``prefill_len`` tokens, left-padded with zeros, and a slot's cache
length starts at ``prefill_len`` regardless of the true prompt length.
A truncated prompt is now recorded (``Request.truncated``) and rejected
loudly when the scheduler runs in strict mode.

Because both the single-device and the sharded engines drive this same
scheduler, their step sequences — and therefore their sampler key
streams — are identical, which is what makes cross-engine token-parity
testable.

Paged mode (``paging=PageManager(...)``): admission is planned against
the *free-page budget* instead of free slots alone — a request is
admitted when a free slot exists in a group with enough free-or-
evictable pages for its prompt (minus whatever prefix-cache pages it
can reuse). Admission stays arrival-ordered with an explicit
starvation guard: a request that does not fit may be bypassed by later
arrivals **once**; the second time it fails to fit, admission stops
behind it until it gets in. Decode allocates pages lazily (one page
per ``page_size`` generated tokens); when the pool is exhausted the
scheduler preempts the latest-admitted slot in the group
(pages freed, request requeued at the front for recompute).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro import obs as _obs
from repro.serving.paging import PageManager, PoolExhaustedError, page_keys


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (plen,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False  # prompt exceeded prefill_len (tail kept)
    bypassed: bool = False   # a later arrival was admitted past this one
    # serving timestamps (perf_counter seconds; engines fill these in)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None   # first token available (TTFT end)
    t_done: Optional[float] = None
    # per-token availability timestamps (one per entry of ``out``) — the
    # per-request source of truth for inter-token latency; cleared on
    # preemption together with ``out`` (the recompute re-emits them)
    t_tokens: list = dataclasses.field(default_factory=list)

    def itl_s(self) -> np.ndarray:
        """This request's inter-token gaps (seconds), possibly empty."""
        if len(self.t_tokens) < 2:
            return np.asarray([], np.float64)
        return np.diff(np.asarray(self.t_tokens, np.float64))


@dataclasses.dataclass
class PrefillPlan:
    tokens: np.ndarray     # (slots, prefill_chunk) int32
    cache_len: np.ndarray  # (slots,) int32 — per-slot write offset
    mask: np.ndarray       # (slots,) bool — slots whose cache rows to keep
    active: list           # slot ids prefilling this step
    finishing: list        # subset completing their final chunk


@dataclasses.dataclass
class DecodePlan:
    tokens: np.ndarray   # (slots, 1) int32 — last sampled token per slot
    lengths: np.ndarray  # (slots,) int32 — current cache lengths
    mask: np.ndarray     # (slots,) bool — slots whose cache rows to keep
    active: list         # slot ids decoding this step


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    tokens: Optional[np.ndarray] = None  # padded (prefill_len,) prompt
    pos: int = 0      # prefill progress (tokens written to cache)
    length: int = 0   # decode-time cache length
    # paged-mode bookkeeping
    seq: int = 0                  # admission order (preemption victim pick)
    hit_pages: int = 0            # prefix pages reused at admission
    keys: Optional[list] = None   # per-page chain hashes of the prompt


class Scheduler:
    def __init__(self, *, slots: int, max_seq: int, prefill_len: int,
                 prefill_chunk: Optional[int] = None, strict: bool = False,
                 paging: Optional[PageManager] = None, obs=None):
        self.prefill_chunk = prefill_chunk or prefill_len
        if prefill_len % self.prefill_chunk:
            raise ValueError(
                f"prefill_len={prefill_len} must be a multiple of "
                f"prefill_chunk={self.prefill_chunk} (fixed-shape chunks)")
        self.n_slots = slots
        self.max_seq = max_seq
        self.prefill_len = prefill_len
        self.strict = strict
        self.paging = paging
        if paging is not None:
            if prefill_len % paging.page_size:
                raise ValueError(
                    f"prefill_len={prefill_len} must be a multiple of "
                    f"page_size={paging.page_size}: decode must start on "
                    "a fresh page so prompt pages stay immutable once "
                    "registered in the prefix cache")
            # prefix hits advance in whole pages AND whole chunks
            self._hit_gran = math.lcm(paging.page_size, self.prefill_chunk)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.preemptions = 0
        self._admit_seq = 0
        self.obs = obs if obs is not None else _obs.get_obs()

    # ---- observability ----------------------------------------------------

    def _count(self, name: str, n: float = 1, **labels) -> None:
        if self.obs is not None:
            self.obs.metrics.inc(name, n, **labels)

    def _admitted(self, req: Request, slot: int, group: int,
                  hit_pages: int) -> None:
        """Per-request span begins at admission (readmission after a
        preemption opens a fresh ``b`` under the same request id)."""
        self._count("sched_admissions_total")
        if self.obs is not None:
            self.obs.tracer.async_begin(
                f"request {req.rid}", req.rid, slot=slot, group=group,
                truncated=req.truncated, hit_pages=hit_pages)

    # ---- admission --------------------------------------------------------

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        if len(req.prompt) > self.prefill_len:
            req.truncated = True
            if self.strict:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f"exceeds prefill_len={self.prefill_len} and the "
                    "engine is strict (tail truncation refused)")
        req.t_submit = now
        self.queue.append(req)
        self._count("sched_submitted_total")
        if req.truncated:
            self._count("sched_truncated_total")

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.req is not None for s in self.slots)

    def _padded(self, prompt: np.ndarray) -> np.ndarray:
        p = np.asarray(prompt, np.int32)[-self.prefill_len:]
        tok = np.zeros(self.prefill_len, np.int32)
        tok[self.prefill_len - len(p):] = p
        return tok

    # ---- paged admission --------------------------------------------------

    def _plan_hit(self, group: int, keys: list) -> tuple[int, list]:
        """Longest page-and-chunk-aligned cached prefix of ``keys``.

        Capped at ``prefill_len - prefill_chunk``: the final chunk always
        runs so the finishing step has last-token logits to sample from.
        Returns (hit_pages, gids) — not yet retained."""
        pm = self.paging
        gids = []
        for k in keys:
            gid = pm.peek(group, k)
            if gid is None:
                break
            gids.append(gid)
        hit_tokens = min(len(gids) * pm.page_size,
                         self.prefill_len - self.prefill_chunk)
        hit_tokens -= hit_tokens % self._hit_gran
        hit_pages = hit_tokens // pm.page_size
        return hit_pages, gids[:hit_pages]

    def _admit_paged(self) -> None:
        """Arrival-ordered admission against the page budget.

        Starvation guard: the first time a request doesn't fit it is
        marked ``bypassed`` and later arrivals may still be admitted
        past it; the second time, admission stops at it — no request is
        ever passed over twice while pages/slots free up behind it."""
        pm = self.paging
        free = [i for i, s in enumerate(self.slots) if s.req is None]
        pages_per_prompt = self.prefill_len // pm.page_size
        for req in list(self.queue):
            if not free:
                break
            tokens = self._padded(req.prompt)
            keys = page_keys(tokens, pm.page_size)
            placed = None
            for i in free:
                g = pm.slot_group(i)
                hit_pages, gids = self._plan_hit(g, keys)
                need = pages_per_prompt - hit_pages
                if pm.available_pages(g, exclude=gids) >= need:
                    placed = (i, g, hit_pages, gids)
                    break
            if placed is None:
                if req.bypassed:
                    break  # guard: it will be next, or nothing moves
                req.bypassed = True
                self._count("sched_bypasses_total")
                if self.obs is not None:
                    self.obs.tracer.instant("sched.bypass", rid=req.rid)
                continue
            i, g, hit_pages, gids = placed
            free.remove(i)
            self.queue.remove(req)
            self._admit_seq += 1
            slot = self.slots[i]
            slot.req = req
            slot.tokens = tokens
            slot.pos = hit_pages * pm.page_size  # skip cached prefix
            slot.length = 0
            slot.seq = self._admit_seq
            slot.hit_pages = hit_pages
            slot.keys = keys
            req.bypassed = False
            self._admitted(req, i, g, hit_pages)
            pm.count_prefix_lookup(pages_per_prompt)
            for p, gid in enumerate(gids):
                pm.hit(gid)
                pm.assign(i, p, gid)
            for p in range(hit_pages, pages_per_prompt):
                pm.assign(i, p, pm.alloc_or_evict(g))

    def _preempt(self, victim: int) -> None:
        """Evict a running request for recompute: free its pages, clear
        generated output, requeue at the FRONT (it keeps arrival
        priority and the starvation guard protects its readmission)."""
        slot = self.slots[victim]
        req = slot.req
        self.paging.free_slot(victim)
        req.out.clear()
        req.t_tokens.clear()
        req.t_first = None
        req.bypassed = False
        self.queue.insert(0, req)
        self.slots[victim] = _Slot()
        self.preemptions += 1
        self._count("sched_preemptions_total")
        if self.obs is not None:
            self.obs.tracer.async_end(f"request {req.rid}", req.rid,
                                      preempted=True)

    def _ensure_decode_page(self, i: int) -> bool:
        """Make sure slot i's next decode write lands in an owned page,
        preempting the youngest other slot in the group if the pool is
        exhausted. False = slot i itself got unschedulable (cannot
        happen while i holds pages; defensive)."""
        pm = self.paging
        p = self.slots[i].length // pm.page_size
        if pm.table[i, p] != 0:
            return True
        g = pm.slot_group(i)
        while True:
            try:
                pm.assign(i, p, pm.alloc_or_evict(g))
                return True
            except PoolExhaustedError:
                victims = [j for j, s in enumerate(self.slots)
                           if j != i and s.req is not None
                           and pm.slot_group(j) == g]
                if not victims:
                    raise PoolExhaustedError(
                        f"slot {i} needs a decode page but group {g} has "
                        "no free, evictable, or preemptible page — pool "
                        "too small for a single max-length request"
                    ) from None
                self._preempt(max(victims, key=lambda j: self.slots[j].seq))

    # ---- prefill ----------------------------------------------------------

    def plan_prefill(self) -> Optional[PrefillPlan]:
        if self.paging is not None:
            self._admit_paged()
        else:
            for i, slot in enumerate(self.slots):
                if slot.req is None and self.queue:
                    slot.req = self.queue.pop(0)
                    slot.tokens = self._padded(slot.req.prompt)
                    slot.pos = 0
                    slot.length = 0
                    self._admitted(slot.req, i, 0, 0)
        chunk = self.prefill_chunk
        active, finishing = [], []
        tokens = np.zeros((self.n_slots, chunk), np.int32)
        cache_len = np.zeros(self.n_slots, np.int32)
        mask = np.zeros(self.n_slots, bool)
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.pos >= self.prefill_len:
                continue
            tokens[i] = slot.tokens[slot.pos:slot.pos + chunk]
            cache_len[i] = slot.pos
            mask[i] = True
            active.append(i)
            if slot.pos + chunk >= self.prefill_len:
                finishing.append(i)
        if not active:
            return None
        return PrefillPlan(tokens, cache_len, mask, active, finishing)

    def finish_prefill(self, plan: PrefillPlan, sampled: np.ndarray,
                       now: Optional[float] = None) -> None:
        """Advance chunk progress; record the first sampled token for
        slots whose prompt is now fully prefilled."""
        for i in plan.active:
            slot = self.slots[i]
            slot.pos += self.prefill_chunk
            if self.obs is not None and i not in plan.finishing:
                self.obs.tracer.async_instant(
                    "prefill_chunk", slot.req.rid, slot=i,
                    pos=slot.pos, of=self.prefill_len)
        for i in plan.finishing:
            slot = self.slots[i]
            req = slot.req
            req.out.append(int(sampled[i]))
            if now is not None:
                req.t_tokens.append(now)
            if req.t_first is None:
                req.t_first = now
                if self.obs is not None:
                    self.obs.tracer.async_instant("first_token", req.rid,
                                                  slot=i)
                    if req.t_submit is not None and now is not None:
                        self.obs.metrics.observe("serve_ttft_seconds",
                                                 now - req.t_submit)
            slot.length = self.prefill_len
            if self.paging is not None:
                # prompt fully written: publish the owned (non-hit) pages
                # under their chain keys; decode starts on a fresh page
                # (prefill_len % page_size == 0), so these stay immutable
                pm = self.paging
                g = pm.slot_group(i)
                for p in range(slot.hit_pages,
                               self.prefill_len // pm.page_size):
                    pm.register_prefix(g, slot.keys[p], int(pm.table[i, p]))
            if len(req.out) >= req.max_new:
                self._finish(i, now)

    # ---- decode -----------------------------------------------------------

    def plan_decode(self) -> Optional[DecodePlan]:
        if self.paging is not None:
            # lazy page allocation for this round's writes, BEFORE the
            # plan is built: a preempted victim drops out of the round
            for i, slot in enumerate(self.slots):
                if slot.req is not None and slot.pos >= self.prefill_len:
                    self._ensure_decode_page(i)
        tokens = np.zeros((self.n_slots, 1), np.int32)
        lengths = np.zeros(self.n_slots, np.int32)
        mask = np.zeros(self.n_slots, bool)
        active = []
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.pos < self.prefill_len:
                continue
            tokens[i, 0] = slot.req.out[-1]
            lengths[i] = slot.length
            mask[i] = True
            active.append(i)
        if not active:
            return None
        # mask gates the cache merge: a decode call must not write its
        # placeholder token-0 K/V into slots that are mid-chunked-prefill
        # (or empty) — their rows keep the pre-step cache
        return DecodePlan(tokens, lengths, mask, active)

    def finish_decode(self, plan: DecodePlan, sampled: np.ndarray,
                      now: Optional[float] = None) -> None:
        for i in plan.active:
            slot = self.slots[i]
            req = slot.req
            req.out.append(int(sampled[i]))
            if now is not None:
                if self.obs is not None and req.t_tokens:
                    self.obs.metrics.observe("serve_itl_seconds",
                                             now - req.t_tokens[-1])
                req.t_tokens.append(now)
            slot.length += 1
            if len(req.out) >= req.max_new or \
                    slot.length >= self.max_seq - 1:
                self._finish(i, now)

    def _finish(self, i: int, now: Optional[float]) -> None:
        slot = self.slots[i]
        req = slot.req
        req.done = True
        req.t_done = now
        self.finished.append(req)
        if self.paging is not None:
            self.paging.free_slot(i)  # pages recycle, not the whole slot
        self.slots[i] = _Slot()
        self._count("sched_finished_total")
        if self.obs is not None:
            self.obs.tracer.async_end(f"request {req.rid}", req.rid,
                                      tokens=len(req.out))
