"""Host-side continuous-batching scheduler (no device work here).

The scheduler owns every per-request decision the engines make —
admission into free slots, chunked-prefill progress, decode membership,
termination — and hands the engines *fixed-shape* numpy plans to feed
the compiled device steps:

  * ``plan_prefill`` admits queued requests into free slots and returns
    ONE ``(slots, prefill_chunk)`` token block covering every slot that
    still has prompt pieces to prefill — admissions are batched into a
    single prefill call per engine step (the original engine ran one
    full ``slots x prefill_len`` forward *per request* and discarded all
    but one slot's rows), and long prompts advance one ``prefill_chunk``
    piece per step so time-to-first-token stays bounded by the chunk
    compute, not the longest prompt.
  * ``plan_decode`` covers every slot whose prefill completed.

Admission semantics match the original engine exactly (the batched-admit
regression test pins this): prompts are truncated to their *last*
``prefill_len`` tokens, left-padded with zeros, and a slot's cache
length starts at ``prefill_len`` regardless of the true prompt length.
A truncated prompt is now recorded (``Request.truncated``) and rejected
loudly when the scheduler runs in strict mode.

Because both the single-device and the sharded engines drive this same
scheduler, their step sequences — and therefore their sampler key
streams — are identical, which is what makes cross-engine token-parity
testable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (plen,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False  # prompt exceeded prefill_len (tail kept)
    # serving timestamps (perf_counter seconds; engines fill these in)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None   # first token available (TTFT end)
    t_done: Optional[float] = None


@dataclasses.dataclass
class PrefillPlan:
    tokens: np.ndarray     # (slots, prefill_chunk) int32
    cache_len: np.ndarray  # (slots,) int32 — per-slot write offset
    mask: np.ndarray       # (slots,) bool — slots whose cache rows to keep
    active: list           # slot ids prefilling this step
    finishing: list        # subset completing their final chunk


@dataclasses.dataclass
class DecodePlan:
    tokens: np.ndarray   # (slots, 1) int32 — last sampled token per slot
    lengths: np.ndarray  # (slots,) int32 — current cache lengths
    mask: np.ndarray     # (slots,) bool — slots whose cache rows to keep
    active: list         # slot ids decoding this step


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    tokens: Optional[np.ndarray] = None  # padded (prefill_len,) prompt
    pos: int = 0      # prefill progress (tokens written to cache)
    length: int = 0   # decode-time cache length


class Scheduler:
    def __init__(self, *, slots: int, max_seq: int, prefill_len: int,
                 prefill_chunk: Optional[int] = None, strict: bool = False):
        self.prefill_chunk = prefill_chunk or prefill_len
        if prefill_len % self.prefill_chunk:
            raise ValueError(
                f"prefill_len={prefill_len} must be a multiple of "
                f"prefill_chunk={self.prefill_chunk} (fixed-shape chunks)")
        self.n_slots = slots
        self.max_seq = max_seq
        self.prefill_len = prefill_len
        self.strict = strict
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    # ---- admission --------------------------------------------------------

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        if len(req.prompt) > self.prefill_len:
            req.truncated = True
            if self.strict:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f"exceeds prefill_len={self.prefill_len} and the "
                    "engine is strict (tail truncation refused)")
        req.t_submit = now
        self.queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s.req is not None for s in self.slots)

    def _padded(self, prompt: np.ndarray) -> np.ndarray:
        p = np.asarray(prompt, np.int32)[-self.prefill_len:]
        tok = np.zeros(self.prefill_len, np.int32)
        tok[self.prefill_len - len(p):] = p
        return tok

    # ---- prefill ----------------------------------------------------------

    def plan_prefill(self) -> Optional[PrefillPlan]:
        for slot in self.slots:
            if slot.req is None and self.queue:
                slot.req = self.queue.pop(0)
                slot.tokens = self._padded(slot.req.prompt)
                slot.pos = 0
                slot.length = 0
        chunk = self.prefill_chunk
        active, finishing = [], []
        tokens = np.zeros((self.n_slots, chunk), np.int32)
        cache_len = np.zeros(self.n_slots, np.int32)
        mask = np.zeros(self.n_slots, bool)
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.pos >= self.prefill_len:
                continue
            tokens[i] = slot.tokens[slot.pos:slot.pos + chunk]
            cache_len[i] = slot.pos
            mask[i] = True
            active.append(i)
            if slot.pos + chunk >= self.prefill_len:
                finishing.append(i)
        if not active:
            return None
        return PrefillPlan(tokens, cache_len, mask, active, finishing)

    def finish_prefill(self, plan: PrefillPlan, sampled: np.ndarray,
                       now: Optional[float] = None) -> None:
        """Advance chunk progress; record the first sampled token for
        slots whose prompt is now fully prefilled."""
        for i in plan.active:
            self.slots[i].pos += self.prefill_chunk
        for i in plan.finishing:
            slot = self.slots[i]
            req = slot.req
            req.out.append(int(sampled[i]))
            if req.t_first is None:
                req.t_first = now
            slot.length = self.prefill_len
            if len(req.out) >= req.max_new:
                self._finish(i, now)

    # ---- decode -----------------------------------------------------------

    def plan_decode(self) -> Optional[DecodePlan]:
        tokens = np.zeros((self.n_slots, 1), np.int32)
        lengths = np.zeros(self.n_slots, np.int32)
        mask = np.zeros(self.n_slots, bool)
        active = []
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.pos < self.prefill_len:
                continue
            tokens[i, 0] = slot.req.out[-1]
            lengths[i] = slot.length
            mask[i] = True
            active.append(i)
        if not active:
            return None
        # mask gates the cache merge: a decode call must not write its
        # placeholder token-0 K/V into slots that are mid-chunked-prefill
        # (or empty) — their rows keep the pre-step cache
        return DecodePlan(tokens, lengths, mask, active)

    def finish_decode(self, plan: DecodePlan, sampled: np.ndarray,
                      now: Optional[float] = None) -> None:
        for i in plan.active:
            slot = self.slots[i]
            req = slot.req
            req.out.append(int(sampled[i]))
            slot.length += 1
            if len(req.out) >= req.max_new or \
                    slot.length >= self.max_seq - 1:
                self._finish(i, now)

    def _finish(self, i: int, now: Optional[float]) -> None:
        slot = self.slots[i]
        slot.req.done = True
        slot.req.t_done = now
        self.finished.append(slot.req)
        self.slots[i] = _Slot()
