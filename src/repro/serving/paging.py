"""Paged KV cache: a pool of fixed-size pages behind a block table.

The serving-side analogue of the paper's index-indirect register reads:
instead of binding a request to a fixed-shape cache slot for its whole
lifetime (stranding ``max_seq`` worth of K/V for short requests), the
cache is a pool of ``page_size``-token pages and every slot owns only a
*block table* row — logical token position ``p`` lives in physical page
``table[slot, p // page_size]``. The device side gathers K/V through
that indirection (``repro.models.attention.paged_gather``); this module
is the host-side owner of the mapping:

  * **Pool accounting** — per-group free lists, allocation, release.
    Freed pages recycle immediately into other requests (continuous
    admission), instead of waiting for a whole slot-shaped cache line.
  * **Refcounts / copy-on-write** — a physical page may be referenced
    by many slots (shared prompt prefixes). Pages are shared read-only;
    a writer must hold the only reference (``fork`` re-homes a shared
    page's writer onto a fresh page, decrementing the old refcount —
    the scheduler's page-aligned prefix granularity makes this
    unreachable in the engines, but the metadata op is the CoW
    contract and is unit-tested).
  * **Prefix cache** — full pages of prefilled prompt are registered
    under a rolling hash of the *padded* prompt-token blocks
    (``page_keys``). A later request whose padded prompt starts with
    the same blocks references those pages instead of recomputing them
    (written once, read by many). Cached pages with no active
    references survive as evictable until pool pressure reclaims them
    (LRU).

Device layout: local row 0 of every group's sub-pool is the **null
page** — a scratch page that masked-off or out-of-range writes land in
and that no block table ever references for reads. Groups exist for the
sharded engine: the pool splits into ``groups`` (= data-parallel
degree) independent sub-pools so a slot's pages always live on its own
data shard and the device gather/scatter never crosses shards. Global
page id ``g * stride + local`` (``stride = pages_per_group + 1``) maps
to local row ``id % stride`` inside each shard's sub-pool.

Everything here is host-side numpy/dict bookkeeping — no device work.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional

import numpy as np

from repro import obs as _obs

__all__ = ["PageManager", "PoolExhaustedError", "page_keys"]


class PoolExhaustedError(RuntimeError):
    """No free or evictable page is available in the requested group."""


def page_keys(tokens: np.ndarray, page_size: int) -> list[bytes]:
    """Rolling per-page hash chain of a (padded) prompt-token block.

    ``key[p]`` commits to every token in pages ``0..p`` — two prompts
    share page ``p`` iff their padded token blocks agree on all of the
    first ``(p+1) * page_size`` tokens. Only full pages get keys."""
    out: list[bytes] = []
    h = b""
    toks = np.asarray(tokens, np.int32)
    for p in range(len(toks) // page_size):
        blk = toks[p * page_size:(p + 1) * page_size].tobytes()
        h = hashlib.blake2b(h + blk, digest_size=16).digest()
        out.append(h)
    return out


@dataclasses.dataclass
class PageStats:
    """Counters the engines surface through ``throughput_stats()``."""

    allocs: int = 0
    prefix_lookup_pages: int = 0  # pages probed against the cache
    prefix_hit_pages: int = 0     # pages actually reused from it
    evictions: int = 0
    forks: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        if self.prefix_lookup_pages == 0:
            return 0.0
        return self.prefix_hit_pages / self.prefix_lookup_pages


class PageManager:
    """Owns the page pool, the block tables, refcounts, and the prefix
    cache. Pure host bookkeeping; the engines upload ``self.table``
    (``(slots, pages_per_slot)`` int32 of *global* page ids, 0 = null)
    to the device each step."""

    def __init__(self, *, page_size: int, pages_per_group: int,
                 slots: int, max_seq: int, groups: int = 1,
                 prefix_cache: bool = True, obs=None):
        if max_seq % page_size:
            raise ValueError(
                f"max_seq={max_seq} must be a multiple of "
                f"page_size={page_size} (block tables are fixed-shape)")
        if slots % groups:
            raise ValueError(
                f"slots={slots} must divide over groups={groups}")
        if pages_per_group < max_seq // page_size:
            raise ValueError(
                f"pages_per_group={pages_per_group} cannot hold even one "
                f"full-length request ({max_seq // page_size} pages) — "
                "no admission could ever be guaranteed progress")
        self.page_size = page_size
        self.pages_per_group = pages_per_group
        self.groups = groups
        self.n_slots = slots
        self.max_seq = max_seq
        self.pages_per_slot = max_seq // page_size
        self.stride = pages_per_group + 1  # +1: local row 0 = null page
        self.rows = groups * self.stride   # device pool leading dim
        self.prefix_enabled = prefix_cache
        # global page id g*stride + j, j in [1, pages_per_group]
        self._free: list[list[int]] = [
            [g * self.stride + j for j in range(pages_per_group, 0, -1)]
            for g in range(groups)
        ]
        self._ref = np.zeros(self.rows, np.int32)
        self._cached: dict[int, bytes] = {}          # gid -> key
        self._prefix: list[dict[bytes, int]] = [dict() for _ in range(groups)]
        self._lru: dict[int, int] = {}               # gid -> last-use stamp
        self._clock = 0
        self._slot_pages: list[list[int]] = [[] for _ in range(slots)]
        self.table = np.zeros((slots, self.pages_per_slot), np.int32)
        self.stats = PageStats()
        # observability: PageStats stays the engine-facing source of
        # truth; when a bundle is attached every stats mutation also
        # increments the page_* / prefix_* metric counters
        self._obs = obs if obs is not None else _obs.get_obs()

    def _count(self, name: str, n: float = 1) -> None:
        if self._obs is not None:
            self._obs.metrics.inc(name, n)

    def count_prefix_lookup(self, pages: int) -> None:
        """Record ``pages`` prefix-cache probes (admission planning)."""
        self.stats.prefix_lookup_pages += pages
        self._count("prefix_lookup_pages_total", pages)

    # ---- geometry ---------------------------------------------------------

    def slot_group(self, slot: int) -> int:
        """Contiguous slot->group mapping, matching P("data") sharding."""
        return slot // (self.n_slots // self.groups)

    def group_of(self, gid: int) -> int:
        return gid // self.stride

    @property
    def capacity(self) -> int:
        return self.groups * self.pages_per_group

    def used_pages(self) -> int:
        """Pages referenced by at least one slot (cache-only pages with
        no active reader count as reclaimable, not used)."""
        return int((self._ref > 0).sum())

    def utilization(self) -> float:
        return self.used_pages() / self.capacity

    def free_pages(self, group: int) -> int:
        return len(self._free[group])

    def evictable_pages(self, group: int, exclude=()) -> int:
        ex = set(exclude)
        return sum(1 for gid in self._cached
                   if self.group_of(gid) == group and self._ref[gid] == 0
                   and gid not in ex)

    def available_pages(self, group: int, exclude=()) -> int:
        """Free plus evictable — the admission budget."""
        return self.free_pages(group) + self.evictable_pages(group, exclude)

    # ---- allocation / refcounts ------------------------------------------

    def alloc(self, group: int) -> int:
        if not self._free[group]:
            raise PoolExhaustedError(
                f"group {group}: no free page "
                f"({self.pages_per_group} total)")
        gid = self._free[group].pop()
        self._ref[gid] = 1
        self.stats.allocs += 1
        self._count("page_allocs_total")
        return gid

    def alloc_or_evict(self, group: int) -> int:
        """Allocate, reclaiming LRU cache-only pages under pressure."""
        if not self._free[group] and not self.evict_lru(group):
            raise PoolExhaustedError(
                f"group {group}: pool exhausted and nothing evictable "
                f"({self.pages_per_group} pages, all actively referenced)")
        return self.alloc(group)

    def retain(self, gid: int) -> None:
        assert gid % self.stride != 0, "null page is not refcountable"
        self._ref[gid] += 1

    def release(self, gid: int) -> None:
        assert self._ref[gid] > 0, f"release of unreferenced page {gid}"
        self._ref[gid] -= 1
        if self._ref[gid] == 0 and gid not in self._cached:
            self._free[self.group_of(gid)].append(gid)
            self._count("page_frees_total")

    def is_shared(self, gid: int) -> bool:
        """A page the holder may NOT write into: other readers exist, or
        the prefix cache could hand it to one at any time."""
        return self._ref[gid] > 1 or gid in self._cached

    def fork(self, gid: int) -> int:
        """Copy-on-write (metadata half): give the caller a private page
        in place of shared ``gid``. The caller owns copying the page
        *contents* before writing. Unreachable from the engines (prefix
        sharing is page-aligned, so writes only ever target sole-owner
        pages) but defines the CoW contract for partial-page sharing."""
        group = self.group_of(gid)
        new = self.alloc_or_evict(group)
        self.release(gid)
        self.stats.forks += 1
        self._count("page_forks_total")
        return new

    # ---- prefix cache -----------------------------------------------------

    def peek(self, group: int, key: bytes) -> Optional[int]:
        """Cache probe without retaining (admission planning)."""
        return self._prefix[group].get(key)

    def hit(self, gid: int) -> None:
        """Commit a planned prefix reuse: retain + LRU bump + stats."""
        self.retain(gid)
        self._clock += 1
        self._lru[gid] = self._clock
        self.stats.prefix_hit_pages += 1
        self._count("prefix_hit_pages_total")

    def register_prefix(self, group: int, key: bytes, gid: int) -> None:
        """Publish a fully-written page under its chain key. First
        writer wins; a concurrent duplicate keeps its private copy."""
        if not self.prefix_enabled or key in self._prefix[group]:
            return
        self._prefix[group][key] = gid
        self._cached[gid] = key
        self._clock += 1
        self._lru[gid] = self._clock

    def evict_lru(self, group: int) -> bool:
        """Reclaim the least-recently-used cache-only page (refcount 0)
        of ``group`` into the free list. False when nothing qualifies."""
        victims = [gid for gid in self._cached
                   if self.group_of(gid) == group and self._ref[gid] == 0]
        if not victims:
            return False
        gid = min(victims, key=lambda g: self._lru.get(g, 0))
        key = self._cached.pop(gid)
        del self._prefix[group][key]
        self._lru.pop(gid, None)
        self._free[group].append(gid)
        self.stats.evictions += 1
        self._count("page_evictions_total")
        return True

    # ---- slot bookkeeping -------------------------------------------------

    def assign(self, slot: int, page_idx: int, gid: int) -> None:
        assert self.table[slot, page_idx] == 0, (slot, page_idx)
        self.table[slot, page_idx] = gid
        self._slot_pages[slot].append(gid)

    def writable(self, slot: int, page_idx: int) -> bool:
        gid = int(self.table[slot, page_idx])
        return gid != 0 and not self.is_shared(gid)

    def free_slot(self, slot: int) -> None:
        """Release every page the slot references and clear its table
        row. Pages drop into the free list the moment their refcount
        hits zero — unless the prefix cache still holds them, in which
        case they stay resident (evictable) for future hits."""
        for gid in self._slot_pages[slot]:
            self.release(gid)
        self._slot_pages[slot] = []
        self.table[slot, :] = 0


def prefix_granularity(page_size: int, chunk: int) -> int:
    """Usable prefix-hit sizes: multiples of lcm(page, chunk) so reused
    pages are whole AND the remaining prompt still splits into
    fixed-shape prefill chunks."""
    return math.lcm(page_size, chunk)
