"""Device-side sampling for the serving engines.

The sampler is traced *inside* the engines' jitted prefill/decode steps,
so per-token logits never round-trip to the host — the only thing the
host sees each step is a ``(slots,)`` int32 array of sampled token ids.
The PRNG key is threaded through the step functions (split once per
step, new key returned alongside the tokens), which makes the
temperature path a pure function of the engine seed: two engines with
the same seed and the same schedule produce bitwise-identical token
streams, and — because the single-device and sharded engines share this
module and the same scheduler — the key stream is identical across
them, so device-count parity tests compare like with like.

Greedy (temperature <= 0) is a plain argmax in f32 — the same
tie-breaking (lowest index) as ``np.argmax`` on host, which is what
keeps batched-admit serving output token-identical to the original
host-sampling engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sampler(temperature: float):
    """Returns ``sampler(logits, key) -> (tokens, new_key)``.

    logits: ``(B, V)`` any float dtype (cast to f32 for the math);
    tokens: ``(B,)`` int32. The key is split even on the greedy path so
    the key stream does not depend on the temperature setting.
    """
    greedy = temperature <= 0

    def sampler(logits: jax.Array, key: jax.Array):
        key, sub = jax.random.split(key)
        lf = logits.astype(jnp.float32)
        if greedy:
            toks = jnp.argmax(lf, axis=-1)
        else:
            toks = jax.random.categorical(sub, lf / temperature, axis=-1)
        return toks.astype(jnp.int32), key

    return sampler
