"""Serving engine: prefill + decode with a continuous-batching scheduler.

`ServeEngine` owns compiled prefill/decode steps (fixed shapes, compiled
once) and a slot-based KV cache: requests are admitted into free batch
slots as others finish (continuous batching), greedy or temperature
sampling per slot. Per-request bookkeeping is host-side; all device steps
are fixed-shape so the engine never recompiles mid-flight — the property
that matters at fleet scale.

The decode step is the artifact the `decode_*` / `long_*` dry-run shapes
lower: one new token against a (B, S, ...) cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM

# cache-leaf base ranks (without scan-stacking); leading extra axes are
# layer stacking, the batch axis sits right after them
_BASE_RANK = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4, "ckv": 3, "kr": 3,
              "conv": 3, "ssm": 3, "wkv": 4, "tm_last": 2, "cm_last": 2}


def _batch_axis(path, leaf) -> int:
    name = [getattr(k, "key", str(k)) for k in path][-1]
    return leaf.ndim - _BASE_RANK.get(name, leaf.ndim)


def merge_cache_slot(new: Any, old: Any, slot: int) -> Any:
    """Take slot `slot` (batch axis) from `new`, everything else from `old`."""

    def one(path, n, o):
        ax = _batch_axis(path, n)
        idx = [slice(None)] * n.ndim
        idx[ax] = slice(slot, slot + 1)
        return jax.lax.dynamic_update_slice_in_dim(
            o, jax.lax.slice_in_dim(n, slot, slot + 1, axis=ax), slot, axis=ax)

    return jax.tree_util.tree_map_with_path(one, new, old)


def make_serve_steps(lm: LM, *, jit: bool = True):
    """Returns (prefill_step, decode_step) pure fns.

    prefill_step(params, tokens, caches)            -> (last_logits, caches)
    decode_step(params, token, caches, cache_len)   -> (logits, caches)
    """

    def prefill_step(params, tokens, caches, enc_input=None):
        logits, caches, _ = lm.forward(
            params, tokens, mode="prefill", caches=caches,
            cache_len=jnp.int32(0), enc_input=enc_input)
        return logits[:, -1], caches

    def decode_step(params, token, caches, cache_len):
        logits, caches, _ = lm.forward(
            params, token, mode="decode", caches=caches, cache_len=cache_len)
        return logits[:, 0], caches

    if jit:
        prefill_step = jax.jit(prefill_step)
        decode_step = jax.jit(decode_step)
    return prefill_step, decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (plen,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching over fixed-shape compiled steps."""

    def __init__(self, lm: LM, params: Any, *, slots: int, max_seq: int,
                 prefill_len: int, temperature: float = 0.0, seed: int = 0,
                 autotune_blocks: bool = False,
                 quantize: Optional[str] = None):
        if quantize not in (None, "int8"):
            raise ValueError(
                f"quantize must be None or 'int8', got {quantize!r}")
        if quantize == "int8":
            # load-time weight quantization: every compressed NMWeight
            # leaf becomes an int8 QNMWeight (per-output-channel absmax
            # scales); dense / masked leaves are untouched. Decode then
            # streams one byte per kept value instead of two (bf16).
            from repro.quant import quantize_tree

            params = quantize_tree(params)
        self.lm = lm
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.prefill_len = prefill_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        if autotune_blocks:
            # pre-pay the per-shape block sweep for every compressed GEMM
            # this engine will issue, so the first real request never eats
            # an inline autotune (results persist in the on-disk cache).
            self._autotune_sparse_blocks()
        self.prefill_step, self.decode_step = make_serve_steps(lm)
        self.caches = lm.init_cache(slots, max_seq)
        self.lengths = np.zeros(slots, np.int32)
        self.active: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _autotune_sparse_blocks(self) -> None:
        """Warm the autotune cache for this engine's sparse-GEMM shapes:
        decode steps run M = slots rows, prefill M = slots * prefill_len.

        Walks the typed NMWeight / QNMWeight leaves of the param tree:
        each weight's own NMConfig supplies the Kc -> K ratio, so a
        model mixing 2:4 and 1:4 layers tunes every shape at its true
        geometry (the old dict walk hardcoded the global ratio), and
        int8 leaves tune under the quantized family's own cache keys
        (value dtype int8). Dense and masked models contribute no such
        leaves — the walk is the gate."""
        from repro.core.nmweight import NMWeight
        from repro.kernels import autotune
        from repro.models.common import get_compute_dtype
        from repro.quant import QNMWeight

        typed = (NMWeight, QNMWeight)
        shapes: set[tuple[int, int, Any, Any]] = set()
        for leaf in jax.tree.leaves(
                self.params, is_leaf=lambda x: isinstance(x, typed)):
            if isinstance(leaf, typed):
                kc, n = leaf.vals.shape[-2:]  # scan-stacked leaves
                dt = (jnp.int8 if isinstance(leaf, QNMWeight)
                      else get_compute_dtype())
                shapes.add((kc * leaf.nm.m // leaf.nm.n, n, leaf.nm, dt))
        for k, n, nm, dt in sorted(
                shapes, key=lambda t: (t[0], t[1], t[2].tag, str(t[3]))):
            for m_rows in {self.slots, self.slots * self.prefill_len}:
                autotune.ensure_tuned(m_rows, n, k, nm, dtype=dt)

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = req.prompt[-self.prefill_len:]
            pad = self.prefill_len - len(prompt)
            tokens = np.zeros((self.slots, self.prefill_len), np.int32)
            tokens[slot, pad:] = prompt
            logits, new_caches = self.prefill_step(
                self.params, jnp.asarray(tokens), self.caches)
            # keep only this slot's freshly prefetched cache rows
            self.caches = merge_cache_slot(new_caches, self.caches, slot)
            req.out.append(self._sample(np.asarray(logits)[slot]))
            self.active[slot] = req
            self.lengths[slot] = self.prefill_len

    def _step_decode(self) -> None:
        tok = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                tok[s, 0] = req.out[-1]
        # per-slot cache lengths: slots admitted at different times decode
        # against their own positions (vector cache_len)
        logits, self.caches = self.decode_step(
            self.params, jnp.asarray(tok),
            self.caches, jnp.asarray(self.lengths, jnp.int32))
        logits = np.asarray(logits)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(self._sample(logits[s]))
            self.lengths[s] += 1
            if len(req.out) >= req.max_new or \
                    self.lengths[s] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.active[s] = None
                self.lengths[s] = 0

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self._admit()
            if any(a is not None for a in self.active):
                self._step_decode()
            steps += 1
        return self.finished
