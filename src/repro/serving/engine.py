"""Serving engines: continuous batching over fixed-shape compiled steps.

``ServeEngine`` (single device) owns compiled prefill/decode steps and a
slot-based KV cache. The host loop is thin: all per-request decisions
live in ``repro.serving.scheduler`` and sampling happens on device
(``repro.serving.sampling``) with a threaded PRNG key — per-token logits
never round-trip to the host, only sampled ``(slots,)`` token ids do.
Admissions are batched: every engine step runs at most ONE prefill call
covering all admitted slots (the original engine ran one full
``slots x prefill_len`` forward per request and kept a single slot's
rows), and ``prefill_chunk`` splits long prompts into fixed-shape pieces
so time-to-first-token is bounded by one chunk's compute.

``ShardedServeEngine`` runs the same host loop with the steps wrapped in
``shard_map`` over a ("data", "model") device mesh: batch slots shard
over "data"; attention/FFN projections run tensor-parallel over "model"
with the head-aware specs from ``repro.parallel.sharding`` (NMWeight /
QNMWeight vals+idx+scales co-sharded, KV caches sharded on the head
axis), so the sparse Pallas kernels execute on their local shard of the
compressed operand with no mid-flight resharding; the row-parallel
partial sums are psum'd inside the model via ``hints.tp_reduce``.

Every device step is fixed-shape, so after the first prefill + decode
compile the engines never recompile — ``compiled_cache_sizes()`` exposes
the underlying jit cache sizes so tests (and fleet monitoring) can
assert exactly that.
"""
from __future__ import annotations

import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.configs.base import AttnConfig
from repro.models.cache import CacheView
from repro.models.transformer import LM
from repro.serving.paging import PageManager
from repro.serving.sampling import make_sampler
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Request", "ServeEngine", "ShardedServeEngine",
           "make_serve_steps", "merge_cache_slot", "merge_cache_slots"]

# cache-leaf base ranks (without scan-stacking); leading extra axes are
# layer stacking, the batch axis sits right after them
_BASE_RANK = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4, "ckv": 3, "kr": 3,
              "conv": 3, "ssm": 3, "wkv": 4, "tm_last": 2, "cm_last": 2}


def _batch_axis(path, leaf) -> int:
    name = [getattr(k, "key", str(k)) for k in path][-1]
    return leaf.ndim - _BASE_RANK.get(name, leaf.ndim)


def merge_cache_slot(new: Any, old: Any, slot: int) -> Any:
    """Take slot `slot` (batch axis) from `new`, everything else from `old`."""

    def one(path, n, o):
        ax = _batch_axis(path, n)
        return jax.lax.dynamic_update_slice_in_dim(
            o, jax.lax.slice_in_dim(n, slot, slot + 1, axis=ax), slot, axis=ax)

    return jax.tree_util.tree_map_with_path(one, new, old)


def merge_cache_slots(new: Any, old: Any, keep: jax.Array) -> Any:
    """Batched ``merge_cache_slot``: keep the batch rows of `new` where
    ``keep`` (bool, length = batch) is set, `old` everywhere else.
    Element-select semantics make this bit-exact with per-slot merges."""

    def one(path, n, o):
        ax = _batch_axis(path, n)
        shape = [1] * n.ndim
        shape[ax] = n.shape[ax]
        return jnp.where(keep.reshape(shape), n, o)

    return jax.tree_util.tree_map_with_path(one, new, old)


def make_serve_steps(lm: LM, *, jit: bool = True):
    """Returns (prefill_step, decode_step) pure fns (dry-run cells).

    prefill_step(params, tokens, caches)            -> (last_logits, caches)
    decode_step(params, token, caches, cache_len)   -> (logits, caches)
    """

    def prefill_step(params, tokens, caches, enc_input=None):
        logits, caches, _ = lm.forward(
            params, tokens, view=CacheView.prefill(), caches=caches,
            enc_input=enc_input)
        return logits[:, -1], caches

    def decode_step(params, token, caches, cache_len):
        logits, caches, _ = lm.forward(
            params, token, view=CacheView.decode(cache_len), caches=caches)
        return logits[:, 0], caches

    if jit:
        prefill_step = jax.jit(prefill_step)
        decode_step = jax.jit(decode_step)
    return prefill_step, decode_step


def _jit_cache_size(fn) -> int:
    """Compiled-signature count of a jitted fn (-1 when unavailable)."""
    try:
        return int(fn._cache_size())
    except (AttributeError, TypeError):
        return -1


class ServeEngine:
    """Slot-based continuous batching over fixed-shape compiled steps."""

    def __init__(self, lm: LM, params: Any, *, slots: int, max_seq: int,
                 prefill_len: int, temperature: float = 0.0, seed: int = 0,
                 autotune_blocks: bool = False,
                 quantize: Optional[str] = None,
                 prefill_chunk: Optional[int] = None,
                 strict: bool = False,
                 paged: bool = False,
                 page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None,
                 obs=None):
        if quantize not in (None, "int8"):
            raise ValueError(
                f"quantize must be None or 'int8', got {quantize!r}")
        if quantize == "int8":
            # load-time weight quantization: every compressed NMWeight
            # leaf becomes an int8 QNMWeight (per-output-channel absmax
            # scales); dense / masked leaves are untouched. Decode then
            # streams one byte per kept value instead of two (bf16).
            from repro.quant import quantize_tree

            params = quantize_tree(params)
        self.lm = lm
        # observability: explicit bundle wins, else the process-global
        # one (None when off — every instrumented site is is-not-None
        # gated, so the off path allocates and records nothing)
        self.obs = obs if obs is not None else _obs.get_obs()
        self.slots = slots
        self.max_seq = max_seq
        self.prefill_len = prefill_len
        self.temperature = temperature
        self.strict = strict
        self.paged = paged
        self.page_manager: Optional[PageManager] = None
        chunk = prefill_chunk or prefill_len
        if paged:
            # paged KV: the cache becomes a pool of fixed-size pages
            # addressed through a per-slot block table. Prefill always
            # runs in mode="chunk" (offset writes through the table), so
            # the model needs the chunkable mixers even at full chunk.
            _validate_chunkable(lm.cfg)
            ps = int(page_size if page_size is not None
                     else os.environ.get("REPRO_KV_PAGE_SIZE") or chunk)
            groups = self._data_parallel()
            pool = int(pool_pages if pool_pages is not None
                       else os.environ.get("REPRO_KV_POOL_PAGES")
                       or slots * (max_seq // ps))
            if pool % groups:
                raise ValueError(
                    f"pool_pages={pool} must divide over the data-parallel "
                    f"degree ({groups}): each data shard owns an "
                    "independent sub-pool")
            self.page_manager = PageManager(
                page_size=ps, pages_per_group=pool // groups,
                slots=slots, max_seq=max_seq, groups=groups, obs=self.obs)
        self.scheduler = Scheduler(
            slots=slots, max_seq=max_seq, prefill_len=prefill_len,
            prefill_chunk=prefill_chunk, strict=strict,
            paging=self.page_manager, obs=self.obs)
        self.prefill_chunk = self.scheduler.prefill_chunk
        if self.prefill_chunk != prefill_len and not paged:
            _validate_chunkable(lm.cfg)
        self.params = params
        if autotune_blocks:
            # pre-pay the per-shape block sweep for every compressed GEMM
            # this engine will issue, so the first real request never eats
            # an inline autotune (results persist in the on-disk cache).
            self._autotune_sparse_blocks()
        self.params = self._place_params(self.params)
        self._sampler = make_sampler(temperature)
        self._key = jax.random.PRNGKey(seed)
        self._build_steps()
        # the paged pool reuses the slot-cache constructor: "batch" rows
        # become pool pages (row 0 of each shard's sub-pool = null page),
        # "max_seq" becomes the page size — same leaf layout, so the
        # sharded engine's cache pspecs apply unchanged
        if paged:
            pm = self.page_manager
            self.caches = self._place_caches(
                lm.init_cache(pm.rows, pm.page_size))
        else:
            self.caches = self._place_caches(lm.init_cache(slots, max_seq))
        self.decode_times: list[float] = []  # wall clock after each decode
        self.queue_depths: list[int] = []    # per-step admission backlog
        self.page_utils: list[float] = []    # per-step pool utilization
        self.steps = 0

    # ---- engine-flavour hooks (overridden by ShardedServeEngine) ---------

    def _data_parallel(self) -> int:
        return 1

    def _place_params(self, params: Any) -> Any:
        return params

    def _place_caches(self, caches: Any) -> Any:
        return caches

    def _build_steps(self) -> None:
        lm, sampler = self.lm, self._sampler
        full = self.prefill_chunk == self.prefill_len

        if self.paged:
            # no merge_cache_slots: the write mask itself gates the cache
            # (masked slots scatter into the null page), so the pool is
            # only ever touched at positions the scheduler owns
            def prefill_step(params, tokens, caches, cache_len, table,
                             mask, key):
                logits, new_caches, _ = lm.forward(
                    params, tokens, caches=caches,
                    view=CacheView.chunk(cache_len, block_table=table,
                                         write_mask=mask))
                toks, key = sampler(logits[:, -1], key)
                return toks, new_caches, key

            def decode_step(params, token, caches, cache_len, table,
                            mask, key):
                logits, new_caches, _ = lm.forward(
                    params, token, caches=caches,
                    view=CacheView.decode(cache_len, block_table=table,
                                          write_mask=mask))
                toks, key = sampler(logits[:, 0], key)
                return toks, new_caches, key

            self._prefill = jax.jit(prefill_step, donate_argnums=(2,))
            self._decode = jax.jit(decode_step, donate_argnums=(2,))
            return

        def prefill_step(params, tokens, caches, cache_len, mask, key):
            if full:
                logits, new_caches, _ = lm.forward(
                    params, tokens, view=CacheView.prefill(), caches=caches)
            else:
                logits, new_caches, _ = lm.forward(
                    params, tokens, view=CacheView.chunk(cache_len),
                    caches=caches)
            new_caches = merge_cache_slots(new_caches, caches, mask)
            toks, key = sampler(logits[:, -1], key)
            return toks, new_caches, key

        def decode_step(params, token, caches, cache_len, mask, key):
            logits, new_caches, _ = lm.forward(
                params, token, view=CacheView.decode(cache_len),
                caches=caches)
            new_caches = merge_cache_slots(new_caches, caches, mask)
            toks, key = sampler(logits[:, 0], key)
            return toks, new_caches, key

        self._prefill = jax.jit(prefill_step, donate_argnums=(2,))
        self._decode = jax.jit(decode_step, donate_argnums=(2,))

    # ---- public API -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req, now=time.perf_counter())

    @property
    def queue(self) -> list:
        return self.scheduler.queue

    @property
    def finished(self) -> list:
        return self.scheduler.finished

    def compiled_cache_sizes(self) -> dict:
        """jit-cache entry counts for the two steps; after warmup these
        must stay at 1 each (fixed shapes => zero recompiles)."""
        return {"prefill": _jit_cache_size(self._prefill),
                "decode": _jit_cache_size(self._decode)}

    def step(self) -> None:
        """One engine step: (batched, possibly chunked) prefill for every
        slot with pending prompt pieces, then one decode for every slot
        whose prefill completed."""
        sched = self.scheduler
        obs = self.obs
        span = obs.tracer.span if obs is not None else _obs.null_span()
        pf = sched.plan_prefill()
        if pf is not None:
            # paged: snapshot the block table AFTER planning — admission
            # just assigned pages for the newly admitted slots
            tbl = ((jnp.asarray(self.page_manager.table),)
                   if self.paged else ())
            with span("engine.prefill", step=self.steps,
                      active=len(pf.active), finishing=len(pf.finishing)):
                toks, self.caches, self._key = self._prefill(
                    self.params, jnp.asarray(pf.tokens), self.caches,
                    jnp.asarray(pf.cache_len), *tbl,
                    jnp.asarray(pf.mask), self._key)
                toks_np = np.asarray(toks)  # device sync inside the span
            sched.finish_prefill(pf, toks_np, now=time.perf_counter())
        dc = sched.plan_decode()
        if dc is not None:
            # paged: plan_decode may have allocated fresh pages (or
            # preempted a slot), so re-snapshot the table
            tbl = ((jnp.asarray(self.page_manager.table),)
                   if self.paged else ())
            with span("engine.decode", step=self.steps,
                      active=len(dc.active)):
                toks, self.caches, self._key = self._decode(
                    self.params, jnp.asarray(dc.tokens), self.caches,
                    jnp.asarray(dc.lengths), *tbl,
                    jnp.asarray(dc.mask), self._key)
                toks_np = np.asarray(toks)  # device sync: real timestamps
            now = time.perf_counter()
            self.decode_times.append(now)
            if len(self.decode_times) > 8192:  # bounded history: a
                # long-running server must not grow a float per token
                del self.decode_times[:4096]
            sched.finish_decode(dc, toks_np, now=now)
            if obs is not None:
                obs.metrics.inc("serve_decode_steps_total")
                obs.metrics.inc("serve_tokens_total", len(dc.active))
        self.queue_depths.append(len(sched.queue))
        if self.page_manager is not None:
            self.page_utils.append(self.page_manager.utilization())
        if len(self.queue_depths) > 8192:
            del self.queue_depths[:4096]
            del self.page_utils[:4096]
        self.steps += 1
        if obs is not None:
            occupied = sum(1 for s in sched.slots if s.req is not None)
            obs.tracer.instant("engine.step", step=self.steps,
                               occupied=occupied, queue=len(sched.queue))
            obs.metrics.inc("serve_steps_total")
            obs.metrics.set_gauge("serve_slots_occupied", occupied)
            obs.metrics.set_gauge("serve_queue_depth", len(sched.queue))
            if self.page_manager is not None:
                # unified with PageStats: gauges mirror the same numbers
                # throughput_stats() reports
                obs.metrics.set_gauge("page_pool_utilization",
                                      self.page_utils[-1])
                obs.metrics.set_gauge(
                    "prefix_hit_rate",
                    self.page_manager.stats.prefix_hit_rate)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1
        return self.scheduler.finished

    def throughput_stats(self) -> dict:
        """Serving metrics over everything finished so far (the serve
        bench's source of truth): generated tokens, mean + p50/p99 TTFT,
        and p50/p99 inter-token latency.

        ITL percentiles pool each finished request's *own* inter-token
        gaps (``Request.t_tokens``). The old estimate diffed the global
        ``decode_times`` wall clock, which conflates a request's token
        cadence with engine-level stalls between *other* requests'
        decode steps (admission gaps, preemption recompute) — a request
        that decoded smoothly would inherit latency spikes it never saw.
        """
        reqs = list(self.scheduler.finished)
        toks = sum(len(r.out) for r in reqs)
        ttfts = [r.t_first - r.t_submit for r in reqs
                 if r.t_first is not None and r.t_submit is not None]
        gaps = [r.itl_s() for r in reqs]
        itl = np.concatenate(gaps) if gaps else np.asarray([])
        stats = {
            "requests": len(reqs),
            "tokens": toks,
            "decode_steps": len(self.decode_times),
            "ttft_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "ttft_p50_s": float(np.percentile(ttfts, 50)) if ttfts else
            float("nan"),
            "ttft_p99_s": float(np.percentile(ttfts, 99)) if ttfts else
            float("nan"),
            "itl_p50_s": float(np.percentile(itl, 50)) if itl.size else
            float("nan"),
            "itl_p99_s": float(np.percentile(itl, 99)) if itl.size else
            float("nan"),
            "queue_depth_mean": float(np.mean(self.queue_depths))
            if self.queue_depths else 0.0,
            "queue_depth_max": int(max(self.queue_depths, default=0)),
        }
        if self.page_manager is not None:
            pm = self.page_manager
            stats.update({
                "page_util_mean": float(np.mean(self.page_utils))
                if self.page_utils else 0.0,
                "page_util_max": float(max(self.page_utils, default=0.0)),
                "prefix_hit_pages": pm.stats.prefix_hit_pages,
                "prefix_lookup_pages": pm.stats.prefix_lookup_pages,
                "prefix_hit_rate": pm.stats.prefix_hit_rate,
                "page_evictions": pm.stats.evictions,
                "preemptions": self.scheduler.preemptions,
            })
        return stats

    # ---- warmup -----------------------------------------------------------

    def _autotune_sparse_blocks(self) -> None:
        """Warm the autotune cache for this engine's sparse-GEMM shapes:
        decode steps run M = slots rows, prefill M = slots * prefill_len.

        Walks the typed NMWeight / QNMWeight leaves of the param tree:
        each weight's own NMConfig supplies the Kc -> K ratio, so a
        model mixing 2:4 and 1:4 layers tunes every shape at its true
        geometry (the old dict walk hardcoded the global ratio), and
        int8 leaves tune under the quantized family's own cache keys
        (value dtype int8). Each leaf's policy also carries the kernel
        backend, so a gpu-pinned weight pre-pays the GPU family's sweep
        under its own key namespace. Dense and masked models contribute
        no such leaves — the walk is the gate."""
        from repro.core.nmweight import NMWeight
        from repro.kernels import autotune
        from repro.kernels.backend import resolve_backend
        from repro.models.common import get_compute_dtype
        from repro.quant import QNMWeight

        typed = (NMWeight, QNMWeight)
        shapes: set[tuple[int, int, Any, Any, str]] = set()
        for leaf in jax.tree.leaves(
                self.params, is_leaf=lambda x: isinstance(x, typed)):
            if isinstance(leaf, typed):
                kc, n = leaf.vals.shape[-2:]  # scan-stacked leaves
                dt = (jnp.int8 if isinstance(leaf, QNMWeight)
                      else get_compute_dtype())
                be = resolve_backend(
                    getattr(leaf.kernel_policy, "backend", "auto"))
                shapes.add((kc * leaf.nm.m // leaf.nm.n, n, leaf.nm, dt, be))
        from repro.kernels.indexmac.ops import decode_m_max

        for k, n, nm, dt, be in sorted(
                shapes, key=lambda t: (t[0], t[1], t[2].tag, str(t[3]), t[4])):
            for m_rows in {self.slots, self.slots * self.prefill_len}:
                if m_rows <= decode_m_max():
                    # skinny-M rows route to the decode kernel family,
                    # which sweeps its own grid under its own cache keys
                    autotune.ensure_tuned(m_rows, n, k, nm, dtype=dt,
                                          family="decode", backend=be)
                else:
                    autotune.ensure_tuned(m_rows, n, k, nm, dtype=dt,
                                          backend=be)

        # block-sparse attention masks pre-pay the bs_attn tile sweep at
        # the full-prefill shape (the only serving shape that routes the
        # bs_attention prefill family; decode/chunk use the mask-aware
        # dense path, which has no tile to tune)
        from repro.kernels.blocksparse_attn.ops import tune_for_serving

        attn_shapes: set = set()
        for entry, _rep in self.lm.cfg.plan:
            blocks = entry if isinstance(entry, tuple) else (entry,)
            for blk in blocks:
                mx = blk.mixer
                if isinstance(mx, AttnConfig) and mx.mask is not None:
                    dk = (mx.nope_head_dim + mx.rope_head_dim
                          if mx.kind == "mla" else mx.head_dim)
                    attn_shapes.add((self.prefill_len, dk, mx.mask))
        for sq, dk, spec in sorted(
                attn_shapes, key=lambda t: (t[0], t[1], t[2].tag)):
            tune_for_serving(sq, sq, dk, spec, dtype=get_compute_dtype(),
                             backend=resolve_backend("auto"))


def _validate_chunkable(cfg) -> None:
    """Chunked prefill needs the mixers' mode="chunk" path (multi-token
    write at a cache offset + causal masking vs absolute positions) —
    implemented for attention (GQA/MLA); state-space / rwkv caches would
    need a resume-from-state prefill instead."""
    for entry, _rep in cfg.plan:
        blocks = entry if isinstance(entry, tuple) else (entry,)
        for blk in blocks:
            if not isinstance(blk.mixer, AttnConfig) or blk.cross_attn:
                raise NotImplementedError(
                    f"prefill_chunk < prefill_len needs attention-mixer "
                    f"decoder blocks; {cfg.name} has "
                    f"{type(blk.mixer).__name__}"
                    f"{' + cross_attn' if blk.cross_attn else ''}")


class ShardedServeEngine(ServeEngine):
    """The same engine with prefill/decode under ``shard_map`` on a
    ("data", "model") mesh: slots data-parallel, projections
    tensor-parallel with head-aware specs, KV caches sharded on heads,
    compressed vals+idx+scales co-sharded so each shard's Pallas kernel
    reads only its local slice. Token streams are identical to the
    single-device engine (same scheduler, same sampler key stream)."""

    def __init__(self, lm: LM, params: Any, *, mesh, **kw):
        from repro.parallel.sharding import serve_tp_plan

        names = getattr(mesh, "axis_names", ())
        if "data" not in names or "model" not in names:
            raise ValueError(
                f"ShardedServeEngine needs a ('data', 'model') mesh, got "
                f"axes {names}")
        self.mesh = mesh
        self._mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        slots = kw.get("slots")
        if slots is None or slots % self._mesh_shape["data"]:
            raise ValueError(
                f"slots={slots} must divide over the data axis "
                f"({self._mesh_shape['data']})")
        self.tp_plan = serve_tp_plan(lm.cfg, self._mesh_shape["model"])
        super().__init__(lm, params, **kw)
        # commit the sampler key to the mesh (replicated) up front: the
        # first step's key would otherwise be single-device while every
        # later key is a mesh-committed jit output — two compiled
        # signatures for one step function (breaks the zero-recompile
        # invariant the fleet monitors)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        self._key = jax.device_put(
            self._key, NamedSharding(self.mesh, P()))

    def _data_parallel(self) -> int:
        # one independent page-pool group per data shard: a slot's pages
        # always live in its own shard's sub-pool, so the paged
        # gather/scatter stays shard-local (no collectives)
        return self._mesh_shape["data"]

    def _place_params(self, params: Any) -> Any:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.parallel.sharding import serve_param_pspecs

        specs = serve_param_pspecs(params, self.mesh, self.tp_plan)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(params, shardings)

    def _place_caches(self, caches: Any) -> Any:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.parallel.sharding import serve_cache_pspecs

        specs = serve_cache_pspecs(caches, self.mesh, self.tp_plan)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(caches, shardings)

    def _build_steps(self) -> None:
        from jax.sharding import PartitionSpec as P

        from repro import compat
        from repro.parallel import hints
        from repro.parallel.sharding import (
            serve_cache_pspecs,
            serve_local_cfg,
            serve_param_pspecs,
        )

        mesh, plan, sampler = self.mesh, self.tp_plan, self._sampler
        full = self.prefill_chunk == self.prefill_len
        # per-shard view of the model: head counts divided by tp so the
        # (B, S, H, D) reshapes match the local projection slices
        lm_local = LM(serve_local_cfg(self.lm.cfg, plan))
        p_specs = serve_param_pspecs(self.params, mesh, plan)
        if self.paged:
            pm = self.page_manager
            cache_shape = lambda: self.lm.init_cache(pm.rows, pm.page_size)  # noqa: E731
        else:
            cache_shape = lambda: self.lm.init_cache(self.slots, self.max_seq)  # noqa: E731
        c_specs = serve_cache_pspecs(jax.eval_shape(cache_shape), mesh, plan)
        tags = plan.reduce_tags
        p_tok = P("data", None)
        p_vec = P("data")

        if self.paged:
            # block table shards with the slots over "data"; the pool's
            # page rows shard over "data" too (rows = dp * stride), so
            # inside each shard `table % stride` is the local page row
            p_tbl = P("data", None)

            def prefill_body(params, tokens, caches, cache_len, table, mask):
                with hints.tp_serving("model", tags):
                    logits, new_caches, _ = lm_local.forward(
                        params, tokens, caches=caches,
                        view=CacheView.chunk(cache_len, block_table=table,
                                             write_mask=mask))
                return logits[:, -1], new_caches

            sh_prefill = compat.shard_map(
                prefill_body, mesh=mesh,
                in_specs=(p_specs, p_tok, c_specs, p_vec, p_tbl, p_vec),
                out_specs=(p_tok, c_specs), check_vma=False)

            def decode_body(params, token, caches, cache_len, table, mask):
                with hints.tp_serving("model", tags):
                    logits, new_caches, _ = lm_local.forward(
                        params, token, caches=caches,
                        view=CacheView.decode(cache_len, block_table=table,
                                              write_mask=mask))
                return logits[:, 0], new_caches

            sh_decode = compat.shard_map(
                decode_body, mesh=mesh,
                in_specs=(p_specs, p_tok, c_specs, p_vec, p_tbl, p_vec),
                out_specs=(p_tok, c_specs), check_vma=False)

            def prefill_step(params, tokens, caches, cache_len, table,
                             mask, key):
                logits, new_caches = sh_prefill(
                    params, tokens, caches, cache_len, table, mask)
                toks, key = sampler(logits, key)
                return toks, new_caches, key

            def decode_step(params, token, caches, cache_len, table,
                            mask, key):
                logits, new_caches = sh_decode(
                    params, token, caches, cache_len, table, mask)
                toks, key = sampler(logits, key)
                return toks, new_caches, key

            self._prefill = jax.jit(prefill_step, donate_argnums=(2,))
            self._decode = jax.jit(decode_step, donate_argnums=(2,))
            return

        def prefill_body(params, tokens, caches, cache_len, mask):
            with hints.tp_serving("model", tags):
                if full:
                    logits, new_caches, _ = lm_local.forward(
                        params, tokens, view=CacheView.prefill(),
                        caches=caches)
                else:
                    logits, new_caches, _ = lm_local.forward(
                        params, tokens, view=CacheView.chunk(cache_len),
                        caches=caches)
            new_caches = merge_cache_slots(new_caches, caches, mask)
            return logits[:, -1], new_caches

        sh_prefill = compat.shard_map(
            prefill_body, mesh=mesh,
            in_specs=(p_specs, p_tok, c_specs, p_vec, p_vec),
            out_specs=(p_tok, c_specs), check_vma=False)

        def decode_body(params, token, caches, cache_len, mask):
            with hints.tp_serving("model", tags):
                logits, new_caches, _ = lm_local.forward(
                    params, token, view=CacheView.decode(cache_len),
                    caches=caches)
            new_caches = merge_cache_slots(new_caches, caches, mask)
            return logits[:, 0], new_caches

        sh_decode = compat.shard_map(
            decode_body, mesh=mesh,
            in_specs=(p_specs, p_tok, c_specs, p_vec, p_vec),
            out_specs=(p_tok, c_specs), check_vma=False)

        # sampling sits outside the shard_map (logits are tiny) but inside
        # the jit: one categorical over the *global* (slots, V) block, so
        # the gumbel noise — hence the sampled stream — is independent of
        # the device mesh and matches the single-device engine bit-for-bit
        def prefill_step(params, tokens, caches, cache_len, mask, key):
            logits, new_caches = sh_prefill(
                params, tokens, caches, cache_len, mask)
            toks, key = sampler(logits, key)
            return toks, new_caches, key

        def decode_step(params, token, caches, cache_len, mask, key):
            logits, new_caches = sh_decode(
                params, token, caches, cache_len, mask)
            toks, key = sampler(logits, key)
            return toks, new_caches, key

        self._prefill = jax.jit(prefill_step, donate_argnums=(2,))
        self._decode = jax.jit(decode_step, donate_argnums=(2,))
