"""DenseNet121 [CNN] — second CNN of the paper's totals (Figs. 5-6).

224x224: 7x7/2 stem (+3x3/2 max-pool), dense blocks of (6, 12, 24, 16)
layers at growth 32 — each layer a 1x1 bottleneck to 4*growth then a
3x3 to growth, concatenated — with 1x1 channel-halving + 2x2 avg-pool
transitions between blocks. 120 convs.
"""
from repro.configs.base import CNNConfig, ConvSpec, DenseStage


def config(sparse: bool = True) -> CNNConfig:
    from repro.configs import cnn_sparsity_or_none

    return CNNConfig(
        name="densenet121",
        kind="densenet",
        stem=ConvSpec("conv1", 3, 64, 7, 7, 2, target="stem"),
        stages=(
            DenseStage(layers=6, growth=32),
            DenseStage(layers=12, growth=32),
            DenseStage(layers=24, growth=32),
            DenseStage(layers=16, growth=32),
        ),
        input_hw=224,
        num_classes=1000,
        sparsity=cnn_sparsity_or_none(sparse),
    )


def reduced(sparse: bool = True) -> CNNConfig:
    """CPU-runnable: 32x32 input, 2 short dense blocks, growth 8."""
    from repro.configs import cnn_sparsity_or_none

    return CNNConfig(
        name="densenet121-reduced",
        kind="densenet",
        stem=ConvSpec("conv1", 3, 8, 3, 3, 1, target="stem"),
        stages=(DenseStage(layers=2, growth=8), DenseStage(layers=2, growth=8)),
        input_hw=32,
        num_classes=10,
        sparsity=cnn_sparsity_or_none(sparse),
    )
