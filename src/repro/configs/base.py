"""Config dataclasses for models, sparsity and shapes.

Everything is a frozen dataclass built from tuples so configs are hashable
and usable as static jit arguments. A model is described by a *plan*: an
ordered tuple of (Block, repeat) groups; groups with repeat > 1 are
executed with lax.scan over stacked parameters (bounded compile time at
depth — essential for the 512-device dry-run and for 1000+ node scale).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

from repro.core.sparsity import NMConfig
from repro.kernels.blocksparse_attn.mask import MaskSpec  # numpy-only

# ---------------------------------------------------------------------------
# sparsity integration (the paper's technique as a framework feature)
# ---------------------------------------------------------------------------

SparseMode = Literal["masked", "compressed"]


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Apply N:M structured sparsity to weight GEMMs.

    mode:
      masked      — dense storage; N:M mask applied in the forward pass
                    (the paper's prune->fine-tune training flow, STE grads)
      compressed  — (values, int8 idx) storage; forward dispatches to the
                    indexmac kernel / its XLA reference (serving + dry-run)
    targets: which projection families are sparsified.
    use_kernel: dispatch to the Pallas kernel when shapes allow.
    nm_overrides: per-target NMConfig overrides, e.g.
      ``(("expert", NMConfig(1, 4)),)`` sparsifies experts at 1:4 while
      everything else uses ``nm`` — mixed per-layer sparsity. This is
      init-time routing only: once built, every weight carries its own
      ``NMConfig`` (``repro.core.nmweight.NMWeight``).
    """

    nm: NMConfig = NMConfig(2, 4)
    mode: SparseMode = "compressed"
    targets: tuple[str, ...] = ("ffn", "attn_proj", "expert")
    use_kernel: bool = False  # pure-XLA path by default (dry-run friendly)
    nm_overrides: tuple[tuple[str, NMConfig], ...] = ()

    def nm_for(self, target: str) -> NMConfig:
        """The N:M pattern a given target family is sparsified at."""
        return dict(self.nm_overrides).get(target, self.nm)

    @property
    def tag(self) -> str:
        return f"{self.nm.tag}-{self.mode}"


# ---------------------------------------------------------------------------
# mixer configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    kind: Literal["gqa", "mla"] = "gqa"
    q_heads: int = 8
    kv_heads: int = 8
    head_dim: int = 128
    rope: bool = True
    window: Optional[int] = None  # sliding-window size (local attention)
    causal: bool = True
    # MLA (DeepSeek-V2) fields
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: Optional[float] = None  # overrides ModelConfig.rope_theta
    # Block-sparse attention pattern. When set it REPLACES the dense
    # causal/window masking: train/prefill routes through the
    # ``bs_attention`` kernel family, decode/chunk through
    # ``bs_attention_decode`` (the spec's own causal/window semantics
    # apply; ``window``/``causal`` above are ignored for this mixer).
    mask: Optional[MaskSpec] = None


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_ff: int = 4096
    act: Literal["swiglu", "gelu", "relu_sq"] = "swiglu"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    d_expert: int = 1408  # per-expert FFN hidden
    n_shared: int = 2  # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    act: Literal["swiglu", "gelu"] = "swiglu"


Mixer = AttnConfig | MambaConfig | RWKVConfig
MLP = FFNConfig | MoEConfig | None


@dataclasses.dataclass(frozen=True)
class Block:
    mixer: Mixer
    mlp: MLP
    cross_attn: bool = False  # enc-dec decoder blocks (whisper)


# ---------------------------------------------------------------------------
# model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    plan: tuple[tuple[Block, int], ...]  # decoder / backbone
    max_seq: int = 8192
    rope_theta: float = 10_000.0
    pos_embed: Literal["rope", "learned", "none"] = "rope"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    sparsity: Optional[SparsityConfig] = None
    # enc-dec (whisper): encoder stack + cross-attention in decoder blocks
    encoder_plan: Optional[tuple[tuple[Block, int], ...]] = None
    encoder_inputs: Literal["tokens", "embeddings"] = "tokens"
    encoder_seq: int = 1500
    # attention chunking for memory-bounded prefill (flash-style scan)
    attn_chunk: int = 512
    # metadata
    family: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio

    @property
    def n_layers(self) -> int:
        return sum((len(e) if isinstance(e, tuple) else 1) * r
                   for e, r in self.plan)

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for MODEL_FLOPS)."""
        from repro.models.transformer import count_params  # lazy, no cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params

        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# CNN configs (the paper's actual evaluation workload: conv layers mapped
# to sparse-dense GEMMs via im2col — §IV)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One 2D convolution, NHWC activations / HWIO weights.

    The GEMM the paper maps it to is A(M=c_out, K=c_in*kh*kw) x
    B(K, N=h_out*w_out); the sparse weight is compressed along K, so the
    float/int8 NMWeight families, autotune, padding and kernel-policy
    dispatch all apply to convs unchanged.
    """

    name: str
    c_in: int
    c_out: int
    kh: int = 1
    kw: int = 1
    stride: int = 1
    padding: Literal["SAME", "VALID"] = "SAME"
    target: str = "conv"  # sparsity target family (SparsityConfig.targets)

    @property
    def k_gemm(self) -> int:
        """Contraction dim of the im2col GEMM (= C_in * kh * kw)."""
        return self.c_in * self.kh * self.kw

    def out_hw(self, h: int, w: int) -> tuple[int, int]:
        """Output spatial dims for an (h, w) input."""
        if self.padding == "SAME":
            return -(-h // self.stride), -(-w // self.stride)
        return ((h - self.kh) // self.stride + 1,
                (w - self.kw) // self.stride + 1)


@dataclasses.dataclass(frozen=True)
class BottleneckStage:
    """ResNet bottleneck stage: ``blocks`` x (1x1 mid, 3x3 mid, 1x1 out)
    with a projection shortcut on the first block. ``stride`` downsamples
    in the first block (on the leading 1x1 and the projection — the
    placement that reproduces the paper's per-layer GEMM table, where
    every conv of a stage runs at the stage's output resolution)."""

    mid: int
    out: int
    blocks: int
    stride: int = 2


@dataclasses.dataclass(frozen=True)
class DenseStage:
    """DenseNet dense block: ``layers`` x (1x1 bottleneck to 4*growth,
    3x3 to growth, concat). A transition (1x1 halving channels + 2x2
    avg-pool) follows every stage except the last."""

    layers: int
    growth: int = 32


CNNStage = BottleneckStage | DenseStage


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """A CNN backbone as a stem + stage stack (resnet or densenet kind).

    ``kind`` picks the block topology in ``repro.models.conv.SparseCNN``;
    the per-layer conv list (and the paper's im2col GEMM table) is derived
    by ``repro.models.conv.cnn_layer_specs`` / ``cnn_layer_gemms``.
    """

    name: str
    kind: Literal["resnet", "densenet"]
    stem: ConvSpec
    stages: tuple[CNNStage, ...]
    input_hw: int = 224
    stem_pool: int = 2  # 3x3 max-pool stride after the stem (1 = none)
    num_classes: int = 1000
    sparsity: Optional[SparsityConfig] = None


# ---------------------------------------------------------------------------
# input shapes (assigned shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
