"""internlm2-20b [dense] — GQA [arXiv:2403.17297].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544, rope theta 1e6.
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _plan(layers, q, kv, hd, ff):
    attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd)
    return ((Block(attn, FFNConfig(d_ff=ff, act="swiglu")), layers),)


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="internlm2-20b",
        vocab_size=92_544,
        d_model=6_144,
        plan=_plan(48, 48, 8, 128, 16_384),
        max_seq=32_768,
        rope_theta=1_000_000.0,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="internlm2-20b-reduced",
        vocab_size=512,
        d_model=128,
        plan=_plan(2, 8, 2, 16, 256),
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )
