"""yi-9b [dense] — llama-arch GQA [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _plan(layers, q, kv, hd, ff):
    attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd)
    return ((Block(attn, FFNConfig(d_ff=ff, act="swiglu")), layers),)


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="yi-9b",
        vocab_size=64_000,
        d_model=4_096,
        plan=_plan(48, 32, 4, 128, 11_008),
        max_seq=32_768,
        rope_theta=10_000.0,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="yi-9b-reduced",
        vocab_size=512,
        d_model=128,
        plan=_plan(2, 8, 1, 16, 256),
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )
