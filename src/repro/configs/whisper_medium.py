"""whisper-medium [audio] — enc-dec [arXiv:2212.04356].

24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The conv frontend is a STUB per task spec: input_specs provides
precomputed frame embeddings (B, 1500, 1024). Deviations noted:
LayerNorm -> RMSNorm (framework-uniform), sinusoidal enc pos -> learned.
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _plans(layers, q, kv, hd, ff):
    dec_attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, rope=False,
                          causal=True)
    enc_attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, rope=False,
                          causal=False)
    ffn = FFNConfig(d_ff=ff, act="gelu")
    dec = ((Block(dec_attn, ffn, cross_attn=True), layers),)
    enc = ((Block(enc_attn, ffn), layers),)
    return dec, enc


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    dec, enc = _plans(24, 16, 16, 64, 4_096)
    return ModelConfig(
        name="whisper-medium",
        vocab_size=51_865,
        d_model=1_024,
        plan=dec,
        encoder_plan=enc,
        encoder_inputs="embeddings",
        encoder_seq=1_500,
        pos_embed="learned",
        max_seq=32_768,  # decoder positions extended for the assigned shapes
        sparsity=sparsity_or_none(sparse),
        family="audio",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    dec, enc = _plans(2, 4, 4, 16, 256)
    return ModelConfig(
        name="whisper-medium-reduced",
        vocab_size=512,
        d_model=64,
        plan=dec,
        encoder_plan=enc,
        encoder_inputs="embeddings",
        encoder_seq=24,
        pos_embed="learned",
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="audio",
    )
