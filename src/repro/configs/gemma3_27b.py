"""gemma3-27b [dense] — 5:1 local:global interleave, 128k ctx
[hf:google/gemma-3-*].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, qk-norm,
sliding window 1024 on local layers (theta 10k), full attention on global
layers (theta 1M). Plan: 10 scanned periods of (5 local + 1 global) + a
2-local tail = 62 layers. Embeddings tied (gemma family).
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _blocks(q, kv, hd, ff, window):
    local = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, qk_norm=True,
                       window=window, rope_theta=10_000.0)
    glob = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, qk_norm=True,
                      window=None, rope_theta=1_000_000.0)
    ffn = FFNConfig(d_ff=ff, act="geglu")
    b_local = Block(local, ffn)
    b_glob = Block(glob, ffn)
    return b_local, b_glob


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    b_local, b_glob = _blocks(32, 16, 128, 21_504, 1_024)
    period = (b_local,) * 5 + (b_glob,)
    return ModelConfig(
        name="gemma3-27b",
        vocab_size=262_144,
        d_model=5_376,
        plan=((period, 10), (b_local, 2)),
        max_seq=131_072,
        tie_embeddings=True,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    b_local, b_glob = _blocks(4, 2, 16, 256, 16)
    period = (b_local,) * 5 + (b_glob,)
    return ModelConfig(
        name="gemma3-27b-reduced",
        vocab_size=512,
        d_model=128,
        plan=((period, 1), (b_local, 1)),
        max_seq=128,
        tie_embeddings=True,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )
