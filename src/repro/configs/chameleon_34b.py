"""chameleon-34b [vlm] — early-fusion multimodal LM [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ image
frontend is a stub per task spec: images are pre-tokenized into the shared
65536 vocab, so input_specs provides token ids only. Chameleon uses
QK-norm for stability — modeled via qk_norm=True.
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _plan(layers, q, kv, hd, ff):
    attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, qk_norm=True)
    return ((Block(attn, FFNConfig(d_ff=ff, act="swiglu")), layers),)


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="chameleon-34b",
        vocab_size=65_536,
        d_model=8_192,
        plan=_plan(48, 64, 8, 128, 22_016),
        max_seq=32_768,
        rope_theta=10_000.0,
        sparsity=sparsity_or_none(sparse),
        family="vlm",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="chameleon-34b-reduced",
        vocab_size=512,
        d_model=128,
        plan=_plan(2, 8, 2, 16, 256),
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="vlm",
    )
