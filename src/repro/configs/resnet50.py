"""ResNet50 [CNN] — the paper's primary per-layer workload (Fig. 4).

Bottleneck v1 structure at 224x224: 7x7/2 stem (+3x3/2 max-pool), then
stages of (1x1 mid, 3x3 mid, 1x1 out) blocks with projection shortcuts;
downsampling on the first block of stages 3-5 (on the leading 1x1 and
the projection, which reproduces the paper's per-layer GEMM table where
every conv of a stage runs at the stage's output resolution). 53 convs.
"""
from repro.configs.base import BottleneckStage, CNNConfig, ConvSpec


def _stages() -> tuple[BottleneckStage, ...]:
    return (
        BottleneckStage(mid=64, out=256, blocks=3, stride=1),
        BottleneckStage(mid=128, out=512, blocks=4, stride=2),
        BottleneckStage(mid=256, out=1024, blocks=6, stride=2),
        BottleneckStage(mid=512, out=2048, blocks=3, stride=2),
    )


def config(sparse: bool = True) -> CNNConfig:
    from repro.configs import cnn_sparsity_or_none

    return CNNConfig(
        name="resnet50",
        kind="resnet",
        stem=ConvSpec("conv1", 3, 64, 7, 7, 2, target="stem"),
        stages=_stages(),
        input_hw=224,
        num_classes=1000,
        sparsity=cnn_sparsity_or_none(sparse),
    )


def reduced(sparse: bool = True) -> CNNConfig:
    """CPU-runnable: 32x32 input, 2 short stages, same block topology."""
    from repro.configs import cnn_sparsity_or_none

    return CNNConfig(
        name="resnet50-reduced",
        kind="resnet",
        stem=ConvSpec("conv1", 3, 8, 3, 3, 1, target="stem"),
        stages=(
            BottleneckStage(mid=8, out=16, blocks=2, stride=1),
            BottleneckStage(mid=16, out=32, blocks=2, stride=2),
        ),
        input_hw=32,
        num_classes=10,
        sparsity=cnn_sparsity_or_none(sparse),
    )
