"""deepseek-v2-lite-16b [moe] — MLA + MoE [arXiv:2405.04434].

27L d_model=2048 16H MLA (kv_lora=512, no q compression), layer 0 dense
FFN (10944), layers 1-26 MoE: 64 routed top-6 (d_expert=1408) + 2 shared.
vocab=102400.
"""
from repro.configs.base import (
    AttnConfig,
    Block,
    FFNConfig,
    ModelConfig,
    MoEConfig,
)


def _blocks(q_heads, kv_lora, d_ff_dense, n_exp, top_k, d_expert, n_shared,
            rope_hd=64, nope_hd=128, v_hd=128):
    mla = AttnConfig(kind="mla", q_heads=q_heads, kv_lora_rank=kv_lora,
                     q_lora_rank=None, rope_head_dim=rope_hd,
                     nope_head_dim=nope_hd, v_head_dim=v_hd)
    dense = Block(mla, FFNConfig(d_ff=d_ff_dense, act="swiglu"))
    moe = Block(mla, MoEConfig(n_experts=n_exp, top_k=top_k,
                               d_expert=d_expert, n_shared=n_shared))
    return dense, moe


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    dense, moe = _blocks(16, 512, 10_944, 64, 6, 1_408, 2)
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        vocab_size=102_400,
        d_model=2_048,
        plan=((dense, 1), (moe, 26)),
        max_seq=131_072,
        rope_theta=10_000.0,
        sparsity=sparsity_or_none(sparse),
        family="moe",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    dense, moe = _blocks(4, 32, 256, 8, 2, 64, 1, rope_hd=8, nope_hd=16,
                         v_hd=16)
    return ModelConfig(
        name="deepseek-v2-lite-16b-reduced",
        vocab_size=512,
        d_model=128,
        plan=((dense, 1), (moe, 2)),
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="moe",
    )
