"""codeqwen1.5-7b [dense] — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (MHA, kv=32) d_ff=13440 vocab=92416, rope theta 1e6
(64k context). Deviation noted: qwen1.5 uses QKV biases; this framework is
bias-free (negligible for perf/roofline purposes).
"""
from repro.configs.base import AttnConfig, Block, FFNConfig, ModelConfig


def _plan(layers, q, kv, hd, ff):
    attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd)
    return ((Block(attn, FFNConfig(d_ff=ff, act="swiglu")), layers),)


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="codeqwen1.5-7b",
        vocab_size=92_416,
        d_model=4_096,
        plan=_plan(32, 32, 32, 128, 13_440),
        max_seq=65_536,
        rope_theta=1_000_000.0,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="codeqwen1.5-7b-reduced",
        vocab_size=512,
        d_model=128,
        plan=_plan(2, 4, 4, 32, 256),
        max_seq=128,
        sparsity=sparsity_or_none(sparse),
        family="dense",
    )
