"""Architecture registry: 10 assigned archs, full + reduced (smoke) configs.

get_config(name, sparse=True)  -> full-size ModelConfig (dry-run only)
get_reduced(name)              -> CPU-runnable reduced config, same family
                                  structure (pattern, mixers, MoE, enc-dec)
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    AttnConfig,
    Block,
    BottleneckStage,
    CNNConfig,
    ConvSpec,
    DenseStage,
    FFNConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeConfig,
    SparsityConfig,
)

ARCHS: tuple[str, ...] = (
    "chameleon-34b",
    "codeqwen1.5-7b",
    "internlm2-20b",
    "yi-9b",
    "gemma3-27b",
    "rwkv6-3b",
    "whisper-medium",
    "deepseek-v2-236b",
    "deepseek-v2-lite-16b",
    "jamba-v0.1-52b",
)

_MODULES = {
    "chameleon-34b": "chameleon_34b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "internlm2-20b": "internlm2_20b",
    "yi-9b": "yi_9b",
    "gemma3-27b": "gemma3_27b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-medium": "whisper_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

# shapes each arch skips, with the reason recorded in DESIGN.md §6
SHAPE_SKIPS: dict[str, dict[str, str]] = {
    name: {"long_500k": "full attention is quadratic at 524k prefill; "
                        "no sub-quadratic path"}
    for name in ARCHS
    if name not in ("rwkv6-3b", "jamba-v0.1-52b")
}
SHAPE_SKIPS.setdefault("rwkv6-3b", {})
SHAPE_SKIPS.setdefault("jamba-v0.1-52b", {})


def _mod(name: str):
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, sparse: bool = True) -> ModelConfig:
    return _mod(name).config(sparse=sparse)


def get_reduced(name: str, sparse: bool = True) -> ModelConfig:
    return _mod(name).reduced(sparse=sparse)


def runnable_shapes(name: str) -> list[str]:
    return [s for s in SHAPES if s not in SHAPE_SKIPS.get(name, {})]


DEFAULT_SPARSITY = SparsityConfig()  # 2:4 compressed, targets ffn/attn_proj/expert


def sparsity_or_none(sparse: bool) -> SparsityConfig | None:
    return DEFAULT_SPARSITY if sparse else None


# ---------------------------------------------------------------------------
# CNN registry (the paper's evaluation workload: conv layers -> im2col GEMMs)
# ---------------------------------------------------------------------------

CNN_ARCHS: tuple[str, ...] = ("resnet50", "densenet121")

# every conv family is sparsified (the paper prunes all conv layers);
# the stem stays dense — its K = 3*kh*kw contraction is not M-divisible.
DEFAULT_CNN_SPARSITY = SparsityConfig(targets=("conv", "proj"))


def cnn_sparsity_or_none(sparse: bool) -> SparsityConfig | None:
    return DEFAULT_CNN_SPARSITY if sparse else None


def _cnn_mod(name: str):
    if name not in CNN_ARCHS:
        raise KeyError(f"unknown CNN {name!r}; known: {CNN_ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_cnn_config(name: str, sparse: bool = True) -> CNNConfig:
    return _cnn_mod(name).config(sparse=sparse)


def get_cnn_reduced(name: str, sparse: bool = True) -> CNNConfig:
    return _cnn_mod(name).reduced(sparse=sparse)
