"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (attention-free, 40 heads of 64) d_ff=8960 vocab=65536.
O(1) state per layer -> long_500k runner. The paper's technique applies to
all r/k/v/g/o and channel-mix GEMMs (DESIGN.md §6).
"""
from repro.configs.base import Block, FFNConfig, ModelConfig, RWKVConfig


def _plan(layers, d_ff, head_dim=64, decay_lora=64, mix_lora=32):
    blk = Block(RWKVConfig(head_dim=head_dim, decay_lora=decay_lora,
                           mix_lora=mix_lora),
                FFNConfig(d_ff=d_ff))
    return ((blk, layers),)


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="rwkv6-3b",
        vocab_size=65_536,
        d_model=2_560,
        plan=_plan(32, 8_960),
        max_seq=1_048_576,  # state is O(1); cap is nominal
        pos_embed="none",
        sparsity=sparsity_or_none(sparse),
        family="ssm",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="rwkv6-3b-reduced",
        vocab_size=512,
        d_model=128,
        plan=_plan(2, 256, head_dim=32, decay_lora=16, mix_lora=8),
        max_seq=128,
        pos_embed="none",
        sparsity=sparsity_or_none(sparse),
        family="ssm",
    )
