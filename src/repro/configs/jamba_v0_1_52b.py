"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7, MoE [arXiv:2403.19887].

32L d_model=4096, attn at layer i%8==4 (32H GQA kv=8), mamba elsewhere
(d_state=16, conv=4, expand=2, dt_rank=256); MoE (16 experts top-2,
d_expert=14336, no shared) on odd layers, dense FFN (14336) on even.
Plan: one 8-layer period scanned 4x. No positional embedding (hybrid
recurrence carries position). vocab=65536. Hybrid -> long_500k runner.
"""
from repro.configs.base import (
    AttnConfig,
    Block,
    FFNConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
)


def _period(q, kv, hd, ff, n_exp, top_k, d_expert, d_state, dt_rank):
    attn = AttnConfig(q_heads=q, kv_heads=kv, head_dim=hd, rope=False)
    mam = MambaConfig(d_state=d_state, d_conv=4, expand=2, dt_rank=dt_rank)
    ffn = FFNConfig(d_ff=ff, act="swiglu")
    moe = MoEConfig(n_experts=n_exp, top_k=top_k, d_expert=d_expert,
                    n_shared=0)
    # layer i in period: attn iff i == 4; moe iff i odd
    return tuple(
        Block(attn if i == 4 else mam, moe if i % 2 == 1 else ffn)
        for i in range(8)
    )


def config(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="jamba-v0.1-52b",
        vocab_size=65_536,
        d_model=4_096,
        plan=((_period(32, 8, 128, 14_336, 16, 2, 14_336, 16, 256), 4),),
        max_seq=1_048_576,
        pos_embed="none",
        sparsity=sparsity_or_none(sparse),
        family="hybrid",
    )


def reduced(sparse: bool = True) -> ModelConfig:
    from repro.configs import sparsity_or_none

    return ModelConfig(
        name="jamba-v0.1-52b-reduced",
        vocab_size=512,
        d_model=128,
        plan=((_period(4, 2, 16, 256, 4, 2, 256, 8, 16), 1),),
        max_seq=128,
        pos_embed="none",
        sparsity=sparsity_or_none(sparse),
        family="hybrid",
    )
