"""Deterministic synthetic LM data pipeline with a persistable cursor.

Production shape: each host materializes only its shard of the global
batch (host_id / num_hosts slicing); the cursor (step count) is saved in
checkpoints so a restarted/elastically-resized job resumes on exactly the
next batch — no data repetition or skips across failures (the
fault-tolerance tests assert this).

Synthetic distribution: Zipf-ish token draws + a deterministic "copy span"
so models can actually learn next-token structure in the examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_frac: float = 0.25  # fraction of the sequence that is a copy span


@dataclasses.dataclass
class DataPipeline:
    cfg: PipelineConfig
    step: int = 0
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.cfg.global_batch % self.num_hosts == 0
        return self.cfg.global_batch // self.num_hosts

    def _gen(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, self.host_id]))
        b, s = self.host_batch, c.seq_len
        # zipf-ish marginal over the vocab
        ranks = np.arange(1, c.vocab_size + 1)
        p = 1.0 / ranks
        p /= p.sum()
        toks = rng.choice(c.vocab_size, size=(b, s), p=p).astype(np.int32)
        # plant a copy span: second half of the span repeats the first
        span = max(2, int(s * c.copy_frac)) // 2 * 2
        half = span // 2
        start = rng.integers(0, s - span + 1)
        toks[:, start + half : start + span] = toks[:, start : start + half]
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -100, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}

    def next(self) -> dict:
        batch = self._gen(self.step)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step = int(state["step"])
