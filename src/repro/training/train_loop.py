"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation, remat, and optional int8 error-feedback gradient compression
on the cross-pod data-parallel reduction.

The returned `train_step(params, opt_state, batch, compress_state)` is a
pure function suitable for jax.jit with in/out shardings (launch/train.py
and launch/dryrun.py own the pjit wrapping).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import LM
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1  # gradient accumulation steps per train step
    remat: str = "dots"  # none | dots | full
    grad_compression: bool = False  # int8 error-feedback DP reduction


def _int8_compress(g: jax.Array):
    """Error-feedback int8 quantization for gradient all-reduce volume.

    Returns (q, scale). Dequant: q * scale. The residual (g - deq) is the
    error-feedback term the caller folds into the next step.
    """
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _compress_tree(grads, err):
    """Quantize grads (+error feedback), return (deq_grads, new_err)."""
    def one(g, e):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g, e
        gf = g.astype(jnp.float32) + e
        q, s = _int8_compress(gf)
        deq = q.astype(jnp.float32) * s
        return deq, gf - deq

    out = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def init_compress_state(params):
    return jax.tree.map(
        lambda p: (jnp.zeros_like(p, dtype=jnp.float32)
                   if jnp.issubdtype(p.dtype, jnp.inexact)
                   else jnp.zeros((), jnp.int8)), params)


def make_train_step(lm: LM, tcfg: TrainConfig):
    """Builds train_step(params, opt_state, batch [, compress_err])."""

    def loss_fn(params, batch):
        loss, parts = lm.loss(params, batch, remat=tcfg.remat)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True, allow_int=True)

    def accumulate(params, batch):
        if tcfg.microbatches == 1:
            (loss, parts), grads = grad_fn(params, batch)
            return loss, parts, grads
        mb = tcfg.microbatches
        split = jax.tree.map(
            lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)

        def body(carry, mbatch):
            acc, loss_sum = carry
            (loss, parts), grads = grad_fn(params, mbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32)
                if jnp.issubdtype(g.dtype, jnp.floating) else a,
                acc, grads)
            return (acc, loss_sum + loss), parts

        zero = jax.tree.map(
            lambda p: (jnp.zeros(p.shape, jnp.float32)
                       if jnp.issubdtype(p.dtype, jnp.inexact)
                       else jnp.zeros((), jnp.int8)), params)
        (acc, loss_sum), parts = jax.lax.scan(body, (zero, 0.0), split)
        grads = jax.tree.map(
            lambda g: g / mb if jnp.issubdtype(g.dtype, jnp.floating) else g,
            acc)
        parts = jax.tree.map(lambda x: x[-1], parts)
        return loss_sum / mb, parts, grads

    def train_step(params, opt_state, batch, compress_err=None):
        loss, parts, grads = accumulate(params, batch)
        new_err = compress_err
        if tcfg.grad_compression:
            assert compress_err is not None
            grads, new_err = _compress_tree(grads, compress_err)
        params, opt_state, om = adamw_update(tcfg.opt, params, grads,
                                             opt_state)
        metrics = {"loss": loss, **parts, **om}
        if tcfg.grad_compression:
            return params, opt_state, new_err, metrics
        return params, opt_state, metrics

    return train_step


def init_train_state(lm: LM, key: jax.Array, tcfg: TrainConfig,
                     param_dtype=jnp.float32):
    params = lm.init(key, param_dtype=param_dtype)
    opt_state = adamw_init(params)
    if tcfg.grad_compression:
        return params, opt_state, init_compress_state(params)
    return params, opt_state
