"""Fault-tolerant training driver: checkpoint / restart / resume.

Scope at 1000+ nodes (documented design, exercised here at test scale):

* **Failure model**: a node failure kills the whole SPMD step (synchronous
  collectives). Recovery = restart on a healthy slice, restore the latest
  checkpoint, fast-forward the data cursor, continue. `run_resilient`
  implements exactly that loop and the tests inject failures.
* **Elastic scaling**: restore re-places arrays under the *current* mesh's
  shardings (Checkpointer.restore(shardings=...)), so the replacement
  slice may have a different device count/topology.
* **Straggler mitigation**: steps are fixed-shape and compiled once, so
  variance comes from the platform, not the program. The framework keeps
  per-step wall-time telemetry (`StepTimer`) and flags steps > k·median —
  the signal used to trigger re-slicing; with checkpoints every
  `ckpt_every` steps the lost-work bound is ckpt_every·step_time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.data.pipeline import DataPipeline
from repro.training.checkpoint import Checkpointer


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StepTimer:
    times: list = dataclasses.field(default_factory=list)
    straggler_factor: float = 3.0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times[-50:]))
        return dt > self.straggler_factor * med


def run_resilient(
    *,
    train_step: Callable,
    init_state: Callable[[], Any],
    pipeline: DataPipeline,
    ckpt: Checkpointer,
    total_steps: int,
    ckpt_every: int = 10,
    failure_hook: Optional[Callable[[int], None]] = None,
    max_restarts: int = 10,
) -> dict:
    """Run to total_steps surviving injected failures.

    failure_hook(step) may raise SimulatedFailure to model a node loss.
    Returns {"metrics": last, "restarts": n, "steps_run": ...}.
    """
    restarts = 0
    timer = StepTimer()
    stragglers = 0

    while True:
        # (re)initialize or restore
        state = init_state()
        start = 0
        if ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state)
            start = meta["step"]
            pipeline.restore(meta["extra"]["data"])
        try:
            metrics = None
            for step in range(start, total_steps):
                batch = pipeline.next()
                if failure_hook is not None:
                    failure_hook(step)
                t0 = time.perf_counter()
                state, metrics = train_step(state, batch)
                if timer.record(time.perf_counter() - t0):
                    stragglers += 1
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    ckpt.save(step + 1, state,
                              extra={"data": pipeline.state()}, async_=True)
            ckpt.wait()
            return {"metrics": metrics, "restarts": restarts,
                    "steps_run": total_steps, "stragglers": stragglers,
                    "final_state": state}
        except SimulatedFailure:
            try:  # drain any in-flight async save before restarting
                ckpt.wait()
            except Exception:
                pass
            restarts += 1
            if restarts > max_restarts:
                raise
            # the failed slice's pipeline state is discarded; restore path
            # above re-syncs it from the checkpoint manifest
            pipeline.step = 0
