"""Sharded checkpointing with async save and elastic restore.

Format: one .npz per pytree "shard group" + a JSON manifest holding the
treedef, dtypes, shapes, step and data-pipeline cursor. Restore works onto
a *different* mesh/sharding than the save used (elastic scaling): arrays
are loaded host-side and re-placed with jax.device_put under the target
sharding — the standard resize-on-restart flow for 1000+ node jobs where
the replacement slice differs from the failed one.

Async: `save_async` snapshots to host memory synchronously (cheap) and
writes to disk on a background thread so the train loop is not blocked on
I/O; `wait()` joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    named = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    return named, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, named: dict, meta: dict) -> None:
        try:
            path = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(path + ".tmp", exist_ok=True)
            np.savez(os.path.join(path + ".tmp", "arrays.npz"), **named)
            with open(os.path.join(path + ".tmp", "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(path):  # re-save after restart: replace
                import shutil
                shutil.rmtree(path)
            os.rename(path + ".tmp", path)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            p = os.path.join(self.dir, f"step_{s:08d}")
            for f in os.listdir(p):
                os.remove(os.path.join(p, f))
            os.rmdir(p)

    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             async_: bool = False) -> None:
        self.wait()
        # snapshot to host memory (synchronous, releases devices)
        named, _ = _flatten(state)
        meta = {"step": step, "extra": extra or {}}
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, named, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, named, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """template: pytree with the target structure (e.g. from
        jax.eval_shape). shardings: optional matching pytree of
        NamedShardings for elastic re-placement onto the current mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = jax.tree_util.tree_flatten(template)
        loaded = []
        for i, leaf in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != "
                    f"template {leaf.shape}")
            loaded.append(arr.astype(leaf.dtype))
        state = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta
