"""Sharded checkpointing with async save and elastic restore.

Format (v3): one .npz per pytree "shard group" + a JSON manifest holding
the step, the data-pipeline cursor, per-leaf key paths, and the static
metadata of every typed sparse weight node
(:class:`repro.core.nmweight.NMWeight` / :class:`MaskedNMWeight` /
the quantized :class:`repro.quant.QNMWeight`): the N:M pattern,
compressed axis, kernel policy and — for quantized weights — the
quantization kind and scale dtype travel WITH the checkpoint, and
restore verifies them against the template (a 1:4 checkpoint cannot
silently restore into a 2:4 model, and a bf16 checkpoint cannot
restore into an int8 template — the arrays would decompress into
garbage long before any shape check fired). v3 only *adds* the
quantized node kind: v2 checkpoints (no QNMWeight leaves) restore
unchanged through the same positional path. Restore
works onto a *different* mesh/sharding than the save used (elastic
scaling): arrays are loaded host-side and re-placed with jax.device_put
under the target sharding — the standard resize-on-restart flow for
1000+ node jobs where the replacement slice differs from the failed one.

Legacy migration: checkpoints written before NMWeight existed stored
compressed weights as ``{"vals", "idx"}`` dicts, whose sorted-key
flatten order (idx, vals) is the reverse of NMWeight's (vals, idx) — a
blind leaf-index restore would transpose the pair. A one-time shim
detects the old manifest (no ``format`` field), rebuilds the legacy leaf
order by dict-ifying the typed template, and remaps by key path. This
module is the ONE place allowed to know the legacy dict layout.

Async: `save_async` snapshots to host memory synchronously (cheap) and
writes to disk on a background thread so the train loop is not blocked on
I/O; `wait()` joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.nmweight import MaskedNMWeight, NMWeight, is_weight_node
from repro.quant import QNMWeight

_FORMAT = 3


def _pathstr(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "name", k))) for k in path)


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], list[str]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    named = {f"leaf_{i}": np.asarray(l) for i, (_, l) in enumerate(flat)}
    return named, [_pathstr(p) for p, _ in flat]


def _policy_meta(pol) -> dict:
    return {
        "mode": pol.mode,
        "block": list(pol.block) if pol.block else None,
        "decode_block": list(pol.decode_block) if pol.decode_block else None,
        "backend": getattr(pol, "backend", "auto"),
    }


def policy_from_meta(meta: dict) -> "KernelPolicy":
    """Rebuild a :class:`repro.core.nmweight.KernelPolicy` from a
    manifest's per-leaf ``policy`` dict. Manifests written before the
    kernel-backend axis existed carry no ``backend`` key — they restore
    as ``"auto"`` (the pre-axis behavior: platform decides)."""
    from repro.core.nmweight import KernelPolicy

    return KernelPolicy(
        mode=meta.get("mode", "off"),
        block=tuple(meta["block"]) if meta.get("block") else None,
        decode_block=(tuple(meta["decode_block"])
                      if meta.get("decode_block") else None),
        backend=meta.get("backend", "auto"),
    )


def _weight_meta(tree: Any) -> dict[str, dict]:
    """Static metadata of every typed sparse weight node, keyed by path."""
    out: dict[str, dict] = {}
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_weight_node)[0]
    for path, leaf in flat:
        if isinstance(leaf, QNMWeight):
            # checked before NMWeight branches: the quantized node must
            # never be mistaken for (or restored as) the float kind.
            pol = leaf.kernel_policy
            out[_pathstr(path)] = {
                "kind": "quantized", "n": leaf.nm.n, "m": leaf.nm.m,
                "axis": leaf.axis,
                "scale_dtype": str(np.dtype(
                    getattr(leaf.scales, "dtype", np.float32))),
                "policy": _policy_meta(pol),
            }
        elif isinstance(leaf, NMWeight):
            pol = leaf.kernel_policy
            out[_pathstr(path)] = {
                "kind": "compressed", "n": leaf.nm.n, "m": leaf.nm.m,
                "axis": leaf.axis,
                "policy": _policy_meta(pol),
            }
        elif isinstance(leaf, MaskedNMWeight):
            out[_pathstr(path)] = {
                "kind": "masked", "n": leaf.nm.n, "m": leaf.nm.m,
                "axis": leaf.axis,
            }
    return out


def _to_legacy(tree: Any) -> Any:
    """Template as it looked before typed weights: NMWeight ->
    {"vals", "idx"} dict, MaskedNMWeight -> {"w"} dict (migration shim
    only — nothing else may reconstruct this layout)."""

    def conv(x):
        if isinstance(x, NMWeight):
            return {"vals": x.vals, "idx": x.idx}
        if isinstance(x, MaskedNMWeight):
            return {"w": x.w}
        return x

    return jax.tree.map(conv, tree, is_leaf=is_weight_node)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, named: dict, meta: dict) -> None:
        try:
            path = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(path + ".tmp", exist_ok=True)
            np.savez(os.path.join(path + ".tmp", "arrays.npz"), **named)
            with open(os.path.join(path + ".tmp", "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(path):  # re-save after restart: replace
                import shutil
                shutil.rmtree(path)
            os.rename(path + ".tmp", path)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            p = os.path.join(self.dir, f"step_{s:08d}")
            for f in os.listdir(p):
                os.remove(os.path.join(p, f))
            os.rmdir(p)

    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             async_: bool = False) -> None:
        self.wait()
        # snapshot to host memory (synchronous, releases devices)
        named, paths = _flatten(state)
        meta = {"format": _FORMAT, "step": step, "extra": extra or {},
                "leaves": paths, "weights": _weight_meta(state)}
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, named, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, named, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def _leaf_order(self, meta: dict, template: Any) -> list[int]:
        """Checkpoint leaf index for each template leaf, in template
        order. v2 manifests restore by position (paths recorded for
        diagnostics); legacy manifests go through the migration shim."""
        tflat = jax.tree_util.tree_flatten_with_path(template)[0]
        tpaths = [_pathstr(p) for p, _ in tflat]
        if meta.get("format", 1) >= 2:
            saved = meta.get("leaves")
            if saved is not None and list(saved) != tpaths:
                extra = set(saved) ^ set(tpaths)
                raise ValueError(
                    "checkpoint tree does not match restore template "
                    f"(mismatched paths, e.g. {sorted(extra)[:3]})")
            return list(range(len(tpaths)))
        # legacy {vals, idx} dict checkpoints: rebuild the old flatten
        # order from the dict-ified template and remap by key path.
        lflat = jax.tree_util.tree_flatten_with_path(_to_legacy(template))[0]
        index = {_pathstr(p): i for i, (p, _) in enumerate(lflat)}
        try:
            return [index[p] for p in tpaths]
        except KeyError as e:
            raise ValueError(
                f"legacy checkpoint migration failed: no stored leaf for "
                f"template path {e.args[0]!r}") from None

    def _check_weight_meta(self, meta: dict, template: Any) -> None:
        stored = meta.get("weights")
        if stored is None:  # legacy manifest: nothing to verify against
            return
        want = _weight_meta(template)
        for path, tw in want.items():
            sw = stored.get(path)
            if sw is None:
                raise ValueError(
                    f"checkpoint has no sparse-weight metadata for {path!r}"
                    " (saved from a dense/differently-sparsified model?)")
            for key in ("kind", "n", "m", "axis"):
                if sw.get(key) != tw.get(key):
                    raise ValueError(
                        f"sparse-weight metadata mismatch at {path!r}: "
                        f"checkpoint {sw.get(key)!r} != template "
                        f"{tw.get(key)!r} for {key!r}")
            # kernel_policy is an execution preference, not data: the
            # template's policy wins on restore (no check).

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """template: pytree with the target structure (e.g. from
        jax.eval_shape). shardings: optional matching pytree of
        NamedShardings for elastic re-placement onto the current mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        self._check_weight_meta(meta, template)
        data = np.load(os.path.join(path, "arrays.npz"))
        order = self._leaf_order(meta, template)
        leaves, treedef = jax.tree_util.tree_flatten(template)
        loaded = []
        for i, leaf in enumerate(leaves):
            arr = data[f"leaf_{order[i]}"]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != "
                    f"template {leaf.shape}")
            loaded.append(arr.astype(leaf.dtype))
        state = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta
