"""Custom-kernel layer for the paper's compute hot spot (N:M spmm).

Structure:
  registry.py  — named implementations per op, priority dispatch, records
                 (each registered for a kernel backend; other-backend
                 impls are filtered silently)
  backend.py   — the backend axis: tpu/gpu availability, auto
                 resolution (call -> policy -> $REPRO_BACKEND ->
                 platform), typed force errors
  padding.py   — shape normalization (pad-to-tileable, slice back)
  autotune.py  — per-shape block sweep with a persistent on-disk cache
                 (per value-dtype family and kernel backend: the int8
                 sweep never shares keys with bf16/f32, nor gpu with
                 tpu)
  indexmac/    — TPU adaptation: decompress-in-VMEM -> MXU (the fast
                 path) + the int8 dequantizing variant (nm_matmul_q)
  indexmac_gather/ — literal vindexmac port (faithfulness artifact)
                 + its int8 variant (indexmac_gather_q)
  indexmac_gpu/ — Pallas-on-Triton lowering of all three families
                 (prefill, decode, gather + int8 variants): output-tile
                 grids, in-kernel K reduction, register accumulators
"""
from repro.kernels import registry  # noqa: F401  (re-export for callers)
