"""Custom-kernel layer for the paper's compute hot spot (N:M spmm).

Structure:
  registry.py  — named implementations per op, priority dispatch, records
  padding.py   — shape normalization (pad-to-tileable, slice back)
  autotune.py  — per-shape block sweep with a persistent on-disk cache
                 (per value-dtype family: the int8 sweep never shares
                 keys with bf16/f32)
  indexmac/    — TPU adaptation: decompress-in-VMEM -> MXU (the fast
                 path) + the int8 dequantizing variant (nm_matmul_q)
  indexmac_gather/ — literal vindexmac port (faithfulness artifact)
                 + its int8 variant (indexmac_gather_q)
"""
from repro.kernels import registry  # noqa: F401  (re-export for callers)
