"""Custom-kernel layer for the paper's compute hot spot (N:M spmm).

Structure:
  registry.py  — named implementations per op, priority dispatch, records
  padding.py   — shape normalization (pad-to-tileable, slice back)
  autotune.py  — per-shape block sweep with a persistent on-disk cache
  indexmac/    — TPU adaptation: decompress-in-VMEM -> MXU (the fast path)
  indexmac_gather/ — literal vindexmac port (faithfulness artifact)
"""
from repro.kernels import registry  # noqa: F401  (re-export for callers)
