"""Backend axis for kernel dispatch: which *kernel family* runs a call.

The registry grew out of a single Pallas-on-TPU lowering, which let
TPU-shaped assumptions (tile geometry, VMEM scratch, SMEM scalar reads)
leak into call sites. The follow-up work (arXiv 2501.10189,
arXiv 2305.05559) shows the indexed-MAC idea spans ISAs — the software
mirror is an explicit backend axis: every kernel implementation is
registered *for* a backend, and selection is a first-class API concern
instead of an implicit "whatever Pallas TPU emits".

Two kernel backends exist:

  tpu   the original family (:mod:`repro.kernels.indexmac` /
        ``indexmac_gather``): Mosaic lowering, VMEM scratch
        accumulators, SMEM scalar index reads. Off-TPU it runs in the
        Pallas interpreter (the historical CPU-test behavior), so it is
        always *available*.
  gpu   the Pallas-on-Triton family (:mod:`repro.kernels.indexmac_gpu`):
        grid over output tiles only (every grid dim is a parallel
        program instance — there is no sequential-grid accumulator), the
        K reduction lives inside the kernel, no TPU memory spaces.
        Available on a CUDA/ROCm host, or anywhere when
        ``REPRO_GPU_INTERPRET=1`` opts into the interpreter (the CI
        ``gpu-interpret`` lane).

Resolution order for a call (``repro.api.nm_matmul`` / the weight's
:class:`repro.core.nmweight.KernelPolicy`):

  1. an explicit per-call ``backend=`` argument,
  2. the weight policy's static ``backend`` field,
  3. ``$REPRO_BACKEND`` (consulted only when 1-2 say ``"auto"``),
  4. the device platform (``jax.default_backend()``): a GPU host
     resolves to ``gpu``, everything else to ``tpu``.

Forcing an *unavailable* backend raises the typed
:class:`repro.kernels.registry.KernelForceError` naming the backend —
the same no-silent-fallback contract ``KernelPolicy("force")`` already
enforces for shapes. ``auto`` never raises: the platform default is
available by construction.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = [
    "BACKENDS",
    "KERNEL_BACKENDS",
    "backend_unavailable_reason",
    "gpu_interpret_opt_in",
    "platform_backend",
    "resolve_backend",
]

# the values a policy / call / $REPRO_BACKEND may carry
BACKENDS = ("auto", "tpu", "gpu")
# the values resolution produces (and registrations declare)
KERNEL_BACKENDS = ("tpu", "gpu")


def gpu_interpret_opt_in() -> bool:
    """True when ``REPRO_GPU_INTERPRET=1`` opts the GPU kernel family
    into the Pallas interpreter on a host without GPU devices (the CI
    ``gpu-interpret`` lane and the parity test suite)."""
    return os.environ.get("REPRO_GPU_INTERPRET") == "1"


def platform_backend() -> str:
    """The kernel backend the device platform implies: ``gpu`` on a
    CUDA/ROCm host, ``tpu`` everywhere else (on CPU the TPU family runs
    in the Pallas interpreter — the historical default)."""
    return "gpu" if jax.default_backend() == "gpu" else "tpu"


def _validate(value: str, source: str) -> str:
    if value not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {value!r} from {source}; expected one "
            f"of {BACKENDS}")
    return value


def backend_unavailable_reason(backend: str) -> Optional[str]:
    """None when ``backend`` can execute on this host, else the
    human-readable reason (used both for the typed force error and for
    registry skip diagnostics)."""
    if backend == "tpu":
        return None  # interpreter fallback keeps the family runnable
    if backend == "gpu":
        if jax.default_backend() == "gpu" or gpu_interpret_opt_in():
            return None
        return ("no GPU devices visible (jax.default_backend()="
                f"{jax.default_backend()!r}) and REPRO_GPU_INTERPRET!=1")
    return f"unknown backend {backend!r}"


def resolve_backend(requested: Optional[str] = None, *,
                    check: bool = True) -> str:
    """Resolve ``auto``/``tpu``/``gpu``/None to a concrete kernel backend.

    ``None`` and ``"auto"`` defer to ``$REPRO_BACKEND`` and then the
    device platform. With ``check=True`` (the default) an explicitly
    requested backend that cannot execute here raises the typed
    :class:`repro.kernels.registry.KernelForceError` naming the backend
    — auto resolution never raises.
    """
    from repro.kernels.registry import KernelForceError

    source = "call/policy"
    value = requested if requested is not None else "auto"
    _validate(value, source)
    if value == "auto":
        env = os.environ.get("REPRO_BACKEND")
        if env:
            value, source = _validate(env, "$REPRO_BACKEND"), "$REPRO_BACKEND"
    if value == "auto":
        return platform_backend()
    if check:
        why = backend_unavailable_reason(value)
        if why is not None:
            raise KernelForceError(
                f"kernel backend {value!r} (from {source}) cannot execute "
                f"on this host: {why}")
    return value


def interpret_for(backend: str) -> bool:
    """Whether a Pallas kernel of ``backend`` must run interpreted on
    this host: the TPU family interprets off-TPU, the GPU family
    interprets off-GPU (reachable only under the explicit
    ``REPRO_GPU_INTERPRET=1`` opt-in)."""
    return jax.default_backend() != backend
