"""Block-sparse attention mask specs and their compiled block plans.

A :class:`MaskSpec` is to attention what :class:`repro.kernels.epilogue.
Epilogue` is to the GEMM epilogue: a small frozen declaration (pattern
name + static parameters, never a callable) that every execution path
honors identically. It lives on :class:`repro.configs.base.AttnConfig`
(``mask=``) and on the ``api.attention`` call surface, and hashes into
the autotune cache key via ``.tag`` (same duck-type as ``NMConfig``).

This module is deliberately dependency-free (numpy only) so the configs
layer can import it without pulling in jax or the kernel stack.

Two artifacts per spec:

* :func:`token_mask` — the token-level visibility predicate, the single
  source of truth. It is written against plain operators so the same
  function evaluates on numpy arrays (mask compilation, static kernel
  operands) and on traced jnp arrays (the dense reference, the decode
  path, the MLA absorbed path).
* :func:`compile_mask` — the static compiler: tile the (sq, skv) token
  grid at an arbitrary ``(bq, bk)`` tile (independent of ``spec.block``,
  so autotune can sweep tiles), and emit a :class:`MaskPlan` holding the
  block bitmap, the row-major live (q-block, k-block) pair lists the TPU
  kernel iterates (the same compressed-index idea as the weight
  kernels' ``idx`` operand), and per-row padded gather index lists for
  the gather-style lowerings. Returns ``None`` when the mask does not
  tile — the analogue of ``plan_nm_matmul`` returning ``None`` for a
  non-normalizable shape, and what ``KernelPolicy("force")`` turns into
  a typed ``MaskForceError``.

Budgets (the attention analogue of ``REPRO_PAD_WASTE_LIMIT``):

  REPRO_BS_DENSITY_LIMIT  (default 0.9)  live blocks / total blocks
      above which the block-sparse kernels decline — a near-dense mask
      gains nothing over the fused dense path.
  REPRO_BS_WASTE_LIMIT    (default 4.0)  live block *area* / live
      *token* pairs — a mask whose live blocks are mostly masked tokens
      wastes the MXU on NEG_INF lanes; decline past the limit.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
from typing import Optional

import numpy as np

MASK_KINDS = ("causal", "local", "strided", "blockwise")

_DEFAULT_DENSITY_LIMIT = 0.9
_DEFAULT_WASTE_LIMIT = 4.0

# token tiles must land on the f32 sublane granularity so the kernels'
# scratch accumulators stay legally tileable.
_SUBLANE = 8


def density_limit() -> float:
    return float(
        os.environ.get("REPRO_BS_DENSITY_LIMIT", _DEFAULT_DENSITY_LIMIT))


def waste_limit() -> float:
    return float(os.environ.get("REPRO_BS_WASTE_LIMIT", _DEFAULT_WASTE_LIMIT))


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Frozen declaration of an attention sparsity pattern.

    kind      "causal" | "local" | "strided" | "blockwise"
    block     pattern granularity in tokens (the unit ``strided`` and
              ``blockwise`` are defined over; also the default kernel
              tile). Multiple of 8.
    window    ("local") tokens of lookback: position q sees k iff
              ``q - window < k`` (and ``k <= q`` when causal).
    stride    ("strided") block-diagonal plus every stride-th block
              column: q-block i sees k-block j iff ``i == j`` or
              ``(i - j) % stride == 0``.
    blocks    ("blockwise") explicit live (q_block, k_block) pairs at
              ``block`` granularity.
    causal    AND the causal triangle into the pattern (ignored for
              kind="causal", which is inherently causal). Masks with
              ``causal=False`` may leave a query row with no visible
              token — such masks do not compile (softmax undefined).
    """

    kind: str = "causal"
    block: int = 128
    window: Optional[int] = None
    stride: Optional[int] = None
    blocks: Optional[tuple[tuple[int, int], ...]] = None
    causal: bool = True

    def __post_init__(self):
        if self.kind not in MASK_KINDS:
            raise ValueError(
                f"MaskSpec.kind must be one of {MASK_KINDS}, got "
                f"{self.kind!r}")
        if self.block < _SUBLANE or self.block % _SUBLANE:
            raise ValueError(
                f"MaskSpec.block must be a multiple of {_SUBLANE}, got "
                f"{self.block}")
        if self.kind == "local":
            if self.window is None or self.window < 1:
                raise ValueError(
                    "MaskSpec(kind='local') needs window >= 1, got "
                    f"{self.window!r}")
        elif self.window is not None:
            raise ValueError(f"window is local-only, not {self.kind!r}")
        if self.kind == "strided":
            if self.stride is None or self.stride < 1:
                raise ValueError(
                    "MaskSpec(kind='strided') needs stride >= 1, got "
                    f"{self.stride!r}")
        elif self.stride is not None:
            raise ValueError(f"stride is strided-only, not {self.kind!r}")
        if self.kind == "blockwise":
            if not self.blocks:
                raise ValueError(
                    "MaskSpec(kind='blockwise') needs a non-empty blocks "
                    "tuple of (q_block, k_block) pairs")
            norm = tuple(sorted({(int(i), int(j)) for i, j in self.blocks}))
            if any(i < 0 or j < 0 for i, j in norm):
                raise ValueError("blockwise pairs must be non-negative")
            object.__setattr__(self, "blocks", norm)
        elif self.blocks is not None:
            raise ValueError(f"blocks is blockwise-only, not {self.kind!r}")

    @property
    def tag(self) -> str:
        """Autotune-key token (the ``NMConfig.tag`` duck-type)."""
        c = f"c{int(self.causal)}"
        if self.kind == "causal":
            return f"causal:b{self.block}"
        if self.kind == "local":
            return f"local:w{self.window}:b{self.block}:{c}"
        if self.kind == "strided":
            return f"strided:s{self.stride}:b{self.block}:{c}"
        digest = hashlib.blake2s(
            repr(self.blocks).encode()).hexdigest()[:10]
        return f"blockwise:{len(self.blocks)}p:{digest}:b{self.block}:{c}"


def block_bitmap(spec: MaskSpec, nq: int, nk: int) -> np.ndarray:
    """(nq, nk) bool bitmap of a blockwise spec's live pairs at
    ``spec.block`` granularity (pairs outside the bounds are dropped —
    they address blocks past the sequence)."""
    bm = np.zeros((nq, nk), dtype=bool)
    for i, j in spec.blocks or ():
        if i < nq and j < nk:
            bm[i, j] = True
    return bm


def token_mask(spec: MaskSpec, q_pos, k_pos, *, bitmap=None):
    """Token-level visibility predicate — the single source of truth.

    ``q_pos`` / ``k_pos`` are broadcastable integer arrays, numpy OR
    traced jnp (only plain operators are used). ``bitmap`` is required
    for kind="blockwise": the :func:`block_bitmap` array covering every
    position, as numpy (static callers) or jnp (traced callers) —
    indexing picks the caller's backend.
    """
    if spec.kind == "causal":
        return k_pos <= q_pos
    if spec.kind == "local":
        near = q_pos - k_pos < spec.window
        if spec.causal:
            return (k_pos <= q_pos) & near
        return near & (k_pos - q_pos < spec.window)
    qb = q_pos // spec.block
    kb = k_pos // spec.block
    if spec.kind == "strided":
        live = (qb == kb) | ((qb - kb) % spec.stride == 0)
    else:  # blockwise
        if bitmap is None:
            raise ValueError(
                "token_mask(kind='blockwise') needs the block bitmap")
        live = bitmap[qb, kb]
    if spec.causal:
        live = live & (k_pos <= q_pos)
    return live


@dataclasses.dataclass(frozen=True, eq=False)
class MaskPlan:
    """Static compiled form of a MaskSpec for an (sq, skv) problem at a
    ``(bq, bk)`` token tile. All arrays are host numpy — kernel operands
    and static mask constants are derived from them at trace time.

    ``pair_q``/``pair_k`` are sorted row-major (q-block monotone
    non-decreasing), which is what makes the TPU kernel's
    first/last-pair scratch init + output flush correct. ``row_idx`` /
    ``row_valid`` are the per-q-row live k-block lists padded to the
    widest row (pad index 0 — gather-safe, masked out by row_valid).
    ``tokens`` is the full padded token-level mask the tiling was
    derived from (tiles of it become the kernels' static mask operands
    and the reference comparison).
    """

    sq: int
    skv: int
    bq: int
    bk: int
    nqb: int
    nkb: int
    bitmap: np.ndarray      # (nqb, nkb) bool
    tokens: np.ndarray      # (nqb*bq, nkb*bk) bool, padded positions False
    pair_q: np.ndarray      # (n_live,) int32
    pair_k: np.ndarray      # (n_live,) int32
    row_idx: np.ndarray     # (nqb, gather_width) int32
    row_valid: np.ndarray   # (nqb, gather_width) bool
    n_live: int
    live_tokens: int

    @property
    def density(self) -> float:
        """Live blocks / total blocks — the fraction of the block grid
        the sparse kernels actually visit."""
        return self.n_live / max(self.nqb * self.nkb, 1)

    @property
    def waste(self) -> float:
        """Live block area / live token pairs (>= 1.0) — the attention
        analogue of ``PadPlan.waste_nk``."""
        return (self.n_live * self.bq * self.bk) / max(self.live_tokens, 1)

    @property
    def gather_width(self) -> int:
        return int(self.row_idx.shape[1])

    # DispatchRecord geometry hooks (the PadPlan duck-type consumed by
    # registry.dispatch when uses_plan=True).
    @property
    def padded_shape(self) -> tuple:
        return (self.nqb * self.bq, self.nkb * self.bk)

    @property
    def block(self) -> tuple:
        return (self.bq, self.bk)


def _round_up(x: int, to: int) -> int:
    return -(-x // to) * to


def default_tile(spec: MaskSpec, sq: int, skv: int) -> tuple[int, int]:
    """The pattern-granularity tile, clamped to the problem."""
    bq = min(spec.block, _round_up(max(sq, 1), _SUBLANE))
    bk = min(spec.block, _round_up(max(skv, 1), _SUBLANE))
    return bq, bk


@functools.lru_cache(maxsize=512)
def compile_mask(spec: MaskSpec, sq: int, skv: int,
                 tile: Optional[tuple[int, int]] = None
                 ) -> Optional[MaskPlan]:
    """Compile ``spec`` for an (sq, skv) attention problem at ``tile``
    (default: the spec's own block granularity, clamped to the problem).

    Returns None — "mask does not tile" — when the problem is empty,
    the tile is not sublane-aligned, or any query row ends up with zero
    visible tokens (softmax undefined; only reachable with
    ``causal=False`` patterns that skip a row).
    """
    if sq <= 0 or skv <= 0:
        return None
    bq, bk = tile or default_tile(spec, sq, skv)
    if bq < _SUBLANE or bq % _SUBLANE or bk < _SUBLANE or bk % _SUBLANE:
        return None
    nqb = -(-sq // bq)
    nkb = -(-skv // bk)
    q_pos = np.arange(nqb * bq)
    k_pos = np.arange(nkb * bk)
    bm_tok = None
    if spec.kind == "blockwise":
        bm_tok = block_bitmap(spec, -(-(nqb * bq) // spec.block),
                              -(-(nkb * bk) // spec.block))
    tokens = token_mask(spec, q_pos[:, None], k_pos[None, :], bitmap=bm_tok)
    tokens = tokens & (q_pos[:, None] < sq) & (k_pos[None, :] < skv)
    if not tokens[:sq].any(axis=1).all():
        return None  # a query row sees nothing: softmax undefined
    bitmap = tokens.reshape(nqb, bq, nkb, bk).any(axis=(1, 3))
    pair_q, pair_k = np.nonzero(bitmap)  # row-major == sorted by q-block
    counts = bitmap.sum(axis=1)
    width = int(counts.max())
    row_idx = np.zeros((nqb, width), dtype=np.int32)
    row_valid = np.zeros((nqb, width), dtype=bool)
    for r in range(nqb):
        live = np.nonzero(bitmap[r])[0]
        row_idx[r, : live.size] = live
        row_valid[r, : live.size] = True
    return MaskPlan(
        sq=sq, skv=skv, bq=bq, bk=bk, nqb=nqb, nkb=nkb,
        bitmap=bitmap, tokens=tokens,
        pair_q=pair_q.astype(np.int32), pair_k=pair_k.astype(np.int32),
        row_idx=row_idx, row_valid=row_valid,
        n_live=int(pair_q.size), live_tokens=int(tokens.sum()),
    )


def pair_masks(plan: MaskPlan) -> np.ndarray:
    """(n_live, bq, bk) static token masks, one tile per live pair — the
    TPU kernel's per-grid-step mask operand."""
    t4 = plan.tokens.reshape(plan.nqb, plan.bq, plan.nkb, plan.bk)
    return np.ascontiguousarray(
        t4[plan.pair_q, :, plan.pair_k, :])


def gather_masks(plan: MaskPlan) -> np.ndarray:
    """(nqb, gather_width, bq, bk) token masks aligned with ``row_idx``
    — padded gather slots are all-False (row_valid folded in)."""
    t4 = plan.tokens.reshape(plan.nqb, plan.bq, plan.nkb, plan.bk)
    # separated advanced indices: the broadcast (nqb, width) index dims
    # land first, giving (nqb, width, bq, bk) directly.
    out = t4[np.arange(plan.nqb)[:, None], :, plan.row_idx, :]
    return out & plan.row_valid[:, :, None, None]
