"""Block-sparse attention kernel family (``bs_attention`` /
``bs_attention_decode``).

Layout mirrors the weight-kernel packages:

  mask.py        MaskSpec (frozen pattern declaration), the token-level
                 predicate, and the static block compiler (bitmap +
                 live-pair lists + per-row gather lists) — numpy-only,
                 importable from the configs layer.
  ref.py         backend-neutral XLA lowerings: the dense masked
                 reference (parity oracle), the block-gather lowering,
                 and the mask-aware decode path.
  kernel.py      Pallas TPU pair-list kernel (scalar-prefetch grid over
                 live blocks only).
  gpu_kernel.py  platform-neutral Pallas lowering (output-tile grid,
                 in-kernel gather loop) — the gpu-interpret CI lane.
  ops.py         registry registrations, the shared route, typed
                 entries and ``explain_dispatch_attention``.
"""
from repro.kernels.blocksparse_attn.mask import (  # noqa: F401
    MaskPlan,
    MaskSpec,
    compile_mask,
)
from repro.kernels.blocksparse_attn.ops import (  # noqa: F401
    MaskForceError,
    bs_attention,
    bs_attention_decode,
    explain_dispatch_attention,
)
