"""Backend-neutral lowerings of the block-sparse attention families.

Two XLA implementations registered for backend "any":

* :func:`masked_reference` — dense attention with the spec's token
  predicate applied through ``jnp.where``: the parity oracle every
  sparse path is compared against, and the priority-0 fallback.
* :func:`blocksparse_xla` — the block-gather lowering: pad both
  sequence axes to the plan's tile, gather each query row's live
  k-blocks through the plan's compressed ``row_idx`` lists, and run a
  masked softmax over the gathered lane only. Pure XLA (no Pallas), so
  it is the lowering that actually wins on CPU hosts — compute drops
  with the gather width instead of the full k length.

Also home of :func:`jnp_token_mask` (the traced-side wrapper of the
numpy predicate) and :func:`masked_decode` — the mask-aware decode /
chunk path shared by ``bs_attention_decode``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dots import acc_einsum
from repro.kernels.blocksparse_attn.mask import (
    MaskPlan,
    MaskSpec,
    block_bitmap,
    gather_masks,
    token_mask,
)

NEG_INF = -1e30


def jnp_token_mask(spec: MaskSpec, q_pos, k_pos, *, max_q: int, max_k: int):
    """The token predicate over (possibly traced) jnp positions.

    ``max_q``/``max_k`` are static position bounds — blockwise specs
    need them to size the bitmap the traced lookup gathers from.
    """
    bm = None
    if spec.kind == "blockwise":
        bm = jnp.asarray(block_bitmap(
            spec, -(-max_q // spec.block), -(-max_k // spec.block)))
    return token_mask(spec, q_pos, k_pos, bitmap=bm)


def _split_heads(q, k, v, scale):
    b, sq, hq, dk = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, dk)
    return qf, g


def masked_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     spec: MaskSpec, scale: Optional[float] = None
                     ) -> jax.Array:
    """Dense jnp.where-masked attention — the parity oracle.

    q: (B, Sq, Hq, Dk); k/v: (B, Skv, Hkv, D*) with Hq % Hkv == 0 (GQA
    grouping). Positions are absolute from 0 (prefill semantics).
    """
    b, sq, hq, dk = q.shape
    skv = k.shape[1]
    qf, g = _split_heads(q, k, v, scale)
    logits = acc_einsum("bqhgd,bshd->bqhgs", qf, k)
    mask = jnp_token_mask(
        spec, jnp.arange(sq)[:, None], jnp.arange(skv)[None, :],
        max_q=sq, max_k=skv)
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = acc_einsum("bqhgs,bshd->bqhgd", p, v)
    return out.reshape(b, sq, hq, -1).astype(q.dtype)


def blocksparse_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    spec: MaskSpec, plan: MaskPlan,
                    scale: Optional[float] = None) -> jax.Array:
    """Block-gather lowering: attention cost scales with the plan's
    gather width (live k-blocks per query row), not the full k length.
    """
    b, sq, hq, dk = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    bq, bk = plan.bq, plan.bk
    nqb, nkb, width = plan.nqb, plan.nkb, plan.gather_width
    if scale is None:
        scale = dk ** -0.5

    qp = jnp.pad(q, ((0, 0), (0, nqb * bq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nkb * bk - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nkb * bk - skv), (0, 0), (0, 0)))
    qb = (qp.astype(jnp.float32) * scale).reshape(b, nqb, bq, hkv, g, dk)
    kb = kp.reshape(b, nkb, bk, hkv, dk)
    vb = vp.reshape(b, nkb, bk, hkv, dv)

    idx = jnp.asarray(plan.row_idx.reshape(-1))  # (nqb*width,)
    kg = jnp.take(kb, idx, axis=1).reshape(b, nqb, width, bk, hkv, dk)
    vg = jnp.take(vb, idx, axis=1).reshape(b, nqb, width, bk, hkv, dv)

    logits = acc_einsum("bnqhgd,bnwkhd->bnqhgwk", qb, kg)
    # static numpy mask aligned with the gather: (nqb, width, bq, bk)
    gmask = jnp.asarray(gather_masks(plan))
    logits = jnp.where(
        gmask.transpose(0, 2, 1, 3)[None, :, :, None, None, :, :],
        logits, NEG_INF)
    flat = logits.reshape(b, nqb, bq, hkv, g, width * bk)
    m = jnp.max(flat, axis=-1, keepdims=True)
    p = jnp.exp(flat - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    p = p.reshape(b, nqb, bq, hkv, g, width, bk)
    out = acc_einsum("bnqhgwk,bnwkhd->bnqhgd", p, vg)
    out = out.reshape(b, nqb * bq, hq, dv)[:, :sq]
    return out.astype(q.dtype)


def masked_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  spec: MaskSpec, length, q_positions=None,
                  scale: Optional[float] = None) -> jax.Array:
    """Mask-aware decode/chunk attention over a (possibly overlong)
    cache view: q (B, Sq, Hq, Dk) against k/v (B, S, Hkv, D*).

    ``length`` is the number of valid cache positions (traced scalar or
    (B,) vector); ``q_positions`` gives each query's absolute position
    (chunk mode) — defaults to ``length - 1`` (single-step decode).
    Cache validity (``pos <= q_position``) is enforced on top of the
    spec predicate, so non-causal specs still never read unwritten
    slots.
    """
    b, sq, hq, dk = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    length = jnp.asarray(length, jnp.int32)
    if q_positions is None:
        qp = jnp.reshape(length - 1, (-1, 1))          # (B|1, 1)
        qp = jnp.broadcast_to(qp, (qp.shape[0], sq))
    else:
        qp = jnp.asarray(q_positions, jnp.int32)
        if qp.ndim == 1:
            qp = qp[None, :]
    pos = jnp.arange(s, dtype=jnp.int32)
    valid = jnp_token_mask(
        spec, qp[:, :, None], pos[None, None, :], max_q=s, max_k=s)
    valid = valid & (pos[None, None, :] <= qp[:, :, None])
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, dk)
    logits = acc_einsum("bqhgd,bshd->bqhgs", qf, k)
    logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = acc_einsum("bqhgs,bshd->bqhgd", p, v)
    return out.reshape(b, sq, hq, -1).astype(q.dtype)
