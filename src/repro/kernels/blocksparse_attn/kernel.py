"""Pallas TPU kernel for block-sparse prefill attention.

The grid iterates only the *live* (q-block, k-block) pairs of the
compiled :class:`~repro.kernels.blocksparse_attn.mask.MaskPlan` — the
attention analogue of the weight kernels' compressed-index walk. The
pair lists ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps can
pick each step's q/k/v tiles data-dependently before the body runs;
dense (masked-off) blocks are never fetched, never multiplied.

Grid: ``(B, Hq, n_live)`` with the pair dimension innermost and
``"arbitrary"`` semantics — the streaming-softmax scratch (m, l, acc)
carries across consecutive pairs of one query row. The pair lists are
sorted row-major by construction (``compile_mask``), so

* a pair whose q-block differs from its predecessor's is the row's
  first live block: re-init the scratch (``pl.when``);
* a pair whose successor starts a new row is the row's last: normalize
  and flush the output tile (Pallas revisits the same output block for
  every pair of the row — the write lands once, on the final revision).

Each pair also carries its static token-level mask tile (live blocks on
the causal diagonal are half masked; sequence-tail tiles mask padding),
applied to the f32 scores before the online-softmax update. Every
query row of a compiled plan has >= 1 live block, so the normalizer is
never zero on logical rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.blocksparse_attn.mask import MaskPlan, pair_masks

NEG_INF = -1e30


def _bs_attn_kernel(pq_ref, pk_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, scale, out_dtype):
    p = pl.program_id(2)
    n_live = pl.num_programs(2)
    prev = jnp.maximum(p - 1, 0)
    nxt = jnp.minimum(p + 1, n_live - 1)
    first = jnp.logical_or(p == 0, pq_ref[p] != pq_ref[prev])
    last = jnp.logical_or(p == n_live - 1, pq_ref[nxt] != pq_ref[p])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dk)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dk)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (bq, bk)
    s = jnp.where(mask_ref[0], s, NEG_INF)

    m_prev = m_ref[:, :1]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    pmat = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, :1] * corr + jnp.sum(pmat, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dv)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pmat, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(last)
    def _flush():
        norm = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / norm).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "plan", "scale", "interpret"),
)
def _bs_attn_call(q, k, v, pair_q, pair_k, masks, *, spec, plan, scale,
                  interpret):
    b, hq, sqp, dk = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    bq, bk = plan.bq, plan.bk
    n_live = plan.n_live
    grid = (b, hq, n_live)
    kernel = functools.partial(
        _bs_attn_kernel, scale=scale, out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hq, sqp, dv), q.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bq, dk),
                    lambda bi, hi, p, pq, pk: (bi, hi, pq[p], 0)),
                pl.BlockSpec(
                    (1, 1, bk, dk),
                    lambda bi, hi, p, pq, pk: (bi, hi // g, pk[p], 0)),
                pl.BlockSpec(
                    (1, 1, bk, dv),
                    lambda bi, hi, p, pq, pk: (bi, hi // g, pk[p], 0)),
                pl.BlockSpec(
                    (1, bq, bk),
                    lambda bi, hi, p, pq, pk: (p, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, dv),
                lambda bi, hi, p, pq, pk: (bi, hi, pq[p], 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, dv), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pair_q, pair_k, q, k, v, masks)


def run_bs_attention_tpu(q, k, v, *, spec, plan: MaskPlan, scale=None,
                         interpret: bool = False):
    """Pad to the plan's tiles, run the pair-list kernel, slice back.

    q: (B, Sq, Hq, Dk); k/v: (B, Skv, Hkv, D*) — same layout as the
    reference. Head-minor layouts are transposed to (B, H, S, D) so the
    tile walk is over the trailing (seq, depth) pair.
    """
    b, sq, hq, dk = q.shape
    skv = k.shape[1]
    if scale is None:
        scale = dk ** -0.5
    sqp = plan.nqb * plan.bq
    skvp = plan.nkb * plan.bk
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
    out = _bs_attn_call(
        qt, kt, vt,
        jnp.asarray(plan.pair_q), jnp.asarray(plan.pair_k),
        jnp.asarray(pair_masks(plan)),
        spec=spec, plan=plan, scale=float(scale), interpret=interpret)
    return jnp.transpose(out, (0, 2, 1, 3))[:, :sq]
