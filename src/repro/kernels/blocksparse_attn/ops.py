"""The block-sparse attention op layer: two dispatch families behind
one shared route.

``bs_attention`` (prefill/train shapes — q and k/v cover the same
absolute positions from 0):

  pallas_bs_attention   backend "tpu", priority 100 — the pair-list
                        scalar-prefetch kernel (:mod:`.kernel`).
                        Declines off-TPU (it would interpret) unless
                        forced.
  gpu_bs_attention      backend "gpu", priority 100 — the output-tile
                        gather kernel (:mod:`.gpu_kernel`); the gpu
                        backend is explicit opt-in, so interpreting is
                        part of the contract (CI parity lane).
  xla_bs_attention      backend "any", priority 50 — the pure-XLA
                        block-gather lowering; the one that wins real
                        wall-clock on CPU hosts.
  masked_reference      backend "any", priority 0 — dense jnp.where
                        fallback (also the parity oracle).

``bs_attention_decode`` (cache-view shapes — queries at absolute
positions against a fixed-size cache):

  masked_decode         backend "any", priority 0 — the spec predicate
                        applied inside the decode softmax; block
                        skipping buys nothing at Sq ∈ {1, chunk} with a
                        traced cache length, so the mask-aware dense
                        path IS the decode lowering (not a fallback).

Budgets (auto mode; ``force`` ignores both, and raises the typed
:class:`MaskForceError` when the mask does not tile at all):

  REPRO_BS_DENSITY_LIMIT   live blocks / total blocks (default 0.9)
  REPRO_BS_WASTE_LIMIT     live block area / live tokens (default 4.0)

``explain_dispatch_attention`` shares :func:`_route` with the executing
entries — the explanation cannot drift from the real routing.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import autotune, registry
from repro.kernels.backend import interpret_for, resolve_backend
from repro.kernels.blocksparse_attn.kernel import run_bs_attention_tpu
from repro.kernels.blocksparse_attn.gpu_kernel import run_bs_attention_gpu
from repro.kernels.blocksparse_attn.mask import (
    MaskSpec,
    compile_mask,
    density_limit,
    waste_limit,
)
from repro.kernels.blocksparse_attn.ref import (
    blocksparse_xla,
    masked_decode,
    masked_reference,
)


class MaskForceError(registry.KernelForceError):
    """KernelPolicy("force") demanded the block-sparse kernel but the
    MaskSpec does not compile to a tileable block plan for this shape
    (empty problem, misaligned tile, or a query row with zero visible
    tokens). Raised instead of silently serving the dense path."""


# ---------------------------------------------------------------------------
# supports predicates
# ---------------------------------------------------------------------------


def _sparse_supports(ctx: dict) -> Optional[str]:
    """Shared gate for every block-sparse lowering (plan + budgets)."""
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    plan = ctx["plan"]
    if plan is None:
        return "mask does not tile"
    if ctx.get("force"):
        return None
    limit = density_limit()
    if plan.density > limit:
        return (f"block density {plan.density:.2f} > limit {limit:.2f} "
                f"(near-dense mask)")
    wlimit = waste_limit()
    if plan.waste > wlimit:
        return f"block waste {plan.waste:.2f}x > limit {wlimit:.2f}x"
    return None


def _tpu_supports(ctx: dict) -> Optional[str]:
    why = _sparse_supports(ctx)
    if why is not None:
        return why
    if interpret_for("tpu") and not ctx.get("force"):
        return "tpu kernel would interpret on this host"
    return None


# ---------------------------------------------------------------------------
# registered implementations
# ---------------------------------------------------------------------------


@registry.register("bs_attention", "pallas_bs_attention", priority=100,
                   supports=_tpu_supports, uses_plan=True, backend="tpu")
def _run_tpu_impl(q, k, v, *, spec, plan, scale, interpret):
    return run_bs_attention_tpu(
        q, k, v, spec=spec, plan=plan, scale=scale, interpret=interpret)


@registry.register("bs_attention", "gpu_bs_attention", priority=100,
                   supports=_sparse_supports, uses_plan=True, backend="gpu")
def _run_gpu_impl(q, k, v, *, spec, plan, scale, interpret):
    return run_bs_attention_gpu(
        q, k, v, spec=spec, plan=plan, scale=scale, interpret=interpret)


@registry.register("bs_attention", "xla_bs_attention", priority=50,
                   supports=_sparse_supports, uses_plan=True, backend="any")
def _run_xla_impl(q, k, v, *, spec, plan, scale, interpret):
    return blocksparse_xla(q, k, v, spec=spec, plan=plan, scale=scale)


@registry.register("bs_attention", "masked_reference", priority=0,
                   backend="any")
def _run_ref_impl(q, k, v, *, spec, plan, scale, interpret):
    return masked_reference(q, k, v, spec=spec, scale=scale)


@registry.register("bs_attention_decode", "masked_decode", priority=0,
                   backend="any")
def _run_decode_impl(q, k, v, *, spec, length, q_positions, scale):
    return masked_decode(q, k, v, spec=spec, length=length,
                         q_positions=q_positions, scale=scale)


# ---------------------------------------------------------------------------
# routing: shape + spec + policy -> family, plan, ctx
# ---------------------------------------------------------------------------


def _route(sq, skv, dk, spec, *, decode, dtype, use_kernel, force, tile,
           backend):
    """Resolve family, tile, mask plan and dispatch ctx for one call —
    shared by the executing entries and
    :func:`explain_dispatch_attention` so they can never drift.
    ``backend`` is the resolved kernel backend (never "auto")."""
    if not isinstance(spec, MaskSpec):
        raise TypeError(
            f"mask must be a MaskSpec, got {type(spec).__name__}")
    op = "bs_attention_decode" if decode else "bs_attention"
    plan = None
    if not decode and use_kernel:
        blk = tile
        if blk is None:
            blk = autotune.best_attn_tile(sq, skv, dk, spec, dtype,
                                          backend=backend)
        plan = compile_mask(spec, sq, skv, tuple(blk))
        if plan is None and force:
            raise MaskForceError(
                f"KernelPolicy('force') on mask {spec.tag}: shape "
                f"Sq={sq} Skv={skv} does not compile to a tileable "
                f"block plan (empty problem, misaligned tile, or a "
                f"query row with zero visible tokens), and force "
                f"forbids the dense fallback")
    ctx = registry.make_ctx(
        (sq, skv, dk), nm=spec, use_kernel=use_kernel, plan=plan,
        dtype=dtype, force=force, backend=backend)
    return op, plan, ctx


def _resolve(policy, backend):
    """(use_kernel, force, tile, resolved backend) from a policy-ish."""
    mode, tile, pol_backend = "auto", None, "auto"
    if policy is not None:
        if isinstance(policy, str):
            mode = policy
        else:  # KernelPolicy duck-type
            mode = policy.mode
            tile = getattr(policy, "block", None)
            pol_backend = getattr(policy, "backend", "auto")
    if mode not in ("off", "auto", "force"):
        raise ValueError(
            f"policy mode must be 'off' | 'auto' | 'force', got {mode!r}")
    be = resolve_backend(backend if backend is not None else pol_backend)
    if tile is not None:
        tile = tuple(tile)[:2]
    return mode != "off", mode == "force", tile, be


# ---------------------------------------------------------------------------
# typed entry points
# ---------------------------------------------------------------------------


def bs_attention(q, k, v, *, spec: MaskSpec, scale=None, policy="auto",
                 backend=None, tile=None):
    """Block-sparse prefill attention: q (B, Sq, Hq, Dk) and k/v
    (B, Skv, Hkv, D*) share absolute positions from 0."""
    _check_shapes(q, k, v)
    use_kernel, force, pol_tile, be = _resolve(policy, backend)
    op, plan, ctx = _route(
        q.shape[1], k.shape[1], q.shape[-1], spec, decode=False,
        dtype=q.dtype, use_kernel=use_kernel, force=force,
        tile=tile or pol_tile, backend=be)
    return registry.dispatch(
        op, ctx, q, k, v, spec=spec, plan=plan, scale=scale,
        interpret=interpret_for(be))


def bs_attention_decode(q, k, v, *, spec: MaskSpec, length,
                        q_positions=None, scale=None, policy="auto",
                        backend=None):
    """Mask-aware decode/chunk attention against a fixed-size cache
    view; ``length`` is the valid cache extent (traced ok),
    ``q_positions`` the queries' absolute positions (chunk mode)."""
    _check_shapes(q, k, v)
    use_kernel, force, _, be = _resolve(policy, backend)
    op, plan, ctx = _route(
        q.shape[1], k.shape[1], q.shape[-1], spec, decode=True,
        dtype=q.dtype, use_kernel=use_kernel, force=force, tile=None,
        backend=be)
    return registry.dispatch(
        op, ctx, q, k, v, spec=spec, length=length,
        q_positions=q_positions, scale=scale)


def _check_shapes(q, k, v):
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError(
            f"attention expects (B, S, H, D) operands, got q{q.shape} "
            f"k{k.shape} v{v.shape}")
    if q.shape[2] % k.shape[2]:
        raise ValueError(
            f"Hq={q.shape[2]} must be a multiple of Hkv={k.shape[2]}")
    if k.shape[1] != v.shape[1] or k.shape[2] != v.shape[2]:
        raise ValueError(
            f"k/v sequence+head mismatch: k{k.shape} v{v.shape}")


def explain_dispatch_attention(q_shape, kv_shape, *, mask: MaskSpec,
                               decode: bool = False, dtype=jnp.float32,
                               policy="auto", backend=None, tile=None):
    """The :class:`repro.kernels.registry.DispatchRecord` that
    ``bs_attention`` (or the decode family, with ``decode=True``)
    *would* write for operands of these shapes — family, lowering,
    backend, tile and padded block geometry — without executing
    anything. Raises the same typed errors as the real call, including
    :class:`MaskForceError` for a forced untileable mask."""
    sq = q_shape[1] if len(q_shape) == 4 else q_shape[0]
    skv = kv_shape[1] if len(kv_shape) == 4 else kv_shape[0]
    dk = q_shape[-1]
    use_kernel, force, pol_tile, be = _resolve(policy, backend)
    op, _, ctx = _route(
        sq, skv, dk, mask, decode=decode, dtype=jnp.dtype(dtype),
        use_kernel=use_kernel, force=force, tile=tile or pol_tile,
        backend=be)
    return registry.explain(op, ctx)


def tune_for_serving(sq, skv, dk, spec: MaskSpec, dtype=jnp.float32,
                     backend: str = "tpu"):
    """Pre-pay the attention tile sweep for a serving shape (engine
    warmup) — the ``ensure_tuned`` of the bs_attn family."""
    return autotune.ensure_tuned_attn(sq, skv, dk, spec, dtype,
                                      backend=backend)
