"""Platform-neutral Pallas lowering of block-sparse prefill attention.

Mirrors the ``indexmac_gpu`` family's shape: no TPU memory spaces, no
scalar prefetch — the grid covers only the *output* tiles
(``(B*Hq, nqb)``), and each program walks its query row's live k-blocks
with an in-kernel loop over the plan's padded ``row_idx`` gather list
(dynamic ``pl.ds`` slices into the full-row k/v operands). Streaming
softmax state lives in registers across the static loop. Runs under
``interpret=True`` on any host — the CI ``gpu-interpret`` lane — and
lowers via Pallas-on-Triton on a real GPU.

Padded gather slots carry an all-False mask tile (``gather_masks``
folds ``row_valid`` in), so their scores are NEG_INF and contribute
exp(NEG_INF - m) == 0 to the running sums — duplicate index 0 reads are
harmless.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blocksparse_attn.mask import MaskPlan, gather_masks

NEG_INF = -1e30


def _bs_attn_gpu_kernel(q_ref, k_ref, v_ref, idx_ref, mask_ref, o_ref, *,
                        width, bk, scale, out_dtype):
    q = q_ref[0].astype(jnp.float32) * scale             # (bq, dk)
    bq = q.shape[0]
    dv = v_ref.shape[-1]
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, dv), jnp.float32)
    for w in range(width):
        kb_i = idx_ref[0, w]
        k_blk = k_ref[0, pl.ds(kb_i * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(kb_i * bk, bk), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        s = jnp.where(mask_ref[0, w], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, v_blk,
                                   preferred_element_type=jnp.float32)
        m = m_new
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "plan", "scale", "interpret"),
)
def _bs_attn_gpu_call(q, k, v, row_idx, masks, *, spec, plan, scale,
                      interpret):
    bhq, sqp, dk = q.shape
    bhkv = k.shape[0]
    dv = v.shape[-1]
    bq, bk = plan.bq, plan.bk
    nqb, width = plan.nqb, plan.gather_width
    g = bhq // bhkv  # == Hq // Hkv: flattening is batch-major on both
    kernel = functools.partial(
        _bs_attn_gpu_kernel, width=width, bk=bk, scale=scale,
        out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bhq, nqb * bq, dv), q.dtype),
        grid=(bhq, nqb),
        in_specs=[
            pl.BlockSpec((1, bq, dk), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, plan.nkb * bk, dk),
                         lambda bh, qi, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((1, plan.nkb * bk, dv),
                         lambda bh, qi, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((1, width), lambda bh, qi: (qi, 0)),
            pl.BlockSpec((1, width, bq, bk), lambda bh, qi: (qi, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda bh, qi: (bh, qi, 0)),
        interpret=interpret,
    )(q, k, v, row_idx, masks)


def run_bs_attention_gpu(q, k, v, *, spec, plan: MaskPlan, scale=None,
                         interpret: bool = False):
    """Flatten (batch, head), pad to the plan's tiles, run, slice back.

    Layout contract matches the reference: q (B, Sq, Hq, Dk), k/v
    (B, Skv, Hkv, D*). GQA head mapping is (b, h) -> (b, h // g) on the
    flattened axis — the flattening keeps batch-major order so the
    integer division in the index map is exact.
    """
    b, sq, hq, dk = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    if scale is None:
        scale = dk ** -0.5
    sqp = plan.nqb * plan.bq
    skvp = plan.nkb * plan.bk
    qt = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * hq, sq, dk)
    kt = jnp.transpose(k, (0, 2, 1, 3)).reshape(b * hkv, skv, dk)
    vt = jnp.transpose(v, (0, 2, 1, 3)).reshape(b * hkv, skv, dv)
    qt = jnp.pad(qt, ((0, 0), (0, sqp - sq), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, skvp - skv), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, skvp - skv), (0, 0)))
    out = _bs_attn_gpu_call(
        qt, kt, vt,
        jnp.asarray(plan.row_idx), jnp.asarray(gather_masks(plan)),
        spec=spec, plan=plan, scale=float(scale), interpret=interpret)
    out = out.reshape(b, hq, sqp, dv)[:, :, :sq]
    return jnp.transpose(out, (0, 2, 1, 3))
