"""Literal port of the paper's Algorithm 3 / vindexmac to Pallas.

C[Mr, Nc] = A_sparse[Mr, K] @ B[K, Nc], paper orientation (A sparse along
its rows). Per nonzero:  C[i, :] += vals[i, j] * B_vmem[(j//n)*m + idx, :]

Faithfulness mapping:
  * the B tile sits stationary in VMEM (BlockSpec index constant over the
    whole m sweep)                                  -> vector register file
  * vals/idx live in SMEM and are read as scalars    -> scalar register rs
  * the scalar index drives a dynamic VMEM row read  -> the vindexmac
    indirect read port
  * one scalar-vector MAC per nonzero on the VPU     -> vindexmac execute

This is deliberately *not* how one should do it on a TPU — the MXU idles
and throughput is one VPU MAC row per step. It exists to (a) demonstrate
the mechanism 1:1, (b) quantify in the roofline why the decompress->MXU
adaptation (kernels/indexmac) is the right TPU mapping (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.sparsity import NMConfig


def _gather_kernel(vals_ref, idx_ref, b_ref, o_ref, acc_ref, *, n, m, nk, bm, bkc):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(t, _):
        i = t // bkc  # row of A within the tile
        j = t % bkc   # nonzero slot within the row strip
        v = vals_ref[i, j]          # scalar read (SMEM)
        ii = idx_ref[i, j]          # scalar read (SMEM) -> "rs"
        r = (j // n) * m + jnp.int32(ii)
        b_row = b_ref[pl.dslice(r, 1), :]          # indirect VMEM read
        acc_ref[pl.dslice(i, 1), :] += v.astype(jnp.float32) * b_row.astype(
            jnp.float32
        )
        return 0

    jax.lax.fori_loop(0, bm * bkc, body, 0)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gather_q_kernel(
    vals_ref, idx_ref, scales_ref, b_ref, o_ref, acc_ref, *, n, m, nk, bm, bkc
):
    """int8-value variant of the gather port: the scalar value read from
    SMEM is an int8; it is cast in-register (the "rs" register widens)
    and the per-output-row scale multiplies the f32 accumulator once at
    writeback — one float multiply per C element, zero extra loads in
    the per-nonzero loop."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(t, _):
        i = t // bkc
        j = t % bkc
        v = vals_ref[i, j]          # scalar int8 read (SMEM)
        ii = idx_ref[i, j]          # scalar read (SMEM) -> "rs"
        r = (j // n) * m + jnp.int32(ii)
        b_row = b_ref[pl.dslice(r, 1), :]          # indirect VMEM read
        acc_ref[pl.dslice(i, 1), :] += v.astype(jnp.float32) * b_row.astype(
            jnp.float32
        )
        return 0

    jax.lax.fori_loop(0, bm * bkc, body, 0)

    @pl.when(ki == nk - 1)
    def _done():
        def scale_row(i, _):
            acc_ref[pl.dslice(i, 1), :] *= scales_ref[i, 0]
            return 0

        jax.lax.fori_loop(0, bm, scale_row, 0)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "interpret"),
)
def indexmac_gather_pallas_q(
    vals: jax.Array,   # (Mr, Kc) compressed A values, int8
    idx: jax.Array,    # (Mr, Kc) int8
    scales: jax.Array,  # (Mr,) float32, one per output row
    b: jax.Array,      # (K, Nc) dense
    *,
    cfg: NMConfig,
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 64,
    interpret: bool = False,
) -> jax.Array:
    mr, kc = vals.shape
    k, nc = b.shape
    if kc * cfg.m != k * cfg.n:
        raise ValueError("compressed width inconsistent with K and N:M")
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized gather needs int8 vals, got {vals.dtype}")
    if scales.shape != (mr,):
        raise ValueError(f"scales shape {scales.shape} != (Mr,) = ({mr},)")
    if k % block_k or block_k % cfg.m or mr % block_m or nc % block_n:
        raise ValueError("shapes not tileable")
    nk = k // block_k
    bkc = block_k * cfg.n // cfg.m
    kernel = functools.partial(
        _gather_q_kernel, n=cfg.n, m=cfg.m, nk=nk, bm=block_m, bkc=bkc
    )
    return pl.pallas_call(
        kernel,
        grid=(mr // block_m, nc // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, bkc), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, bkc), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mr, nc), b.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(vals, idx, scales.astype(jnp.float32).reshape(mr, 1), b)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "interpret"),
)
def indexmac_gather_pallas(
    vals: jax.Array,   # (Mr, Kc) compressed A values
    idx: jax.Array,    # (Mr, Kc) int8
    b: jax.Array,      # (K, Nc) dense
    *,
    cfg: NMConfig,
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 64,
    interpret: bool = False,
) -> jax.Array:
    mr, kc = vals.shape
    k, nc = b.shape
    if kc * cfg.m != k * cfg.n:
        raise ValueError("compressed width inconsistent with K and N:M")
    if k % block_k or block_k % cfg.m or mr % block_m or nc % block_n:
        raise ValueError("shapes not tileable")
    nk = k // block_k
    bkc = block_k * cfg.n // cfg.m
    kernel = functools.partial(
        _gather_kernel, n=cfg.n, m=cfg.m, nk=nk, bm=block_m, bkc=bkc
    )
    return pl.pallas_call(
        kernel,
        grid=(mr // block_m, nc // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, bkc), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, bkc), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            # stationary dense tile: index does not depend on i -> loaded
            # once per (j, k) and reused across the whole m sweep.
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mr, nc), b.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(vals, idx, b)
