"""Oracle for the gather-port kernel: paper orientation C = A_sp @ B."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig, decompress_nm


def indexmac_gather_ref(
    vals: jax.Array, idx: jax.Array, b: jax.Array, cfg: NMConfig
) -> jax.Array:
    a = decompress_nm(vals, idx, cfg, axis=1)  # (Mr, K)
    y = jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return y.astype(b.dtype)


def indexmac_gather_q_ref(
    vals: jax.Array, idx: jax.Array, scales: jax.Array, b: jax.Array,
    cfg: NMConfig
) -> jax.Array:
    """int8 oracle mirroring the quantized gather kernel's arithmetic:
    f32 dot on the exact int8 lattice, then one per-output-row scale
    multiply at the end (C[i, :] *= scales[i])."""
    a8 = decompress_nm(vals, idx, cfg, axis=1)  # (Mr, K) int8
    y = jnp.dot(
        a8.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y = y * scales.astype(jnp.float32)[:, None]
    return y.astype(b.dtype)
