from repro.kernels.indexmac_gather.ops import (  # noqa: F401
    indexmac_gather,
)
