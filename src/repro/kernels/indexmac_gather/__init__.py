from repro.kernels.indexmac_gather.ops import indexmac_gather_spmm  # noqa: F401
