"""jit'd wrappers for the literal gather-port kernel (inference-only).

``indexmac_gather(w, b)`` consumes an :class:`NMWeight` whose rows are
compressed along axis 1 (the paper's A-matrix orientation, C = A @ B);
nm and the use-kernel decision come from the weight's own metadata.
The positional (vals, idx, cfg) surface is deprecated — it lives only
in :mod:`repro.kernels.raw` and warns on use;
``indexmac_gather_positional`` is the non-warning internal for
kernel-level tests.

Routed through the kernel registry so dispatch decisions (Pallas gather
port vs. jnp reference) land in the same inspectable record stream as
`nm_matmul`. The gather port is a faithfulness artifact, not a perf
path — shapes that don't tile exactly fall back to the reference rather
than padding.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.nmweight import NMWeight
from repro.core.sparsity import NMConfig
from repro.kernels import registry
from repro.kernels.backend import interpret_for, resolve_backend
from repro.kernels.indexmac_gather.kernel import (
    indexmac_gather_pallas,
    indexmac_gather_pallas_q,
)
from repro.kernels.indexmac_gather.ref import (
    indexmac_gather_q_ref,
    indexmac_gather_ref,
)
from repro.quant.qnmweight import QNMWeight

DEFAULT_BLOCK = (8, 128, 64)


def _pallas_supports(ctx: dict) -> Optional[str]:
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    if not ctx["tileable"]:
        return "shape not tileable (gather port does not pad)"
    return None


@registry.register("indexmac_gather", "pallas_gather", priority=100,
                   supports=_pallas_supports, backend="tpu")
def _run_pallas(vals, idx, b, *, cfg, block):
    bm, bn, bk = block
    return indexmac_gather_pallas(
        vals, idx, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret_for("tpu"),
    )


@registry.register("indexmac_gather", "reference", priority=0)
def _run_ref(vals, idx, b, *, cfg, block):
    return indexmac_gather_ref(vals, idx, b, cfg)


@registry.register("indexmac_gather_q", "pallas_gather_q", priority=100,
                   supports=_pallas_supports, backend="tpu")
def _run_pallas_q(vals, idx, scales, b, *, cfg, block):
    bm, bn, bk = block
    return indexmac_gather_pallas_q(
        vals, idx, scales, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret_for("tpu"),
    )


@registry.register("indexmac_gather_q", "reference_q", priority=0)
def _run_ref_q(vals, idx, scales, b, *, cfg, block):
    return indexmac_gather_q_ref(vals, idx, scales, b, cfg)


def _tileable(mr: int, k: int, nc: int, cfg: NMConfig,
              block: tuple[int, int, int]) -> bool:
    bm, bn, bk = block
    return mr % bm == 0 and nc % bn == 0 and k % bk == 0 and bk % cfg.m == 0


def indexmac_gather(
    w,
    b: jax.Array,
    *,
    block: Optional[tuple[int, int, int]] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """C = densify(w) @ b for a row-compressed A (w.axis == 1).

    Accepts an :class:`NMWeight` or an int8 :class:`QNMWeight`; the
    quantized type routes to the dequantizing gather variant (its own
    ``indexmac_gather_q`` dispatch family). ``backend`` overrides the
    weight policy's kernel backend (see :mod:`repro.kernels.backend`)."""
    if not isinstance(w, (NMWeight, QNMWeight)):
        raise TypeError(
            f"indexmac_gather expects an NMWeight or QNMWeight, got "
            f"{type(w).__name__}"
        )
    if w.axis != 1:
        raise ValueError(
            "the gather port consumes the paper's A-orientation: rows "
            f"compressed along axis 1; got axis={w.axis}"
        )
    block = block or w.kernel_policy.block or DEFAULT_BLOCK
    mr, _ = w.vals.shape
    k, nc = b.shape
    be = resolve_backend(
        backend if backend is not None
        else getattr(w.kernel_policy, "backend", "auto"))
    ctx = registry.weight_ctx(
        w, (mr, k, nc),
        dtype=b.dtype, tileable=_tileable(mr, k, nc, w.nm, block),
        backend=be,
    )
    if isinstance(w, QNMWeight):
        return registry.dispatch(
            "indexmac_gather_q", ctx, w.vals, w.idx, w.scales, b,
            cfg=w.nm, block=block
        )
    return registry.dispatch(
        "indexmac_gather", ctx, w.vals, w.idx, b, cfg=w.nm, block=block
    )


def explain_gather(b_shape, w, *, backend=None) -> registry.DispatchRecord:
    """Dry-run routing for the gather-port families: the record
    ``indexmac_gather(w, b)`` would produce for a dense B operand of
    shape ``b_shape`` (the ``w.axis == 1`` arm of
    ``repro.api.explain_dispatch``)."""
    if w.axis != 1:
        raise ValueError(
            "the gather port consumes the paper's A-orientation: rows "
            f"compressed along axis 1; got axis={w.axis}"
        )
    block = w.kernel_policy.block or DEFAULT_BLOCK
    mr = w.vals.shape[0]
    k, nc = b_shape
    be = resolve_backend(
        backend if backend is not None
        else getattr(w.kernel_policy, "backend", "auto"))
    ctx = registry.weight_ctx(
        w, (mr, k, nc), tileable=_tileable(mr, k, nc, w.nm, block),
        backend=be,
    )
    op = ("indexmac_gather_q" if isinstance(w, QNMWeight)
          else "indexmac_gather")
    return registry.explain(op, ctx)


def indexmac_gather_positional(
    vals: jax.Array,
    idx: jax.Array,
    b: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
) -> jax.Array:
    """Positional surface (kernel-level tests / the deprecated
    wrapper in :mod:`repro.kernels.raw`)."""
    mr, kc = vals.shape
    k, nc = b.shape
    ctx = registry.make_ctx(
        (mr, k, nc), nm=cfg, use_kernel=use_kernel, dtype=b.dtype,
        tileable=_tileable(mr, k, nc, cfg, block),
    )
    return registry.dispatch(
        "indexmac_gather", ctx, vals, idx, b, cfg=cfg, block=block
    )

