"""jit'd wrapper for the literal gather-port kernel (inference-only)."""
from __future__ import annotations

import jax

from repro.core.sparsity import NMConfig
from repro.kernels.indexmac_gather.kernel import indexmac_gather_pallas
from repro.kernels.indexmac_gather.ref import indexmac_gather_ref


def indexmac_gather_spmm(
    vals: jax.Array,
    idx: jax.Array,
    b: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: tuple[int, int, int] = (8, 128, 64),
) -> jax.Array:
    bm, bn, bk = block
    mr, kc = vals.shape
    k, nc = b.shape
    tileable = mr % bm == 0 and nc % bn == 0 and k % bk == 0 and bk % cfg.m == 0
    if use_kernel and tileable:
        return indexmac_gather_pallas(
            vals, idx, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
            interpret=jax.default_backend() == "cpu",
        )
    return indexmac_gather_ref(vals, idx, b, cfg)
