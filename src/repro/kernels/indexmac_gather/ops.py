"""jit'd wrapper for the literal gather-port kernel (inference-only).

Routed through the kernel registry so dispatch decisions (Pallas gather
port vs. jnp reference) land in the same inspectable record stream as
`nm_matmul`. The gather port is a faithfulness artifact, not a perf
path — shapes that don't tile exactly fall back to the reference rather
than padding.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.sparsity import NMConfig
from repro.kernels import registry
from repro.kernels.indexmac_gather.kernel import indexmac_gather_pallas
from repro.kernels.indexmac_gather.ref import indexmac_gather_ref


def _pallas_supports(ctx: dict) -> Optional[str]:
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    if not ctx["tileable"]:
        return "shape not tileable (gather port does not pad)"
    return None


@registry.register("indexmac_gather", "pallas_gather", priority=100,
                   supports=_pallas_supports)
def _run_pallas(vals, idx, b, *, cfg, block):
    bm, bn, bk = block
    return indexmac_gather_pallas(
        vals, idx, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=jax.default_backend() == "cpu",
    )


@registry.register("indexmac_gather", "reference", priority=0)
def _run_ref(vals, idx, b, *, cfg, block):
    return indexmac_gather_ref(vals, idx, b, cfg)


def indexmac_gather_spmm(
    vals: jax.Array,
    idx: jax.Array,
    b: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: tuple[int, int, int] = (8, 128, 64),
) -> jax.Array:
    bm, bn, bk = block
    mr, kc = vals.shape
    k, nc = b.shape
    tileable = mr % bm == 0 and nc % bn == 0 and k % bk == 0 and bk % cfg.m == 0
    ctx = {
        "shape": (mr, k, nc),
        "plan": None,
        "use_kernel": use_kernel,
        "tileable": tileable,
        "cfg": cfg,
        "dtype": b.dtype,
    }
    return registry.dispatch(
        "indexmac_gather", ctx, vals, idx, b, cfg=cfg, block=block
    )
