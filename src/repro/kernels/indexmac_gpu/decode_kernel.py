"""Skinny-M Pallas GPU kernels for decode-shaped N:M sparse GEMMs.

The GPU mirror of :mod:`repro.kernels.indexmac.decode_kernel`: same
masked-dot dataflow (the activation rows are the indexed operand — for
each in-block offset pair (s, j) the strided x slice ``x[:, j::m]``
contracts against ``where(idx[s::n] == j, vals[s::n], 0)``, m-fold less
MAC work than dense expansion), same fused epilogue contract
(``activation(acc [* scales] + bias)`` on the f32 accumulator, see
:mod:`repro.kernels.epilogue`), different grid shape:

* grid is ``(N/bn,)`` — one program instance per output column strip.
  There is no sequential grid dimension on Triton, so the K reduction
  is an in-kernel loop and the accumulator lives in registers rather
  than VMEM scratch.
* the whole skinny x (bm <= 8 rows, full K) is block-resident in every
  instance — the stationary operand, same as the TPU kernel's pinned
  VMEM block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparsity import NMConfig
from repro.kernels.epilogue import ACTIVATIONS


def _decode_partial(x, v, ii, n: int, m: int):
    """Sum of per-(s, j) offset dots — identical math to the TPU
    decode kernel's partial; no densified W intermediate."""
    bm = x.shape[0]
    bn = v.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for s in range(n):
        v_s = v[s::n, :].astype(jnp.float32)  # (bk/m, bn)
        i_s = ii[s::n, :].astype(jnp.int32)
        for j in range(m):
            xj = x[:, j::m]  # (bm, bk/m)
            w_sj = jnp.where(i_s == j, v_s, 0.0)
            acc += jax.lax.dot(xj, w_sj, preferred_element_type=jnp.float32)
    return acc


def _decode_gpu_kernel(x_ref, vals_ref, idx_ref, *rest, n, m, nk, block_k,
                       out_dtype, activation, quantized, has_bias):
    refs = list(rest)
    scales_ref = refs.pop(0) if quantized else None
    bias_ref = refs.pop(0) if has_bias else None
    (o_ref,) = refs
    bkc = block_k * n // m
    bm = x_ref.shape[0]
    bn = vals_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for k in range(nk):
        xk = x_ref[:, k * block_k:(k + 1) * block_k].astype(jnp.float32)
        acc += _decode_partial(
            xk,
            vals_ref[k * bkc:(k + 1) * bkc, :],
            idx_ref[k * bkc:(k + 1) * bkc, :], n, m)
    y = acc
    if scales_ref is not None:
        y = y * scales_ref[...]
    if bias_ref is not None:
        y = y + bias_ref[...]
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    o_ref[...] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_n", "block_k", "activation", "out_dtype",
                     "interpret"),
)
def nm_spmm_gpu_decode(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    cfg: NMConfig,
    block_n: int = 128,
    block_k: int = 512,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = epilogue(x @ decompress(vals, idx)) for skinny x, GPU lowering.

    Shape requirements (enforced): M a sublane multiple (the op layer
    pads 1..8 rows up to 8 — kept for layout parity with the TPU
    family), N % block_n == 0, K % block_k == 0, block_k % m == 0;
    ``bias`` is (N,) when given.
    """
    return _gpu_decode(x, vals, idx, None, bias, cfg=cfg,
                       block_n=block_n, block_k=block_k,
                       activation=activation, out_dtype=out_dtype,
                       interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_n", "block_k", "activation", "out_dtype",
                     "interpret"),
)
def nm_spmm_gpu_decode_q(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    cfg: NMConfig,
    block_n: int = 128,
    block_k: int = 512,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """int8 decode sibling on GPU: per-output-channel ``scales`` multiply
    the f32 accumulator before the bias/activation epilogue — the same
    one-launch composition contract as the TPU family."""
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized kernel needs int8 vals, got {vals.dtype}")
    if scales.shape != (vals.shape[1],):
        raise ValueError(
            f"scales shape {scales.shape} != (N,) = ({vals.shape[1]},)")
    return _gpu_decode(x, vals, idx, scales, bias, cfg=cfg,
                       block_n=block_n, block_k=block_k,
                       activation=activation, out_dtype=out_dtype,
                       interpret=interpret)


def _gpu_decode(x, vals, idx, scales, bias, *, cfg, block_n, block_k,
                activation, out_dtype, interpret):
    mm, kk = x.shape
    kc, nn = vals.shape
    if kc * cfg.m != kk * cfg.n:
        raise ValueError(
            f"vals rows {kc} inconsistent with K={kk} and {cfg.tag}")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    if mm % 8:
        raise ValueError(f"decode kernel needs M a sublane multiple, got {mm}")
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    block_k = min(block_k, kk)
    block_n = min(block_n, nn)
    if kk % block_k or block_k % cfg.m:
        raise ValueError(f"K={kk} block_k={block_k} m={cfg.m} not tileable")
    if nn % block_n:
        raise ValueError(f"N={nn} not divisible by block_n={block_n}")
    if bias is not None and bias.shape != (nn,):
        raise ValueError(f"bias shape {bias.shape} != (N,) = ({nn},)")
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k

    quantized = scales is not None
    has_bias = bias is not None
    # one program instance per output column strip; x and the full
    # compressed column strip are block-resident, K loops in-kernel.
    in_specs = [
        pl.BlockSpec((mm, kk), lambda j: (0, 0)),
        pl.BlockSpec((kc, block_n), lambda j: (0, j)),
        pl.BlockSpec((kc, block_n), lambda j: (0, j)),
    ]
    operands = [x, vals, idx]
    if quantized:
        in_specs.append(pl.BlockSpec((1, block_n), lambda j: (0, j)))
        operands.append(scales.astype(jnp.float32).reshape(1, nn))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, block_n), lambda j: (0, j)))
        operands.append(bias.astype(jnp.float32).reshape(1, nn))

    kernel = functools.partial(
        _decode_gpu_kernel, n=cfg.n, m=cfg.m, nk=nk, block_k=block_k,
        out_dtype=out_dtype, activation=activation, quantized=quantized,
        has_bias=has_bias,
    )
    return pl.pallas_call(
        kernel,
        grid=(nn // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((mm, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        interpret=interpret,
    )(*operands)
