"""Pallas-on-Triton lowering of the indexmac kernel families.

The GPU mirror of :mod:`repro.kernels.indexmac` /
:mod:`repro.kernels.indexmac_gather`: same dispatch families, same
logical contract (bit-exact vs the references on the integer lattice),
registered in the kernel registry under ``backend="gpu"`` — see
:mod:`repro.kernels.backend` for how a call selects a backend.

Structure:
  kernel.py        — nm_spmm_gpu / nm_spmm_gpu_q (prefill-shaped)
  decode_kernel.py — nm_spmm_gpu_decode / _q (skinny-M, fused epilogue)
  gather_kernel.py — indexmac_gather_gpu / _q (paper A-orientation)
  ops.py           — registry registrations + pad/slice wrappers
"""
from repro.kernels.indexmac_gpu.decode_kernel import (  # noqa: F401
    nm_spmm_gpu_decode,
    nm_spmm_gpu_decode_q,
)
from repro.kernels.indexmac_gpu.gather_kernel import (  # noqa: F401
    indexmac_gather_gpu,
    indexmac_gather_gpu_q,
)
from repro.kernels.indexmac_gpu.kernel import (  # noqa: F401
    nm_spmm_gpu,
    nm_spmm_gpu_q,
)
