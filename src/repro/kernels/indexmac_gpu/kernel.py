"""Pallas GPU (Triton-lowered) kernel for N:M structured-sparse matmul.

Computes  y[M, N] = x[M, K] @ W  with W stored compressed along K:
  vals[Kc, N] (x dtype or int8), idx[Kc, N] (int8 in [0, m)),
  Kc = K * n / m — the same operand contract as the TPU family
  (:mod:`repro.kernels.indexmac.kernel`), different dataflow:

* The grid covers **output tiles only** — ``(M/bm, N/bn)``. On Triton
  every grid step is an independent program instance (there is no
  sequential grid dimension to carry a scratch accumulator across, the
  way the TPU kernel's ``(mi, ni, ki)`` grid does), so the K reduction
  is an in-kernel loop over ``block_k`` chunks with the accumulator held
  in registers.
* The compressed tile is expanded in-register to a dense ``(bk, bn)``
  chunk with broadcast-compare selects (no HBM gather — the bounded
  ``idx`` compare is the vindexmac analogue, same as on TPU) and handed
  to the tensor cores via ``jnp.dot``.
* No TPU memory spaces, no VMEM scratch, no Mosaic compiler params —
  the kernel body is platform-neutral Pallas, which is exactly what
  lets the CI ``gpu-interpret`` lane execute it on the interpreter.

Accumulation is f32; the int8 variant applies per-output-column scales
once at writeback, so results are bit-exact vs the reference on the
integer lattice regardless of tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparsity import NMConfig


def _decompress_chunk(v, ii, n: int, m: int):
    """Expand a compressed (bkc, bn) chunk to dense (bk, bn), bk = bkc*m/n.

    Dense row d takes contributions from compressed rows (d//m)*n + s,
    s in [0, n): w[d, c] = sum_s v[(d//m)*n+s, c] * (idx[...]==d%m).
    Uses broadcast_to + reshape instead of jnp.repeat so the expansion
    lowers as a pure layout op on Triton.
    """
    bkc, bn = v.shape
    bk = bkc * m // n
    jpos = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % m
    w = jnp.zeros((bk, bn), dtype=jnp.float32)
    for s in range(n):
        v_s = v[s::n, :].astype(jnp.float32)     # (bk/m, bn)
        i_s = ii[s::n, :].astype(jnp.int32)
        v_rep = jnp.broadcast_to(
            v_s[:, None, :], (bk // m, m, bn)).reshape(bk, bn)
        i_rep = jnp.broadcast_to(
            i_s[:, None, :], (bk // m, m, bn)).reshape(bk, bn)
        w = w + jnp.where(i_rep == jpos, v_rep, 0.0)
    return w


def _nm_spmm_gpu_kernel(x_ref, vals_ref, idx_ref, o_ref, *, n, m, nk,
                        block_k, out_dtype):
    """One (bm, bn) output tile: in-kernel K loop, register accumulator."""
    bkc = block_k * n // m
    bm = x_ref.shape[0]
    bn = vals_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for k in range(nk):
        xk = x_ref[:, k * block_k:(k + 1) * block_k].astype(jnp.float32)
        w = _decompress_chunk(
            vals_ref[k * bkc:(k + 1) * bkc, :],
            idx_ref[k * bkc:(k + 1) * bkc, :], n, m)
        acc += jnp.dot(xk, w, preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(out_dtype)


def _nm_spmm_gpu_q_kernel(x_ref, vals_ref, idx_ref, scales_ref, o_ref, *,
                          n, m, nk, block_k, out_dtype):
    """int8-value variant: the compressed chunk expands straight from
    int8 to f32 in-register (exact — |q| <= 127 << 2^24) and the
    per-output-column scales multiply the f32 accumulator once at
    writeback, so the reduction loop never touches a float weight."""
    bkc = block_k * n // m
    bm = x_ref.shape[0]
    bn = vals_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for k in range(nk):
        xk = x_ref[:, k * block_k:(k + 1) * block_k].astype(jnp.float32)
        w = _decompress_chunk(
            vals_ref[k * bkc:(k + 1) * bkc, :],
            idx_ref[k * bkc:(k + 1) * bkc, :], n, m)
        acc += jnp.dot(xk, w, preferred_element_type=jnp.float32)
    o_ref[...] = (acc * scales_ref[...]).astype(out_dtype)


def _check_pair(x, vals, idx, cfg):
    mm, kk = x.shape
    kc, nn = vals.shape
    if kc * cfg.m != kk * cfg.n:
        raise ValueError(
            f"vals rows {kc} inconsistent with K={kk} and {cfg.tag}")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    return mm, kk, nn


def _check_blocks(mm, nn, kk, cfg, block_m, block_n, block_k):
    block_m = min(block_m, mm)
    block_n = min(block_n, nn)
    block_k = min(block_k, kk)
    if kk % block_k or block_k % cfg.m:
        raise ValueError(f"K={kk} block_k={block_k} m={cfg.m} not tileable")
    if mm % block_m or nn % block_n:
        raise ValueError(
            f"M={mm}/N={nn} not divisible by blocks {block_m}/{block_n}")
    return block_m, block_n, block_k


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"),
)
def nm_spmm_gpu(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    cfg: NMConfig,
    block_m: int = 64,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ decompress(vals, idx), Pallas GPU lowering.

    Shape requirements (enforced): M % block_m == 0, N % block_n == 0,
    K % block_k == 0 (blocks clamped to the problem), block_k % m == 0.
    """
    mm, kk, nn = _check_pair(x, vals, idx, cfg)
    block_m, block_n, block_k = _check_blocks(
        mm, nn, kk, cfg, block_m, block_n, block_k)
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k
    kc = kk * cfg.n // cfg.m

    kernel = functools.partial(
        _nm_spmm_gpu_kernel, n=cfg.n, m=cfg.m, nk=nk, block_k=block_k,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(mm // block_m, nn // block_n),
        in_specs=[
            # full-K row strip / full-Kc column strip: the K reduction is
            # the in-kernel loop, not a grid dimension.
            pl.BlockSpec((block_m, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((kc, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((kc, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        interpret=interpret,
    )(x, vals, idx)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"),
)
def nm_spmm_gpu_q(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    *,
    cfg: NMConfig,
    block_m: int = 64,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = (x @ decompress(int8 vals, idx)) * scales[col], GPU lowering.

    Same tiling contract as :func:`nm_spmm_gpu`; additionally ``vals``
    must be int8 and ``scales`` float32 of shape (N,).
    """
    mm, kk, nn = _check_pair(x, vals, idx, cfg)
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized kernel needs int8 vals, got {vals.dtype}")
    if scales.shape != (nn,):
        raise ValueError(f"scales shape {scales.shape} != (N,) = ({nn},)")
    block_m, block_n, block_k = _check_blocks(
        mm, nn, kk, cfg, block_m, block_n, block_k)
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k
    kc = kk * cfg.n // cfg.m

    kernel = functools.partial(
        _nm_spmm_gpu_q_kernel, n=cfg.n, m=cfg.m, nk=nk, block_k=block_k,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(mm // block_m, nn // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((kc, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((kc, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        interpret=interpret,
    )(x, vals, idx, scales.astype(jnp.float32).reshape(1, nn))
