"""Registry registrations for the GPU lowering of the indexmac families.

Importing this module (done by :mod:`repro.kernels` at package import)
registers the Pallas-on-Triton implementations under ``backend="gpu"``
in the SAME dispatch families as the TPU lowering — ``nm_matmul``,
``nm_matmul_q``, ``nm_matmul_decode``, ``nm_matmul_decode_q``,
``indexmac_gather``, ``indexmac_gather_q`` — with impl names prefixed
``pallas_gpu``. The registry's backend filter (see
:mod:`repro.kernels.registry`) picks the lowering; everything else
(family routing by M, pad plans, waste limits, epilogue composition,
autotune block lookup) is shared with the TPU path byte for byte:

* the routing predicates are literally the TPU module's
  ``_pallas_supports`` / ``_decode_supports`` — a shape that kernels on
  TPU kernels on GPU, and the fallback reasons read identically;
* the pad/slice wrappers reuse :class:`repro.kernels.padding.PadPlan`
  (its sublane/lane granularity is TPU-motivated but GPU-legal, and
  keeping one geometry means one autotune cache schema and bit-exact
  parity fixtures across backends).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig
from repro.kernels import registry
from repro.kernels.backend import interpret_for
from repro.kernels.indexmac.ops import _decode_supports, _pallas_supports
from repro.kernels.indexmac_gather.ops import (
    _pallas_supports as _gather_supports,
)
from repro.kernels.indexmac_gpu.decode_kernel import (
    nm_spmm_gpu_decode,
    nm_spmm_gpu_decode_q,
)
from repro.kernels.indexmac_gpu.gather_kernel import (
    indexmac_gather_gpu,
    indexmac_gather_gpu_q,
)
from repro.kernels.indexmac_gpu.kernel import nm_spmm_gpu, nm_spmm_gpu_q
from repro.kernels.padding import PadPlan, pad_nm_operands


# ---------------------------------------------------------------------------
# prefill-shaped family
# ---------------------------------------------------------------------------


def run_gpu_padded(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Pad operands to the plan, run the GPU kernel, slice the logical
    output — the GPU twin of ``indexmac.ops.run_pallas_padded``."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    bm, bn, bk = plan.block
    y = nm_spmm_gpu(
        xp, vp, ip, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul", "pallas_gpu", priority=100,
                   supports=_pallas_supports, uses_plan=True,
                   backend="gpu")
def _run_gpu_impl(x2, vals, idx, *, cfg, plan, interpret):
    return run_gpu_padded(
        x2, vals, idx, cfg=cfg, plan=plan, interpret=interpret
    )


def run_gpu_padded_q(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Quantized sibling: appended columns get unit scales (sliced away)."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    sp = scales
    if plan.pn > plan.n:
        sp = jnp.pad(scales, (0, plan.pn - plan.n), constant_values=1.0)
    bm, bn, bk = plan.block
    y = nm_spmm_gpu_q(
        xp, vp, ip, sp, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_q", "pallas_gpu_q", priority=100,
                   supports=_pallas_supports, uses_plan=True,
                   backend="gpu")
def _run_gpu_q_impl(x2, vals, idx, scales, *, cfg, plan, interpret):
    return run_gpu_padded_q(
        x2, vals, idx, scales, cfg=cfg, plan=plan, interpret=interpret
    )


# ---------------------------------------------------------------------------
# decode-shaped families (fused epilogue)
# ---------------------------------------------------------------------------


def run_gpu_decode(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    bias: Optional[jax.Array],
    *,
    cfg: NMConfig,
    plan: PadPlan,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    """Pad to the plan and run the fused GPU decode kernel. Padded bias
    columns are zero and every epilogue activation fixes 0, so the
    slice-back stays exact (same argument as the TPU wrapper)."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    bp = bias
    if bias is not None and plan.pn > plan.n:
        bp = jnp.pad(bias, (0, plan.pn - plan.n))
    _, bn, bk = plan.block
    y = nm_spmm_gpu_decode(
        xp, vp, ip, bp, cfg=cfg, block_n=bn, block_k=bk,
        activation=activation, interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_decode", "pallas_gpu_decode", priority=100,
                   supports=_decode_supports, uses_plan=True,
                   backend="gpu")
def _run_gpu_decode_impl(x2, vals, idx, bias, *, cfg, plan, activation,
                         interpret):
    return run_gpu_decode(
        x2, vals, idx, bias, cfg=cfg, plan=plan, activation=activation,
        interpret=interpret,
    )


def run_gpu_decode_q(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    bias: Optional[jax.Array],
    *,
    cfg: NMConfig,
    plan: PadPlan,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    """int8 decode sibling: padded columns get unit scales + zero bias."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    sp, bp = scales, bias
    if plan.pn > plan.n:
        sp = jnp.pad(scales, (0, plan.pn - plan.n), constant_values=1.0)
        if bias is not None:
            bp = jnp.pad(bias, (0, plan.pn - plan.n))
    _, bn, bk = plan.block
    y = nm_spmm_gpu_decode_q(
        xp, vp, ip, sp, bp, cfg=cfg, block_n=bn, block_k=bk,
        activation=activation, interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_decode_q", "pallas_gpu_decode_q", priority=100,
                   supports=_decode_supports, uses_plan=True,
                   backend="gpu")
def _run_gpu_decode_q_impl(x2, vals, idx, scales, bias, *, cfg, plan,
                           activation, interpret):
    return run_gpu_decode_q(
        x2, vals, idx, scales, bias, cfg=cfg, plan=plan,
        activation=activation, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# gather-port families (no padding, same as the TPU port)
# ---------------------------------------------------------------------------


@registry.register("indexmac_gather", "pallas_gpu_gather", priority=100,
                   supports=_gather_supports, backend="gpu")
def _run_gpu_gather(vals, idx, b, *, cfg, block):
    bm, bn, bk = block
    return indexmac_gather_gpu(
        vals, idx, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret_for("gpu"),
    )


@registry.register("indexmac_gather_q", "pallas_gpu_gather_q", priority=100,
                   supports=_gather_supports, backend="gpu")
def _run_gpu_gather_q(vals, idx, scales, b, *, cfg, block):
    bm, bn, bk = block
    return indexmac_gather_gpu_q(
        vals, idx, scales, b, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret_for("gpu"),
    )
