"""Pallas GPU lowering of the gather-port family (paper A-orientation).

C[Mr, Nc] = A_sparse[Mr, K] @ B[K, Nc] with A compressed along its rows:
``vals``/``idx`` are (Mr, Kc), Kc = K * n / m, and compressed column c
addresses dense B row ``(c // n) * m + idx``.

The TPU port (:mod:`repro.kernels.indexmac_gather.kernel`) is a literal
scalar-loop rendition of the paper's vindexmac — SMEM scalar reads
driving indirect VMEM row reads, one MAC per nonzero. That dataflow has
no GPU analogue worth writing (a warp per scalar read is the fully
divergent worst case), so this lowering keeps the *semantics* and swaps
the mechanism for the masked-dot identity, transposed to the sparse-A
orientation: for every in-block offset pair (s, j)

    C += where(idx[:, s::n] == j, vals[:, s::n], 0) @ B[j::m, :]

an (bm, bk/m) x (bk/m, bn) tensor-core dot per pair — the bounded
``idx`` compare is still the vindexmac analogue (a local select, never
an HBM gather), and summed over the n*m pairs this is exactly A @ B.

Grid is ``(Mr/bm, Nc/bn)`` output tiles (all-parallel program
instances); the K reduction is an in-kernel loop over ``block_k``
chunks with a register accumulator. Accumulation is f32; the int8
variant applies per-output-row scales once at writeback, so on the
integer lattice the result is bit-exact vs the reference composition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparsity import NMConfig


def _gather_partial(v, ii, b, n: int, m: int):
    """Sum of per-(s, j) offset dots for one K chunk: compressed
    (bm, bkc) strip of A against the dense (bk, bn) B chunk."""
    bm = v.shape[0]
    bn = b.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for s in range(n):
        v_s = v[:, s::n].astype(jnp.float32)  # (bm, bk/m)
        i_s = ii[:, s::n].astype(jnp.int32)
        for j in range(m):
            a_sj = jnp.where(i_s == j, v_s, 0.0)
            b_j = b[j::m, :].astype(jnp.float32)  # (bk/m, bn)
            acc += jax.lax.dot(a_sj, b_j, preferred_element_type=jnp.float32)
    return acc


def _gather_gpu_kernel(vals_ref, idx_ref, b_ref, o_ref, *, n, m, nk,
                       block_k, out_dtype):
    bkc = block_k * n // m
    bm = vals_ref.shape[0]
    bn = b_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for k in range(nk):
        acc += _gather_partial(
            vals_ref[:, k * bkc:(k + 1) * bkc],
            idx_ref[:, k * bkc:(k + 1) * bkc],
            b_ref[k * block_k:(k + 1) * block_k, :], n, m)
    o_ref[...] = acc.astype(out_dtype)


def _gather_gpu_q_kernel(vals_ref, idx_ref, scales_ref, b_ref, o_ref, *,
                         n, m, nk, block_k, out_dtype):
    bkc = block_k * n // m
    bm = vals_ref.shape[0]
    bn = b_ref.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for k in range(nk):
        acc += _gather_partial(
            vals_ref[:, k * bkc:(k + 1) * bkc],
            idx_ref[:, k * bkc:(k + 1) * bkc],
            b_ref[k * block_k:(k + 1) * block_k, :], n, m)
    o_ref[...] = (acc * scales_ref[...]).astype(out_dtype)


def _check_gather(vals, idx, b, cfg, block_m, block_n, block_k):
    mr, kc = vals.shape
    k, nc = b.shape
    if kc * cfg.m != k * cfg.n:
        raise ValueError("compressed width inconsistent with K and N:M")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    if k % block_k or block_k % cfg.m or mr % block_m or nc % block_n:
        raise ValueError("shapes not tileable")
    return mr, k, nc


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "interpret"),
)
def indexmac_gather_gpu(
    vals: jax.Array,   # (Mr, Kc) compressed A values
    idx: jax.Array,    # (Mr, Kc) int8
    b: jax.Array,      # (K, Nc) dense
    *,
    cfg: NMConfig,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    block_k = min(block_k, b.shape[0])
    mr, k, nc = _check_gather(vals, idx, b, cfg, block_m, block_n, block_k)
    nk = k // block_k
    kc = k * cfg.n // cfg.m
    kernel = functools.partial(
        _gather_gpu_kernel, n=cfg.n, m=cfg.m, nk=nk, block_k=block_k,
        out_dtype=b.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(mr // block_m, nc // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kc), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, kc), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mr, nc), b.dtype),
        interpret=interpret,
    )(vals, idx, b)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "interpret"),
)
def indexmac_gather_gpu_q(
    vals: jax.Array,   # (Mr, Kc) compressed A values, int8
    idx: jax.Array,    # (Mr, Kc) int8
    scales: jax.Array,  # (Mr,) float32, one per output row
    b: jax.Array,      # (K, Nc) dense
    *,
    cfg: NMConfig,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized gather needs int8 vals, got {vals.dtype}")
    block_k = min(block_k, b.shape[0])
    mr, k, nc = _check_gather(vals, idx, b, cfg, block_m, block_n, block_k)
    if scales.shape != (mr,):
        raise ValueError(f"scales shape {scales.shape} != (Mr,) = ({mr},)")
    nk = k // block_k
    kc = k * cfg.n // cfg.m
    kernel = functools.partial(
        _gather_gpu_q_kernel, n=cfg.n, m=cfg.m, nk=nk, block_k=block_k,
        out_dtype=b.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(mr // block_m, nc // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kc), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, kc), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mr, nc), b.dtype),
        interpret=interpret,
    )(vals, idx, scales.astype(jnp.float32).reshape(mr, 1), b)
