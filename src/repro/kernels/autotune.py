"""Block-size autotuner for the N:M Pallas kernel, with a persistent cache.

The follow-up paper (arXiv 2501.10189) shows the speedup of structured-
sparse matmul hinges on picking the right tiling per layer shape — one
fixed block triple leaves decode-shaped GEMMs memory-starved and
prefill-shaped ones pipeline-stalled. This module sweeps candidate
``(block_m, block_n, block_k)`` triples per problem key and remembers the
winner on disk so the sweep is paid once per shape per machine.

Cache
-----
JSON at ``$REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro/autotune.json``), one entry per key::

    {"v2|platform|kernel_backend|dtype|n:m|MxKxN": [bm, bn, bk], ...}

The key carries two distinct backend tokens: ``platform`` is the
*device* (``jax.default_backend()`` — an interpret-mode sweep on CPU
must never shadow a compiled sweep), ``kernel_backend`` is the *kernel
family* (``tpu``/``gpu`` — the GPU lowering sweeps different grids and
gets its own winners even when both families run on one host). Legacy
``v1`` keys (no kernel-backend token — written before the backend axis
existed, always the TPU family) migrate in place on load, so checked-in
CI caches keep their entries without a re-sweep.

Lookup policy in the hot path (``nm_matmul`` with ``block=None``):
cache hit wins; on a miss the default triple is used unless
``REPRO_AUTOTUNE=1``, in which case the sweep runs inline and the result
is persisted. The serving engine and the roofline benchmark call
:func:`ensure_tuned` explicitly to pre-pay sweeps for their shapes.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.core.sparsity import NMConfig
from repro.kernels.backend import interpret_for
from repro.kernels.padding import plan_nm_matmul

DEFAULT_BLOCK = (256, 256, 2048)
# decode family: M is one sublane by construction, K blocks are kept
# small enough that a single k step covers typical reduced projections.
DEFAULT_DECODE_BLOCK = (8, 256, 1024)
# GPU family: output tiles sized for Triton program instances (the K
# reduction is in-kernel, so block_k only bounds the chunk loop, not a
# grid dimension); smaller than the TPU MXU-sweep tiles by design.
DEFAULT_GPU_BLOCK = (64, 128, 512)
DEFAULT_GPU_DECODE_BLOCK = (8, 128, 512)
_CACHE_VERSION = "v2"
_LEGACY_VERSION = "v1"  # pre-backend-axis keys: always the tpu family

_LOCK = threading.Lock()
_MEM: dict[str, tuple] = {}
_LOADED_FROM: Optional[str] = None


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json"),
    )


def _key(m: int, n: int, k: int, cfg: NMConfig, dtype, platform: str,
         backend: str = "tpu", family: str = "") -> str:
    """Cache key; ``platform`` is the device, ``backend`` the kernel
    family (see module docstring). ``family`` distinguishes kernel
    families that sweep different grids over the same problem (the
    decode family gets a ``|decode`` suffix)."""
    base = (f"{_CACHE_VERSION}|{platform}|{backend}|"
            f"{jnp.dtype(dtype).name}|{cfg.tag}|{m}x{k}x{n}")
    return f"{base}|{family}" if family else base


def _migrate_key(key: str) -> str:
    """Map a legacy v1 key (no kernel-backend token) onto the v2 schema.

    Everything written under v1 was the TPU kernel family — the only one
    that existed — so ``v1|plat|rest`` becomes ``v2|plat|tpu|rest``.
    Non-v1 keys pass through unchanged."""
    if not key.startswith(f"{_LEGACY_VERSION}|"):
        return key
    parts = key.split("|")
    if len(parts) < 5:
        return key  # malformed: keep as-is, it simply never matches
    return "|".join([_CACHE_VERSION, parts[1], "tpu"] + parts[2:])


def _load_locked() -> None:
    global _LOADED_FROM
    path = cache_path()
    if _LOADED_FROM == path:
        return
    _MEM.clear()
    _LOADED_FROM = path
    try:
        with open(path) as f:
            raw = json.load(f)
        legacy = {}
        for key, blk in raw.items():
            if not (isinstance(blk, list) and len(blk) == 3):
                continue
            if key.startswith(f"{_LEGACY_VERSION}|"):
                legacy[_migrate_key(key)] = tuple(int(b) for b in blk)
            else:
                _MEM[key] = tuple(int(b) for b in blk)
        # one-time v1 -> v2 migration: a native v2 entry for the same
        # problem wins over the migrated legacy one.
        for key, blk in legacy.items():
            if not key.startswith(f"{_LEGACY_VERSION}|"):
                _MEM.setdefault(key, blk)
    except (OSError, ValueError):
        pass  # missing/corrupt cache == empty cache


def _save_locked() -> None:
    path = cache_path()
    try:
        # merge-on-save: another process may have persisted entries since
        # our load — re-read and overlay so concurrent tuners append
        # rather than erase each other's winners
        try:
            with open(path) as f:
                for key, blk in json.load(f).items():
                    if key not in _MEM and isinstance(blk, list) and len(blk) == 3:
                        _MEM[key] = tuple(int(b) for b in blk)
        except (OSError, ValueError):
            pass
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({k: list(v) for k, v in sorted(_MEM.items())}, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: keep the in-memory cache only


def clear_memory_cache() -> None:
    """Forget loaded entries (tests repoint REPRO_AUTOTUNE_CACHE)."""
    global _LOADED_FROM
    with _LOCK:
        _MEM.clear()
        _LOADED_FROM = None


def cached_block(m: int, n: int, k: int, cfg: NMConfig, dtype,
                 family: str = "", backend: str = "tpu") -> Optional[tuple]:
    platform = jax.default_backend()
    with _LOCK:
        _load_locked()
        hit = _MEM.get(_key(m, n, k, cfg, dtype, platform, backend, family))
    bundle = _obs.get_obs()
    if bundle is not None:
        bundle.metrics.inc(
            "autotune_cache_hits_total" if hit is not None
            else "autotune_cache_misses_total",
            family=family or "default")
    return hit


def candidate_blocks(m: int, n: int, k: int, cfg: NMConfig,
                     family: str = "", backend: str = "tpu") -> list[tuple]:
    """Plan-feasible, deduplicated candidate triples for this problem.

    On CPU the kernel runs in interpret mode (each probe is orders of
    magnitude slower than compiled code), so the grid is trimmed — the
    cache key carries the platform, so a CPU-tuned entry never shadows a
    compiled sweep. The decode family pins block_m to one sublane (its M
    is always 8) and sweeps only the streaming (n, k) tiles. The GPU
    kernel family sweeps smaller output tiles (one Triton program
    instance per tile; its block_k only sizes the in-kernel reduction
    chunks)."""
    interp = interpret_for(backend)
    if backend == "gpu":
        if family == "decode":
            grid_m = (8,)
            grid_n, grid_k = ((128,), (512,)) if interp else (
                (64, 128, 256), (256, 512, 1024))
        else:
            if interp:
                grid_m, grid_n, grid_k = (32, 64), (128,), (512,)
            else:
                grid_m, grid_n, grid_k = (32, 64, 128), (64, 128, 256), (
                    256, 512, 1024)
    elif family == "decode":
        grid_m = (8,)
        if jax.default_backend() == "cpu":
            grid_n, grid_k = (128, 256), (256, 1024)
        else:
            grid_n, grid_k = (128, 256, 512), (256, 512, 1024, 2048)
    elif jax.default_backend() == "cpu":
        grid_m, grid_n, grid_k = (8, 128), (128, 256), (256, 1024)
    else:
        grid_m, grid_n, grid_k = (8, 64, 128, 256), (128, 256, 512), (
            256, 512, 1024, 2048)
    seen, out = set(), []
    for bm in grid_m:
        for bn in grid_n:
            for bk in grid_k:
                plan = plan_nm_matmul(m, n, k, cfg, (bm, bn, bk))
                if plan is None or plan.block in seen:
                    continue
                seen.add(plan.block)
                out.append(plan.block)
    return out


def default_block(family: str = "", backend: str = "tpu") -> tuple:
    """The fallback triple for a (family, kernel-backend) pair."""
    if backend == "gpu":
        return DEFAULT_GPU_DECODE_BLOCK if family == "decode" \
            else DEFAULT_GPU_BLOCK
    return DEFAULT_DECODE_BLOCK if family == "decode" else DEFAULT_BLOCK


def tune(
    m: int,
    n: int,
    k: int,
    cfg: NMConfig,
    dtype=jnp.float32,
    candidates: Optional[Sequence[tuple]] = None,
    repeats: int = 3,
    family: str = "",
    backend: str = "tpu",
) -> tuple:
    """Time every candidate on real operands; persist and return the winner.

    ``dtype`` is the *value* dtype of the compressed operand and selects
    the quantization family: a float dtype sweeps the float kernel on
    float operands; ``int8`` sweeps the dequantizing kernel on int8
    values + per-column scales — the int8 family has its own cache keys
    (the dtype is part of the key), so its winners never shadow the
    float sweep's. ``family="decode"`` sweeps the skinny-M decode
    kernels instead, under their own ``|decode``-suffixed keys;
    ``backend`` selects the kernel lowering (tpu/gpu) being swept, each
    under its own key namespace.
    """
    from repro.core.sparsity import compress_nm, random_nm_matrix
    from repro.kernels.indexmac.ops import (
        run_pallas_decode,
        run_pallas_decode_q,
        run_pallas_padded,
        run_pallas_padded_q,
    )
    from repro.kernels.indexmac_gpu.ops import (
        run_gpu_decode,
        run_gpu_decode_q,
        run_gpu_padded,
        run_gpu_padded_q,
    )

    platform = jax.default_backend()
    interpret = interpret_for(backend)
    quantized = jnp.dtype(dtype) == jnp.dtype(jnp.int8)
    decode = family == "decode"
    gpu = backend == "gpu"
    t_sweep0 = time.perf_counter()
    kk = -(-k // cfg.m) * cfg.m  # operand K must hold whole blocks
    w = random_nm_matrix(jax.random.PRNGKey(0), (kk, n), cfg, axis=0)
    vals, idx = compress_nm(w, cfg, axis=0)
    if quantized:
        # representative int8 operands; activations stay float.
        vals = jnp.clip(jnp.round(vals * 64.0), -127, 127).astype(jnp.int8)
        scales = jnp.full((n,), 1.0 / 64.0, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, kk))

        if decode:
            run_q_decode = run_gpu_decode_q if gpu else run_pallas_decode_q

            def run(x, vals, idx, *, cfg, plan, interpret):
                return run_q_decode(
                    x, vals, idx, scales, None, cfg=cfg, plan=plan,
                    activation=None, interpret=interpret)
        else:
            run_q_padded = run_gpu_padded_q if gpu else run_pallas_padded_q

            def run(x, vals, idx, *, cfg, plan, interpret):
                return run_q_padded(
                    x, vals, idx, scales, cfg=cfg, plan=plan,
                    interpret=interpret)
    else:
        x = jax.random.normal(jax.random.PRNGKey(1), (m, kk)).astype(dtype)
        vals = vals.astype(dtype)
        if decode:
            run_f_decode = run_gpu_decode if gpu else run_pallas_decode

            def run(x, vals, idx, *, cfg, plan, interpret):
                return run_f_decode(
                    x, vals, idx, None, cfg=cfg, plan=plan,
                    activation=None, interpret=interpret)
        else:
            run = run_gpu_padded if gpu else run_pallas_padded

    best, best_t = None, float("inf")
    for block in candidates or candidate_blocks(m, n, kk, cfg, family,
                                                backend):
        plan = plan_nm_matmul(m, n, kk, cfg, block)
        if plan is None:
            continue
        try:
            run(
                x, vals, idx, cfg=cfg, plan=plan, interpret=interpret
            ).block_until_ready()  # compile / warm up
            t = min(
                _time_once(run, x, vals, idx, cfg, plan, interpret)
                for _ in range(repeats)
            )
        except Exception:  # noqa: BLE001 — infeasible on this backend
            continue
        if t < best_t:
            best, best_t = plan.block, t
    if best is None:
        best = plan_nm_matmul(m, n, kk, cfg,
                              default_block(family, backend)).block
    with _LOCK:
        _load_locked()
        _MEM[_key(m, n, k, cfg, dtype, platform, backend, family)] = best
        _save_locked()
    bundle = _obs.get_obs()
    if bundle is not None:
        bundle.metrics.inc("autotune_sweeps_total",
                           family=family or "default")
        bundle.metrics.observe("autotune_sweep_seconds",
                               time.perf_counter() - t_sweep0)
    return best


def _time_once(fn, x, vals, idx, cfg, plan, interpret) -> float:
    t0 = time.perf_counter()
    fn(x, vals, idx, cfg=cfg, plan=plan, interpret=interpret).block_until_ready()
    return time.perf_counter() - t0


def best_block(
    m: int, n: int, k: int, cfg: NMConfig, dtype=jnp.float32,
    family: str = "", backend: str = "tpu",
) -> tuple:
    """Hot-path lookup: cache hit, else sweep iff REPRO_AUTOTUNE=1, else
    the (family, backend) default triple (clamped later by the plan)."""
    hit = cached_block(m, n, k, cfg, dtype, family, backend)
    if hit is not None:
        return hit
    if os.environ.get("REPRO_AUTOTUNE") == "1":
        return tune(m, n, k, cfg, dtype, family=family, backend=backend)
    return default_block(family, backend)


def ensure_tuned(
    m: int, n: int, k: int, cfg: NMConfig, dtype=jnp.float32,
    family: str = "", backend: str = "tpu",
) -> tuple:
    """Sweep-if-missing, for callers that want to pre-pay (serving warmup,
    benchmarks) regardless of REPRO_AUTOTUNE."""
    return cached_block(m, n, k, cfg, dtype, family, backend) or tune(
        m, n, k, cfg, dtype, family=family, backend=backend)


# ---------------------------------------------------------------------------
# bs_attn family: (bq, bk) token tiles for the block-sparse attention
# kernels. Same cache file and key schema — the MaskSpec's ``.tag``
# duck-types NMConfig in ``_key`` and the problem key is Sq x Skv x Dk;
# entries persist as [bq, bk, 0] triples (the loader keeps len-3 lists).
# ---------------------------------------------------------------------------

_ATTN_FAMILY = "bs_attn"


def candidate_attn_tiles(spec, sq: int, skv: int,
                         backend: str = "tpu") -> list[tuple]:
    """Feasible (bq, bk) tiles: the pattern granularity and its
    sublane-aligned subdivisions (a tile above ``spec.block`` can only
    merge live and dead blocks — never swept)."""
    from repro.kernels.blocksparse_attn.mask import compile_mask

    cands = []
    for div in (1, 2, 4):
        bq = spec.block // div
        bk = spec.block // div
        if bq < 8 or bq % 8:
            continue
        if compile_mask(spec, sq, skv, (bq, bk)) is None:
            continue
        if (bq, bk) not in cands:
            cands.append((bq, bk))
    return cands


def tune_attn(sq: int, skv: int, dk: int, spec, dtype=jnp.float32,
              repeats: int = 3, backend: str = "tpu") -> tuple:
    """Time the block-gather lowering at every candidate tile on real
    operands; persist and return the winning (bq, bk). The gather
    lowering is what every backend's routing shares (tile choice moves
    its pad + gather width the same way it moves the kernels' grids),
    and it times honestly in interpret-free XLA on any host."""
    from repro.kernels.blocksparse_attn.mask import compile_mask, default_tile
    from repro.kernels.blocksparse_attn.ref import blocksparse_xla

    platform = jax.default_backend()
    t_sweep0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, sq, 4, dk)).astype(dtype)
    k = jax.random.normal(kk, (1, skv, 4, dk)).astype(dtype)
    v = jax.random.normal(kv, (1, skv, 4, dk)).astype(dtype)
    best, best_t = None, float("inf")
    for tile in candidate_attn_tiles(spec, sq, skv, backend):
        plan = compile_mask(spec, sq, skv, tile)
        if plan is None:
            continue
        try:
            run = jax.jit(lambda q, k, v, plan=plan: blocksparse_xla(
                q, k, v, spec=spec, plan=plan))
            run(q, k, v).block_until_ready()  # compile / warm up
            t = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                run(q, k, v).block_until_ready()
                t = min(t, time.perf_counter() - t0)
        except Exception:  # noqa: BLE001 — infeasible tile
            continue
        if t < best_t:
            best, best_t = tile, t
    if best is None:
        best = default_tile(spec, sq, skv)
    with _LOCK:
        _load_locked()
        _MEM[_key(sq, dk, skv, spec, dtype, platform, backend,
                  _ATTN_FAMILY)] = (best[0], best[1], 0)
        _save_locked()
    bundle = _obs.get_obs()
    if bundle is not None:
        bundle.metrics.inc("autotune_sweeps_total", family=_ATTN_FAMILY)
        bundle.metrics.observe("autotune_sweep_seconds",
                               time.perf_counter() - t_sweep0)
    return best


def best_attn_tile(sq: int, skv: int, dk: int, spec, dtype=jnp.float32,
                   backend: str = "tpu") -> tuple:
    """Hot-path (bq, bk) lookup for the bs_attn family: cache hit, else
    sweep iff REPRO_AUTOTUNE=1, else the spec's own granularity."""
    from repro.kernels.blocksparse_attn.mask import default_tile

    hit = cached_block(sq, dk, skv, spec, dtype, _ATTN_FAMILY, backend)
    if hit is not None:
        return tuple(hit[:2])
    if os.environ.get("REPRO_AUTOTUNE") == "1":
        return tune_attn(sq, skv, dk, spec, dtype, backend=backend)
    return default_tile(spec, sq, skv)


def ensure_tuned_attn(sq: int, skv: int, dk: int, spec,
                      dtype=jnp.float32, backend: str = "tpu") -> tuple:
    """Sweep-if-missing for the bs_attn family (serving warmup)."""
    hit = cached_block(sq, dk, skv, spec, dtype, _ATTN_FAMILY, backend)
    if hit is not None:
        return tuple(hit[:2])
    return tune_attn(sq, skv, dk, spec, dtype, backend=backend)
