"""Pure-jnp oracle for the indexmac N:M sparse matmul kernel.

Computes y = x @ W where W is stored compressed along K:
  vals: (K*n/m, N) same dtype family as x
  idx:  (K*n/m, N) int8, entries in [0, m)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig, decompress_nm


def nm_matmul_ref(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    cfg: NMConfig,
    out_dtype=None,
) -> jax.Array:
    """Decompress W (in the stored dtype — upcasting here would double the
    weight bytes crossing HBM/ICI) and matmul with f32 accumulation."""
    from repro.core.dots import acc_dot

    w = decompress_nm(vals, idx, cfg, axis=0)  # (K, N), vals dtype
    y = acc_dot(x, w)
    return y.astype(out_dtype or x.dtype)


def nm_matmul_q_ref(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    cfg: NMConfig,
    out_dtype=None,
) -> jax.Array:
    """int8 oracle, mirroring the quantized kernel's exact arithmetic:
    decompress the int8 values, cast to f32 (exact — |q| <= 127), f32
    dot, then one per-output-column scale multiply at the end. On the
    integer lattice (integer-valued x, |acc| < 2^24) this is bit-exact
    against the blocked/padded kernel regardless of tiling, because
    every partial sum is an exactly-representable integer."""
    w8 = decompress_nm(vals, idx, cfg, axis=0)  # (K, N) int8
    y32 = jnp.dot(
        x.astype(jnp.float32), w8.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y32 = y32 * scales.astype(jnp.float32)[None, :]
    return y32.astype(out_dtype or x.dtype)
