"""Skinny-M Pallas kernels for decode-shaped N:M sparse GEMMs.

The serving decode step issues M = slots (1-8) row GEMMs against every
projection — shapes where the prefill kernel's (mi, ni, ki) tiling is
all padding and XLA's dense reference wins by default. This module is
the TPU mirror of the operand-reuse restructuring in the follow-up
paper (arXiv 2501.10189, §IV): instead of expanding the compressed tile
to a dense (bk, bn) weight block and paying a full-size MXU pass, the
*activation* rows are the operand that gets "indexed":

* grid is (ni, ki) — no M tiling; the whole padded x block (8, bk)
  pins in VMEM across the entire sweep (the stationary operand).
* ``vals``/``idx`` stream exactly once per (n, k) block.
* for each in-block offset pair (s, j) the kernel contracts the strided
  x column slice ``x[:, j::m]`` against the masked compressed rows
  ``where(idx[s::n] == j, vals[s::n], 0)`` — an (8, bk/m) x (bk/m, bn)
  dot. Summed over the n*m offset pairs this is exactly y = x @ W, with
  m-fold less MXU work than the dense-expansion kernel and no (bk, bn)
  intermediate; the bounded ``idx`` compare is the vindexmac analogue
  (a local select, never an HBM gather).
* the epilogue — dequant scales (int8 family), bias add, activation —
  runs on the f32 accumulator at writeback (see
  :mod:`repro.kernels.epilogue` for the composition contract), so a
  decode GEMM is one kernel launch end to end.

Accumulation is f32 in VMEM scratch; on the integer lattice the result
is bit-exact against the reference composition regardless of tiling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.sparsity import NMConfig
from repro.kernels.epilogue import ACTIVATIONS


def _decode_partial(x, v, ii, n: int, m: int):
    """Sum of per-(s, j) offset dots: the (bm, bk) x block against a
    compressed (bkc, bn) tile, contracted without densifying W."""
    bm = x.shape[0]
    bn = v.shape[1]
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for s in range(n):
        v_s = v[s::n, :].astype(jnp.float32)  # (bk/m, bn)
        i_s = ii[s::n, :].astype(jnp.int32)
        for j in range(m):
            xj = x[:, j::m]  # (bm, bk/m): dense rows j, j+m, ... of K
            w_sj = jnp.where(i_s == j, v_s, 0.0)
            acc += jax.lax.dot(xj, w_sj, preferred_element_type=jnp.float32)
    return acc


def _writeback(acc, o_ref, scales_ref, bias_ref, *, activation, out_dtype):
    y = acc
    if scales_ref is not None:
        y = y * scales_ref[...]
    if bias_ref is not None:
        y = y + bias_ref[...]
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    o_ref[...] = y.astype(out_dtype)


def _decode_kernel(x_ref, vals_ref, idx_ref, *rest, n, m, nk, out_dtype,
                   activation, quantized, has_bias):
    refs = list(rest)
    scales_ref = refs.pop(0) if quantized else None
    bias_ref = refs.pop(0) if has_bias else None
    o_ref, acc_ref = refs
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += _decode_partial(x, vals_ref[...], idx_ref[...], n, m)

    @pl.when(ki == nk - 1)
    def _done():
        _writeback(acc_ref[...], o_ref, scales_ref, bias_ref,
                   activation=activation, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_n", "block_k", "activation", "out_dtype",
                     "interpret"),
)
def nm_spmm_pallas_decode(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    cfg: NMConfig,
    block_n: int = 256,
    block_k: int = 1024,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = epilogue(x @ decompress(vals, idx)) for skinny x.

    Shape requirements (enforced): M a sublane multiple (the op layer
    pads 1..8 rows up to 8), N % block_n == 0, K % block_k == 0,
    block_k % m == 0; ``bias`` is (N,) when given.
    """
    return _pallas_decode(x, vals, idx, None, bias, cfg=cfg,
                          block_n=block_n, block_k=block_k,
                          activation=activation, out_dtype=out_dtype,
                          interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_n", "block_k", "activation", "out_dtype",
                     "interpret"),
)
def nm_spmm_pallas_decode_q(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    cfg: NMConfig,
    block_n: int = 256,
    block_k: int = 1024,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """int8 decode sibling: one byte per kept value streams once, the
    per-output-channel ``scales`` multiply the f32 accumulator before
    the bias/activation epilogue — one launch from int8 payload to
    activated output."""
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized kernel needs int8 vals, got {vals.dtype}")
    if scales.shape != (vals.shape[1],):
        raise ValueError(
            f"scales shape {scales.shape} != (N,) = ({vals.shape[1]},)")
    return _pallas_decode(x, vals, idx, scales, bias, cfg=cfg,
                          block_n=block_n, block_k=block_k,
                          activation=activation, out_dtype=out_dtype,
                          interpret=interpret)


def _pallas_decode(x, vals, idx, scales, bias, *, cfg, block_n, block_k,
                   activation, out_dtype, interpret):
    mm, kk = x.shape
    kc, nn = vals.shape
    if kc * cfg.m != kk * cfg.n:
        raise ValueError(f"vals rows {kc} inconsistent with K={kk} and {cfg.tag}")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    if mm % 8:
        raise ValueError(f"decode kernel needs M a sublane multiple, got {mm}")
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    block_k = min(block_k, kk)
    block_n = min(block_n, nn)
    if kk % block_k or block_k % cfg.m:
        raise ValueError(f"K={kk} block_k={block_k} m={cfg.m} not tileable")
    if nn % block_n:
        raise ValueError(f"N={nn} not divisible by block_n={block_n}")
    if bias is not None and bias.shape != (nn,):
        raise ValueError(f"bias shape {bias.shape} != (N,) = ({nn},)")
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k
    bkc = block_k * cfg.n // cfg.m

    quantized = scales is not None
    has_bias = bias is not None
    grid = (nn // block_n, nk)
    # the whole (skinny) x block is index (0, k): resident across the n
    # sweep — the stationary operand of the decode dataflow.
    in_specs = [
        pl.BlockSpec((mm, block_k), lambda j, k: (0, k)),
        pl.BlockSpec((bkc, block_n), lambda j, k: (k, j)),
        pl.BlockSpec((bkc, block_n), lambda j, k: (k, j)),
    ]
    operands = [x, vals, idx]
    if quantized:
        in_specs.append(pl.BlockSpec((1, block_n), lambda j, k: (0, j)))
        operands.append(scales.astype(jnp.float32).reshape(1, nn))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, block_n), lambda j, k: (0, j)))
        operands.append(bias.astype(jnp.float32).reshape(1, nn))

    kernel = functools.partial(
        _decode_kernel, n=cfg.n, m=cfg.m, nk=nk, out_dtype=out_dtype,
        activation=activation, quantized=quantized, has_bias=has_bias,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((mm, block_n), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[pltpu.VMEM((mm, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
