"""The compressed-GEMM op layer: one typed entry point, four dispatch
families, fused epilogues.

``nm_matmul(x, w, *, epilogue=None)`` consumes an
:class:`repro.core.nmweight.NMWeight` or an int8
:class:`repro.quant.qnmweight.QNMWeight`: the weight's own ``NMConfig``
and :class:`KernelPolicy` drive dispatch — ``off`` pins the XLA
reference, ``auto`` takes a Pallas kernel when the shape normalizes
within the family's waste limit, ``force`` ignores the limit (and
*raises* :class:`repro.kernels.registry.KernelForceError` when no legal
kernel geometry exists, instead of silently serving reference timings).

Dispatch families (the registry selects by M-threshold, not by falling
back to reference):

  nm_matmul          float values, M > REPRO_DECODE_M_MAX (prefill /
                     training shapes; (mi, ni, ki)-tiled kernel)
  nm_matmul_q        int8 values, same shapes (dequantizing kernel)
  nm_matmul_decode   float values, M <= REPRO_DECODE_M_MAX (default 8):
                     the skinny-M kernel of
                     :mod:`repro.kernels.indexmac.decode_kernel`, with
                     the epilogue fused into the accumulator writeback
  nm_matmul_decode_q int8 decode sibling (scales fused too)

The :class:`repro.kernels.epilogue.Epilogue` spec (bias + activation
name) is honored on *every* path: decode kernels fuse it at writeback;
the non-decode families apply the identical f32 composition after the
GEMM; the reference implementations mirror it exactly — so parity is
bit-exact on the integer lattice across all eight implementations.

``explain_dispatch(x_shape, w)`` answers "which family/kernel/block/pad
plan *would* run" without executing anything — the public dry-run used
by benchmarks instead of sniffing the record history.

The positional surfaces are deprecated: they live only in
:mod:`repro.kernels.raw` and warn on use; the non-warning
``nm_matmul_positional`` / ``nm_matmul_q_positional`` internals remain
for kernel-level tests.

Training backward (both float families; padding never changes it — it
works on logical shapes, via the differentiable reference composition):

  y     = act(x @ W + bias),   W = decompress(vals, idx)
  dx    = (dy * act'(..)) @ W^T
  dvals = gather_{kept positions}(x^T @ (dy * act'(..)))
  dbias = sum over rows of (dy * act'(..))     (straight-through on idx)
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nmweight import NMWeight
from repro.core.sparsity import NMConfig, decompress_nm
from repro.kernels import autotune, registry
from repro.kernels.backend import interpret_for, resolve_backend
from repro.kernels.epilogue import apply_epilogue_f32, resolve_epilogue
from repro.kernels.indexmac.decode_kernel import (
    nm_spmm_pallas_decode,
    nm_spmm_pallas_decode_q,
)
from repro.kernels.indexmac.kernel import nm_spmm_pallas, nm_spmm_pallas_q
from repro.kernels.indexmac.ref import nm_matmul_q_ref, nm_matmul_ref
from repro.kernels.padding import (
    PadPlan,
    decode_pad_waste_limit,
    pad_nm_operands,
    pad_waste_limit,
    plan_nm_matmul,
)
from repro.quant.qnmweight import QNMWeight


def decode_m_max() -> int:
    """Largest M (flattened row count) routed to the decode families."""
    return int(os.environ.get("REPRO_DECODE_M_MAX", 8))


def _pin_compressed(vals, idx):
    if os.environ.get("REPRO_GATHER_COMPRESSED") == "1":
        # Pin the compressed operands to (None, "model") so the FSDP
        # all-gather over "data" moves the COMPRESSED bytes (vals+idx,
        # 0.375-0.75x dense) and decompression runs shard-locally — without
        # this, SPMD may decompress on the home shards and gather the
        # dense W (EXPERIMENTS.md §Perf P3).
        from repro.parallel.hints import shard_hint_leaves

        vals, idx = shard_hint_leaves((vals, idx), None, "model")
    return vals, idx


def _validate_pair(vals, idx, k, cfg):
    if vals.shape[0] * cfg.m != k * cfg.n:
        raise ValueError(
            f"vals rows {vals.shape[0]} inconsistent with K={k} and {cfg.tag}"
        )
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")


# ---------------------------------------------------------------------------
# routing: (M, K, N) + policy -> dispatch family + pad plan
# ---------------------------------------------------------------------------


def _route(mm, nn, kk, cfg, dtype, use_kernel, force, block, decode_block,
           quantized, backend="tpu"):
    """Resolve the dispatch family, block triple and pad plan for one
    call — shared by the executing paths and :func:`explain_dispatch`,
    so the explanation can never drift from the real routing.
    ``backend`` is the *resolved* kernel backend (never "auto") — it
    selects which lowering family the registry may pick and which
    autotune cache namespace supplies the default block."""
    decode = mm <= decode_m_max()
    family = "decode" if decode else ""
    op = ("nm_matmul_decode" if decode else "nm_matmul") + (
        "_q" if quantized else "")
    key_dtype = jnp.int8 if quantized else dtype
    plan = None
    if use_kernel:  # skip block resolution (cache I/O, possible inline
        # sweep under REPRO_AUTOTUNE=1) when the kernel can't be taken
        blk = decode_block if decode else block
        if blk is None:
            blk = autotune.best_block(mm, nn, kk, cfg, key_dtype,
                                      family=family, backend=backend)
        plan = plan_nm_matmul(mm, nn, kk, cfg, tuple(blk))
        if plan is None and force:
            raise registry.KernelForceError(
                f"KernelPolicy('force') on a "
                f"{'QNMWeight' if quantized else 'NMWeight'} compressed "
                f"along axis 0 with pattern {cfg.tag}: shape "
                f"M={mm} K={kk} N={nn} does not normalize to any legal "
                f"kernel geometry, and force forbids the reference "
                f"fallback")
    ctx = registry.make_ctx(
        (mm, kk, nn), nm=cfg, use_kernel=use_kernel, plan=plan,
        dtype=key_dtype, force=force, backend=backend,
    )
    return op, plan, ctx


def _pallas_supports(ctx: dict) -> Optional[str]:
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    plan = ctx["plan"]
    if plan is None:
        return "shape not normalizable"
    if ctx.get("force"):
        return None  # KernelPolicy "force": waste limit ignored
    limit = pad_waste_limit()
    if plan.waste > limit:
        return f"padding waste {plan.waste:.2f}x > limit {limit:.2f}x"
    return None


def _decode_supports(ctx: dict) -> Optional[str]:
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    plan = ctx["plan"]
    if plan is None:
        return "shape not normalizable"
    if ctx.get("force"):
        return None
    limit = decode_pad_waste_limit()
    if plan.waste_nk > limit:
        return (f"N/K padding waste {plan.waste_nk:.2f}x > decode limit "
                f"{limit:.2f}x")
    return None


# ---------------------------------------------------------------------------
# prefill-shaped family (M > decode_m_max): (mi, ni, ki)-tiled kernel
# ---------------------------------------------------------------------------


def run_pallas_padded(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Pad operands to the plan, run the kernel, slice the logical output."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    bm, bn, bk = plan.block
    y = nm_spmm_pallas(
        xp, vp, ip, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul", "pallas_padded", priority=100,
                   supports=_pallas_supports, uses_plan=True,
                   backend="tpu")
def _run_pallas_impl(x2, vals, idx, *, cfg, plan, interpret):
    return run_pallas_padded(
        x2, vals, idx, cfg=cfg, plan=plan, interpret=interpret
    )


@registry.register("nm_matmul", "reference", priority=0)
def _run_ref_impl(x2, vals, idx, *, cfg, plan, interpret):
    return nm_matmul_ref(x2, vals, idx, cfg)


def run_pallas_padded_q(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Quantized sibling of :func:`run_pallas_padded`: pads the int8
    operands (appended columns get unit scales — they are sliced away)
    and runs the dequantizing kernel."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    sp = scales
    if plan.pn > plan.n:
        sp = jnp.pad(scales, (0, plan.pn - plan.n), constant_values=1.0)
    bm, bn, bk = plan.block
    y = nm_spmm_pallas_q(
        xp, vp, ip, sp, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_q", "pallas_padded_q", priority=100,
                   supports=_pallas_supports, uses_plan=True,
                   backend="tpu")
def _run_pallas_q_impl(x2, vals, idx, scales, *, cfg, plan, interpret):
    return run_pallas_padded_q(
        x2, vals, idx, scales, cfg=cfg, plan=plan, interpret=interpret
    )


@registry.register("nm_matmul_q", "reference_q", priority=0)
def _run_ref_q_impl(x2, vals, idx, scales, *, cfg, plan, interpret):
    return nm_matmul_q_ref(x2, vals, idx, scales, cfg)


# ---------------------------------------------------------------------------
# decode-shaped families (M <= decode_m_max): skinny-M kernel, fused epilogue
# ---------------------------------------------------------------------------


def run_pallas_decode(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    bias: Optional[jax.Array],
    *,
    cfg: NMConfig,
    plan: PadPlan,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    """Pad to the plan and run the fused decode kernel. Padded bias
    columns are zero and all epilogue activations fix 0 (act(0) == 0 for
    relu/gelu/silu/relu_sq), so the slice-back stays exact."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    bp = bias
    if bias is not None and plan.pn > plan.n:
        bp = jnp.pad(bias, (0, plan.pn - plan.n))
    _, bn, bk = plan.block
    y = nm_spmm_pallas_decode(
        xp, vp, ip, bp, cfg=cfg, block_n=bn, block_k=bk,
        activation=activation, interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_decode", "pallas_decode", priority=100,
                   supports=_decode_supports, uses_plan=True,
                   backend="tpu")
def _run_pallas_decode_impl(x2, vals, idx, bias, *, cfg, plan, activation,
                            interpret):
    return run_pallas_decode(
        x2, vals, idx, bias, cfg=cfg, plan=plan, activation=activation,
        interpret=interpret,
    )


@registry.register("nm_matmul_decode", "reference_decode", priority=0)
def _run_ref_decode_impl(x2, vals, idx, bias, *, cfg, plan, activation,
                         interpret):
    w = decompress_nm(vals, idx, cfg, axis=0)
    y32 = jnp.dot(
        x2.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return apply_epilogue_f32(y32, bias, activation).astype(x2.dtype)


def run_pallas_decode_q(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    bias: Optional[jax.Array],
    *,
    cfg: NMConfig,
    plan: PadPlan,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    """int8 decode sibling: padded columns get unit scales + zero bias."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    sp, bp = scales, bias
    if plan.pn > plan.n:
        sp = jnp.pad(scales, (0, plan.pn - plan.n), constant_values=1.0)
        if bias is not None:
            bp = jnp.pad(bias, (0, plan.pn - plan.n))
    _, bn, bk = plan.block
    y = nm_spmm_pallas_decode_q(
        xp, vp, ip, sp, bp, cfg=cfg, block_n=bn, block_k=bk,
        activation=activation, interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_decode_q", "pallas_decode_q", priority=100,
                   supports=_decode_supports, uses_plan=True,
                   backend="tpu")
def _run_pallas_decode_q_impl(x2, vals, idx, scales, bias, *, cfg, plan,
                              activation, interpret):
    return run_pallas_decode_q(
        x2, vals, idx, scales, bias, cfg=cfg, plan=plan,
        activation=activation, interpret=interpret,
    )


@registry.register("nm_matmul_decode_q", "reference_decode_q", priority=0)
def _run_ref_decode_q_impl(x2, vals, idx, scales, bias, *, cfg, plan,
                           activation, interpret):
    w8 = decompress_nm(vals, idx, cfg, axis=0)
    y32 = jnp.dot(
        x2.astype(jnp.float32), w8.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y32 = y32 * scales.astype(jnp.float32)[None, :]
    return apply_epilogue_f32(y32, bias, activation).astype(x2.dtype)


# ---------------------------------------------------------------------------
# typed entry point
# ---------------------------------------------------------------------------


def _epilogue_after(y, bias, activation):
    """Non-decode paths apply the identical f32 composition after the
    GEMM (the decode kernels fuse it; same arithmetic either way)."""
    if bias is None and activation is None:
        return y
    return apply_epilogue_f32(
        y.astype(jnp.float32), bias, activation).astype(y.dtype)


def nm_matmul(x: jax.Array, w, *,
              block: Optional[tuple[int, int, int]] = None,
              epilogue=None, backend: Optional[str] = None) -> jax.Array:
    """y = epilogue(x @ densify(w)); x: (..., K), w: an NMWeight or
    QNMWeight compressed along its axis 0 (the contraction dim).

    The weight's own metadata drives dispatch: ``w.nm`` is the pattern,
    ``w.kernel_policy`` picks reference/Pallas, the kernel backend and
    the block triples, the weight's *type* picks the quantization family
    (int8 weights route to the dequantizing kernels, which have their
    own autotune keys), and the flattened row count picks prefill-shaped
    vs decode families. ``epilogue`` is an
    :class:`repro.kernels.epilogue.Epilogue` (bias + activation) fused
    into the decode kernels' writeback. ``block`` overrides the policy's
    block for this call (benchmarks); ``backend`` overrides the policy's
    backend (``"auto"``/``"tpu"``/``"gpu"`` — see
    :mod:`repro.kernels.backend`; forcing an unavailable backend raises
    :class:`repro.kernels.registry.KernelForceError`).
    """
    bias, activation = resolve_epilogue(epilogue)
    if isinstance(w, QNMWeight):
        _check_axis0(w, "nm_matmul")
        pol = w.kernel_policy
        be = resolve_backend(
            backend if backend is not None
            else getattr(pol, "backend", "auto"))
        return _nm_matmul_q_core(
            x, w.vals, w.idx, w.scales, bias, w.nm, activation,
            pol.mode != "off", block or pol.block,
            block or pol.decode_block, pol.mode == "force", be)
    if not isinstance(w, NMWeight):
        raise TypeError(
            f"nm_matmul expects an NMWeight or QNMWeight, got "
            f"{type(w).__name__}; wrap compressed operands with "
            "repro.api.sparsify / repro.api.quantize, or use "
            "repro.kernels.raw for positional (vals, idx, cfg) calls"
        )
    _check_axis0(w, "nm_matmul")
    pol = w.kernel_policy
    be = resolve_backend(
        backend if backend is not None else getattr(pol, "backend", "auto"))
    return _nm_matmul_core(
        x, w.vals, w.idx, bias, w.nm, activation,
        pol.mode != "off", block or pol.block,
        block or pol.decode_block, pol.mode == "force", be)


def _check_axis0(w, name):
    if w.axis != 0:
        raise ValueError(
            f"{name} needs the weight compressed along axis 0 (the "
            f"contraction dim of y = x @ W); got axis={w.axis}"
        )


def nm_matmul_q(x: jax.Array, w: QNMWeight, *,
                block: Optional[tuple[int, int, int]] = None,
                epilogue=None, backend: Optional[str] = None) -> jax.Array:
    """Quantized alias of :func:`nm_matmul` (the unified entry point
    type-dispatches; this name survives for callers that want the int8
    family asserted by construction)."""
    if not isinstance(w, QNMWeight):
        raise TypeError(
            f"nm_matmul_q expects a QNMWeight, got {type(w).__name__}; "
            "produce one with repro.api.quantize"
        )
    return nm_matmul(x, w, block=block, epilogue=epilogue, backend=backend)


# float core: custom_vjp so compressed fine-tuning trains through every
# family (the bwd runs the differentiable reference composition on
# logical shapes — padding and family choice never change it)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10))
def _nm_matmul_core(x, vals, idx, bias, cfg, activation, use_kernel, block,
                    decode_block, force, backend):
    return _core_fwd_impl(x, vals, idx, bias, cfg, activation, use_kernel,
                          block, decode_block, force, backend)


def _core_fwd_impl(x, vals, idx, bias, cfg, activation, use_kernel, block,
                   decode_block, force, backend):
    vals, idx = _pin_compressed(vals, idx)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    _validate_pair(vals, idx, k, cfg)
    op, plan, ctx = _route(mm, nn, k, cfg, x.dtype, use_kernel, force,
                           block, decode_block, quantized=False,
                           backend=backend)
    interp = interpret_for(backend)
    if op == "nm_matmul_decode":
        y2 = registry.dispatch(
            op, ctx, x2, vals, idx, bias,
            cfg=cfg, plan=plan, activation=activation, interpret=interp,
        )
    else:
        y2 = registry.dispatch(
            op, ctx, x2, vals, idx,
            cfg=cfg, plan=plan, interpret=interp,
        )
        y2 = _epilogue_after(y2, bias, activation)
    return y2.reshape(*lead, nn)


def _core_fwd(x, vals, idx, bias, cfg, activation, use_kernel, block,
              decode_block, force, backend):
    y = _core_fwd_impl(x, vals, idx, bias, cfg, activation, use_kernel,
                       block, decode_block, force, backend)
    return y, (x, vals, idx, bias)


def _core_bwd(cfg, activation, use_kernel, block, decode_block, force,
              backend, res, dy):
    x, vals, idx, bias = res

    def ref(x_, vals_, bias_):
        w = decompress_nm(vals_, idx, cfg, axis=0).astype(jnp.float32)
        y = jnp.einsum("...k,kn->...n", x_.astype(jnp.float32), w)
        return apply_epilogue_f32(y, bias_, activation)

    dy32 = dy.astype(jnp.float32)
    if bias is None:
        _, vjp = jax.vjp(lambda x_, v_: ref(x_, v_, None), x, vals)
        dx, dvals = vjp(dy32)
        dbias = None
    else:
        _, vjp = jax.vjp(ref, x, vals, bias)
        dx, dvals, dbias = vjp(dy32)
        dbias = dbias.astype(bias.dtype)
    # decompress_nm is a one-hot einsum in vals: its vjp IS the gather of
    # the dense grad at the kept positions (straight-through on idx).
    return (dx.astype(x.dtype), dvals.astype(vals.dtype),
            jnp.zeros_like(idx), dbias)


_nm_matmul_core.defvjp(_core_fwd, _core_bwd)


# int8 core: inference-only (the optimizer never trains int8 leaves)


def _nm_matmul_q_core(x, vals, idx, scales, bias, cfg, activation,
                      use_kernel, block, decode_block, force, backend):
    vals, idx = _pin_compressed(vals, idx)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    _validate_pair(vals, idx, k, cfg)
    op, plan, ctx = _route(mm, nn, k, cfg, x.dtype, use_kernel, force,
                           block, decode_block, quantized=True,
                           backend=backend)
    interp = interpret_for(backend)
    if op == "nm_matmul_decode_q":
        y2 = registry.dispatch(
            op, ctx, x2, vals, idx, scales, bias,
            cfg=cfg, plan=plan, activation=activation, interpret=interp,
        )
    else:
        y2 = registry.dispatch(
            op, ctx, x2, vals, idx, scales,
            cfg=cfg, plan=plan, interpret=interp,
        )
        y2 = _epilogue_after(y2, bias, activation)
    return y2.reshape(*lead, nn)


# ---------------------------------------------------------------------------
# dry-run routing: the public explanation surface
# ---------------------------------------------------------------------------


def explain_dispatch(x_shape, w, *, epilogue=None, dtype=None, backend=None):
    """The :class:`repro.kernels.registry.DispatchRecord` that
    ``nm_matmul(x, w)`` *would* produce for an ``x`` of shape
    ``x_shape`` — family, kernel, backend, block triple and padded
    geometry — without running anything.

    ``x_shape`` is the activation shape ``(..., K)`` (for a gather-port
    weight, ``w.axis == 1``, it is the dense B operand's ``(K, N)``).
    ``dtype`` is the activation dtype for autotune-cache lookup; it
    defaults to the weight's value dtype (the int8 family always keys on
    int8 regardless). ``backend`` overrides the policy's backend, same
    contract as :func:`nm_matmul`. Raises the same typed errors as the
    real call — including :class:`KernelForceError` for a forced weight
    whose shape cannot normalize or a forced backend this host cannot
    execute.
    """
    if not isinstance(w, (NMWeight, QNMWeight)):
        raise TypeError(
            f"explain_dispatch expects an NMWeight or QNMWeight, got "
            f"{type(w).__name__}")
    if w.axis == 1:
        from repro.kernels.indexmac_gather.ops import explain_gather

        return explain_gather(x_shape, w, backend=backend)
    _check_axis0(w, "explain_dispatch")
    resolve_epilogue(epilogue)  # validates; epilogue never changes routing
    k = x_shape[-1]
    mm = math.prod(x_shape[:-1]) if len(x_shape) > 1 else 1
    nn = w.vals.shape[1]
    _validate_pair(w.vals, w.idx, k, w.nm)
    pol = w.kernel_policy
    quantized = isinstance(w, QNMWeight)
    dtype = dtype if dtype is not None else w.vals.dtype
    be = resolve_backend(
        backend if backend is not None else getattr(pol, "backend", "auto"))
    op, plan, ctx = _route(
        mm, nn, k, w.nm, dtype, pol.mode != "off", pol.mode == "force",
        pol.block, pol.decode_block, quantized, backend=be)
    return registry.explain(op, ctx)


# ---------------------------------------------------------------------------
# positional internals (kernel-level tests) + deprecated re-export shims
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def nm_matmul_positional(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: Optional[tuple[int, int, int]] = None,
    force: bool = False,
) -> jax.Array:
    """Positional surface: y = x @ decompress(vals, idx); x: (..., K),
    vals/idx: (Kc, N). Internal (kernel-level tests / the deprecated
    ``repro.kernels.raw`` wrappers); always the prefill-shaped family —
    no decode routing, no epilogue. ``block=None`` consults the autotune
    cache; ``force=True`` skips the padding waste limit.
    """
    return _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force)


def _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force):
    vals, idx = _pin_compressed(vals, idx)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    _validate_pair(vals, idx, k, cfg)
    plan = None
    if use_kernel:
        if block is None:
            block = autotune.best_block(mm, nn, k, cfg, x.dtype)
        plan = plan_nm_matmul(mm, nn, k, cfg, tuple(block))
    ctx = registry.make_ctx(
        (mm, k, nn), nm=cfg, use_kernel=use_kernel, plan=plan,
        dtype=x.dtype, force=force,
    )
    y2 = registry.dispatch(
        "nm_matmul", ctx, x2, vals, idx,
        cfg=cfg, plan=plan, interpret=interpret_for("tpu"),
    )
    return y2.reshape(*lead, nn)


def _fwd(x, vals, idx, cfg, use_kernel, block, force):
    y = _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force)
    return y, (x, vals, idx)


def _bwd(cfg, use_kernel, block, force, res, dy):
    x, vals, idx = res
    w = decompress_nm(vals, idx, cfg, axis=0)  # (K, N)
    dy32 = dy.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", dy32, w.astype(jnp.float32)).astype(x.dtype)
    dw = jnp.einsum(
        "...k,...n->kn", x.astype(jnp.float32), dy32
    )  # dense (K, N) grad
    # gather kept positions: dvals[r, c] = dw[(r//n)*m + idx[r, c], c]
    kc, nn = vals.shape
    block_id = jnp.arange(kc, dtype=jnp.int32) // cfg.n  # (Kc,)
    grow = block_id[:, None] * cfg.m + idx.astype(jnp.int32)  # (Kc, N)
    dvals = jnp.take_along_axis(dw, grow, axis=0).astype(vals.dtype)
    return dx, dvals, jnp.zeros_like(idx)


nm_matmul_positional.defvjp(_fwd, _bwd)


def nm_matmul_q_positional(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: Optional[tuple[int, int, int]] = None,
    force: bool = False,
) -> jax.Array:
    """Positional quantized surface: y = (x @ decompress(vals, idx)) *
    scales[col]. Internal; see :func:`nm_matmul_positional`."""
    vals, idx = _pin_compressed(vals, idx)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    _validate_pair(vals, idx, k, cfg)
    plan = None
    if use_kernel:
        if block is None:
            block = autotune.best_block(mm, nn, k, cfg, jnp.int8)
        plan = plan_nm_matmul(mm, nn, k, cfg, tuple(block))
    ctx = registry.make_ctx(
        (mm, k, nn), nm=cfg, use_kernel=use_kernel, plan=plan,
        dtype=jnp.int8, force=force,
    )
    y2 = registry.dispatch(
        "nm_matmul_q", ctx, x2, vals, idx, scales,
        cfg=cfg, plan=plan, interpret=interpret_for("tpu"),
    )
    return y2.reshape(*lead, nn)

