"""Public op for the indexmac kernel: `nm_matmul`.

Dispatches to the Pallas kernel (interpret=True on CPU so the kernel body
is validated here; compiled Mosaic on real TPUs) or the jnp reference, and
defines the training backward:

  y     = x @ W,           W = decompress(vals, idx)
  dx    = dy @ W^T
  dvals = gather_{kept positions}(x^T @ dy)     (straight-through on idx)

The backward keeps the compressed representation closed under training
(compressed fine-tuning); the paper's prune->retrain flow additionally uses
masked-dense training in `repro/training`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig, decompress_nm
from repro.kernels.indexmac.kernel import nm_spmm_pallas
from repro.kernels.indexmac.ref import nm_matmul_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5)
)
def nm_matmul(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: tuple[int, int, int] = (256, 256, 2048),
) -> jax.Array:
    """y = x @ decompress(vals, idx); x: (..., K), vals/idx: (Kc, N)."""
    return _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block)


def _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block):
    import os

    if os.environ.get("REPRO_GATHER_COMPRESSED") == "1":
        # Pin the compressed operands to (None, "model") so the FSDP
        # all-gather over "data" moves the COMPRESSED bytes (vals+idx,
        # 0.375-0.75x dense) and decompression runs shard-locally — without
        # this, SPMD may decompress on the home shards and gather the
        # dense W (EXPERIMENTS.md §Perf P3).
        from repro.parallel.hints import shard_hint

        vals = shard_hint(vals, None, "model")
        idx = shard_hint(idx, None, "model")
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    bm, bn, bk = block
    nn = vals.shape[1]
    divisible = (
        mm % min(bm, mm) == 0
        and nn % min(bn, nn) == 0
        and k % min(bk, k) == 0
        and min(bk, k) % cfg.m == 0
        and (vals.shape[0] * cfg.m) % cfg.n == 0
    )
    if use_kernel and divisible and mm >= 8:
        y2 = nm_spmm_pallas(
            x2, vals, idx, cfg=cfg,
            block_m=min(bm, mm), block_n=min(bn, nn), block_k=min(bk, k),
            interpret=_on_cpu(),
        )
    else:
        y2 = nm_matmul_ref(x2, vals, idx, cfg)
    return y2.reshape(*lead, nn)


def _fwd(x, vals, idx, cfg, use_kernel, block):
    y = _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block)
    return y, (x, vals, idx)


def _bwd(cfg, use_kernel, block, res, dy):
    x, vals, idx = res
    w = decompress_nm(vals, idx, cfg, axis=0)  # (K, N)
    dy32 = dy.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", dy32, w.astype(jnp.float32)).astype(x.dtype)
    dw = jnp.einsum(
        "...k,...n->kn", x.astype(jnp.float32), dy32
    )  # dense (K, N) grad
    # gather kept positions: dvals[r, c] = dw[(r//n)*m + idx[r, c], c]
    kc, nn = vals.shape
    block_id = jnp.arange(kc, dtype=jnp.int32) // cfg.n  # (Kc,)
    grow = block_id[:, None] * cfg.m + idx.astype(jnp.int32)  # (Kc, N)
    dvals = jnp.take_along_axis(dw, grow, axis=0).astype(vals.dtype)
    return dx, dvals, jnp.zeros_like(idx)


nm_matmul.defvjp(_fwd, _bwd)
