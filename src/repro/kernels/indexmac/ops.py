"""Public ops for the indexmac kernel: `nm_matmul` (typed) and
`nm_matmul_raw` (positional compat wrapper).

``nm_matmul(x, w)`` consumes an :class:`repro.core.nmweight.NMWeight`:
the weight's own ``NMConfig`` and :class:`KernelPolicy` drive dispatch —
``off`` pins the XLA reference, ``auto`` takes the padded Pallas kernel
when the shape normalizes within the waste limit, ``force`` ignores the
limit. ``nm_matmul_raw(x, vals, idx, cfg, ...)`` keeps the old
positional surface for benchmarks and kernel-level tests.

Dispatch goes through the kernel registry (`repro.kernels.registry`):
the padded Pallas implementation normalizes arbitrary (M, K, N) up to a
tileable geometry — zero-padding x and the compressed (vals, idx) pair
and slicing the output — so real transformer shapes execute the kernel
(interpret=True on CPU so the kernel body is validated here; compiled
Mosaic on real TPUs) instead of silently falling back to the dense
reference. Blocks come from the weight's policy, the caller, the
autotune cache, or the default triple, in that order. The reference
implementation remains registered as the priority-0 fallback.

Training backward (unchanged by padding — it works on logical shapes):

  y     = x @ W,           W = decompress(vals, idx)
  dx    = dy @ W^T
  dvals = gather_{kept positions}(x^T @ dy)     (straight-through on idx)

The backward keeps the compressed representation closed under training
(compressed fine-tuning); the paper's prune->retrain flow additionally uses
masked-dense training in `repro/training`.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nmweight import NMWeight
from repro.core.sparsity import NMConfig, decompress_nm
from repro.kernels import autotune, registry
from repro.kernels.indexmac.kernel import nm_spmm_pallas, nm_spmm_pallas_q
from repro.kernels.indexmac.ref import nm_matmul_q_ref, nm_matmul_ref
from repro.kernels.padding import (
    PadPlan,
    pad_nm_operands,
    pad_waste_limit,
    plan_nm_matmul,
)
from repro.quant.qnmweight import QNMWeight


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def run_pallas_padded(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Pad operands to the plan, run the kernel, slice the logical output."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    bm, bn, bk = plan.block
    y = nm_spmm_pallas(
        xp, vp, ip, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


def _pallas_supports(ctx: dict) -> Optional[str]:
    if not ctx["use_kernel"]:
        return "use_kernel=False"
    plan = ctx["plan"]
    if plan is None:
        return "shape not normalizable"
    if ctx.get("force"):
        return None  # KernelPolicy "force": waste limit ignored
    limit = pad_waste_limit()
    if plan.waste > limit:
        return f"padding waste {plan.waste:.2f}x > limit {limit:.2f}x"
    return None


@registry.register("nm_matmul", "pallas_padded", priority=100,
                   supports=_pallas_supports, uses_plan=True)
def _run_pallas_impl(x2, vals, idx, *, cfg, plan, interpret):
    return run_pallas_padded(
        x2, vals, idx, cfg=cfg, plan=plan, interpret=interpret
    )


@registry.register("nm_matmul", "reference", priority=0)
def _run_ref_impl(x2, vals, idx, *, cfg, plan, interpret):
    return nm_matmul_ref(x2, vals, idx, cfg)


# ---------------------------------------------------------------------------
# quantized (int8-value) family — its own dispatch op and autotune keys
# ---------------------------------------------------------------------------


def run_pallas_padded_q(
    x2: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    *,
    cfg: NMConfig,
    plan: PadPlan,
    interpret: bool,
) -> jax.Array:
    """Quantized sibling of :func:`run_pallas_padded`: pads the int8
    operands (appended columns get unit scales — they are sliced away)
    and runs the dequantizing kernel."""
    xp, vp, ip = pad_nm_operands(x2, vals, idx, plan, cfg)
    sp = scales
    if plan.pn > plan.n:
        sp = jnp.pad(scales, (0, plan.pn - plan.n), constant_values=1.0)
    bm, bn, bk = plan.block
    y = nm_spmm_pallas_q(
        xp, vp, ip, sp, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret,
    )
    return y[: plan.m, : plan.n]


@registry.register("nm_matmul_q", "pallas_padded_q", priority=100,
                   supports=_pallas_supports, uses_plan=True)
def _run_pallas_q_impl(x2, vals, idx, scales, *, cfg, plan, interpret):
    return run_pallas_padded_q(
        x2, vals, idx, scales, cfg=cfg, plan=plan, interpret=interpret
    )


@registry.register("nm_matmul_q", "reference_q", priority=0)
def _run_ref_q_impl(x2, vals, idx, scales, *, cfg, plan, interpret):
    return nm_matmul_q_ref(x2, vals, idx, scales, cfg)


def nm_matmul(x: jax.Array, w, *,
              block: Optional[tuple[int, int, int]] = None) -> jax.Array:
    """y = x @ densify(w); x: (..., K), w: an NMWeight or QNMWeight
    compressed along its axis 0 (the contraction dim).

    The weight's own metadata drives dispatch: ``w.nm`` is the pattern,
    ``w.kernel_policy`` picks reference/Pallas and the block triple, and
    the weight's *type* picks the family — int8 weights route to the
    dequantizing kernel (``nm_matmul_q``), which has its own autotune
    keys. ``block`` overrides the policy's block for this call
    (benchmarks).
    """
    if isinstance(w, QNMWeight):
        return nm_matmul_q(x, w, block=block)
    if not isinstance(w, NMWeight):
        raise TypeError(
            f"nm_matmul expects an NMWeight or QNMWeight, got "
            f"{type(w).__name__}; wrap compressed operands with "
            "repro.api.sparsify / repro.api.quantize, or use "
            "nm_matmul_raw for positional (vals, idx, cfg) calls"
        )
    if w.axis != 0:
        raise ValueError(
            f"nm_matmul needs the weight compressed along axis 0 (the "
            f"contraction dim of y = x @ W); got axis={w.axis}"
        )
    pol = w.kernel_policy
    blk = block if block is not None else pol.block
    return nm_matmul_raw(x, w.vals, w.idx, w.nm, pol.mode != "off", blk,
                         pol.mode == "force")


def nm_matmul_q(x: jax.Array, w: QNMWeight, *,
                block: Optional[tuple[int, int, int]] = None) -> jax.Array:
    """y = x @ densify(w) for an int8 :class:`QNMWeight` (inference
    path; the optimizer never trains int8 leaves). Dispatch mirrors
    :func:`nm_matmul` but through the ``nm_matmul_q`` registry family,
    whose autotune cache keys carry the int8 value dtype."""
    if not isinstance(w, QNMWeight):
        raise TypeError(
            f"nm_matmul_q expects a QNMWeight, got {type(w).__name__}; "
            "produce one with repro.api.quantize"
        )
    if w.axis != 0:
        raise ValueError(
            f"nm_matmul_q needs the weight compressed along axis 0 (the "
            f"contraction dim of y = x @ W); got axis={w.axis}"
        )
    pol = w.kernel_policy
    blk = block if block is not None else pol.block
    return nm_matmul_q_raw(x, w.vals, w.idx, w.scales, w.nm,
                           pol.mode != "off", blk, pol.mode == "force")


def nm_matmul_q_raw(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: Optional[tuple[int, int, int]] = None,
    force: bool = False,
) -> jax.Array:
    """Positional quantized surface: y = (x @ decompress(vals, idx)) *
    scales[col]; x: (..., K), vals/idx: int8 (Kc, N), scales: (N,).

    ``block=None`` consults the autotune cache under the int8 family's
    own keys (value dtype int8 — never shared with the float sweep).
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    if vals.shape[0] * cfg.m != k * cfg.n:
        raise ValueError(
            f"vals rows {vals.shape[0]} inconsistent with K={k} and {cfg.tag}"
        )
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    plan = None
    if use_kernel:
        if block is None:
            block = autotune.best_block(mm, nn, k, cfg, jnp.int8)
        plan = plan_nm_matmul(mm, nn, k, cfg, tuple(block))
    ctx = registry.make_ctx(
        (mm, k, nn), nm=cfg, use_kernel=use_kernel, plan=plan,
        dtype=jnp.int8, force=force,
    )
    y2 = registry.dispatch(
        "nm_matmul_q", ctx, x2, vals, idx, scales,
        cfg=cfg, plan=plan, interpret=_on_cpu(),
    )
    return y2.reshape(*lead, nn)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def nm_matmul_raw(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    cfg: NMConfig,
    use_kernel: bool = True,
    block: Optional[tuple[int, int, int]] = None,
    force: bool = False,
) -> jax.Array:
    """Positional compat surface: y = x @ decompress(vals, idx);
    x: (..., K), vals/idx: (Kc, N).

    ``block=None`` consults the autotune cache (see
    ``repro.kernels.autotune``) and falls back to the default triple.
    ``force=True`` skips the padding waste limit (KernelPolicy "force").
    """
    return _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force)


def _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force):
    if os.environ.get("REPRO_GATHER_COMPRESSED") == "1":
        # Pin the compressed operands to (None, "model") so the FSDP
        # all-gather over "data" moves the COMPRESSED bytes (vals+idx,
        # 0.375-0.75x dense) and decompression runs shard-locally — without
        # this, SPMD may decompress on the home shards and gather the
        # dense W (EXPERIMENTS.md §Perf P3).
        from repro.parallel.hints import shard_hint_leaves

        vals, idx = shard_hint_leaves((vals, idx), None, "model")
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mm = x2.shape[0]
    nn = vals.shape[1]
    if vals.shape[0] * cfg.m != k * cfg.n:
        raise ValueError(
            f"vals rows {vals.shape[0]} inconsistent with K={k} and {cfg.tag}"
        )
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    plan = None
    if use_kernel:  # skip block resolution (cache I/O, possible inline
        # sweep under REPRO_AUTOTUNE=1) when the kernel can't be taken
        if block is None:
            block = autotune.best_block(mm, nn, k, cfg, x.dtype)
        plan = plan_nm_matmul(mm, nn, k, cfg, tuple(block))
    ctx = registry.make_ctx(
        (mm, k, nn), nm=cfg, use_kernel=use_kernel, plan=plan,
        dtype=x.dtype, force=force,
    )
    y2 = registry.dispatch(
        "nm_matmul", ctx, x2, vals, idx,
        cfg=cfg, plan=plan, interpret=_on_cpu(),
    )
    return y2.reshape(*lead, nn)


def _fwd(x, vals, idx, cfg, use_kernel, block, force):
    y = _nm_matmul_fwd_impl(x, vals, idx, cfg, use_kernel, block, force)
    return y, (x, vals, idx)


def _bwd(cfg, use_kernel, block, force, res, dy):
    x, vals, idx = res
    w = decompress_nm(vals, idx, cfg, axis=0)  # (K, N)
    dy32 = dy.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", dy32, w.astype(jnp.float32)).astype(x.dtype)
    dw = jnp.einsum(
        "...k,...n->kn", x.astype(jnp.float32), dy32
    )  # dense (K, N) grad
    # gather kept positions: dvals[r, c] = dw[(r//n)*m + idx[r, c], c]
    kc, nn = vals.shape
    block_id = jnp.arange(kc, dtype=jnp.int32) // cfg.n  # (Kc,)
    grow = block_id[:, None] * cfg.m + idx.astype(jnp.int32)  # (Kc, N)
    dvals = jnp.take_along_axis(dw, grow, axis=0).astype(vals.dtype)
    return dx, dvals, jnp.zeros_like(idx)


nm_matmul_raw.defvjp(_fwd, _bwd)
