from repro.kernels.indexmac.ops import nm_matmul, nm_matmul_q  # noqa: F401
