from repro.kernels.indexmac.ops import nm_matmul  # noqa: F401
