"""Pallas TPU kernel for N:M structured-sparse matmul (the IndexMAC port).

Computes  y[M, N] = x[M, K] @ W  with W stored compressed along K:
  vals[Kc, N] (x dtype), idx[Kc, N] (int8 in [0, m)),  Kc = K * n / m.

TPU adaptation of the paper's vindexmac + B-stationary dataflow
(DESIGN.md §2/§4):

* The *dense* operand tile is pinned in VMEM: the grid is (mi, ni, ki) with
  k innermost; when the K dimension fits a single k-block (the common case
  for transformer projections, K <= 8k bf16), the x block index is constant
  across the whole n sweep, so Pallas's pipeline loads it once and keeps it
  resident — the paper's "pre-load tile of B in the register file".
* The compressed operand is streamed from HBM at (n/m)·(1 + 0.5) of the
  dense byte volume (values + int8 indices) — the eliminated memory traffic
  the paper measures in Fig. 6.
* The bounded indices are expanded *inside VMEM* into a dense tile via
  iota-compare selects (the indirect-register-read analogue: a local,
  bounded indexed operation, never an HBM gather) and handed to the MXU.

Accumulation is fp32 in a VMEM scratch buffer, output written on the last
k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.sparsity import NMConfig


def _decompress_block(v, ii, n: int, m: int):
    """Expand a compressed (bkc, bn) block to dense (bk, bn), bk = bkc*m/n.

    Dense row d takes contributions from compressed rows (d//m)*n + s,
    s in [0, n): w[d, c] = sum_s v[(d//m)*n+s, c] * (idx[...]==d%m).
    Implemented with 2D-friendly ops (strided slice + repeat + iota select)
    so it lowers cleanly in Mosaic.
    """
    bkc, bn = v.shape
    bk = bkc * m // n
    jpos = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % m
    w = jnp.zeros((bk, bn), dtype=jnp.float32)
    for s in range(n):
        v_s = v[s::n, :]  # (bkc/n, bn) = (bk/m, bn)
        i_s = ii[s::n, :].astype(jnp.int32)
        v_rep = jnp.repeat(v_s, m, axis=0)  # (bk, bn)
        i_rep = jnp.repeat(i_s, m, axis=0)
        w = w + jnp.where(i_rep == jpos, v_rep.astype(jnp.float32), 0.0)
    return w


def _nm_spmm_kernel(x_ref, vals_ref, idx_ref, o_ref, acc_ref, *, n, m, nk, out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_block(vals_ref[...], idx_ref[...], n, m)  # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        x, w, preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _nm_spmm_q_kernel(
    x_ref, vals_ref, idx_ref, scales_ref, o_ref, acc_ref, *, n, m, nk, out_dtype
):
    """int8-value variant: the compressed tile streams as one byte per
    kept value; dequantization happens in-register — the int8 block is
    expanded to a dense f32 tile inside VMEM (the int8 -> f32 cast rides
    the same iota-compare selects as the float path) and the per-output-
    channel scales multiply the f32 accumulator once at writeback, so
    the inner loop never touches a float weight operand."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # _decompress_block casts the int8 values to f32 in-register: exact
    # (|q| <= 127 << 2^24), so the MXU sees the integer lattice scaled
    # only at the end.
    w = _decompress_block(vals_ref[...], idx_ref[...], n, m)  # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        x, w, preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _done():
        # scales: (1, bn) f32, one per output column — constant over K,
        # so one multiply per output element at writeback.
        o_ref[...] = (acc_ref[...] * scales_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def nm_spmm_pallas_q(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    scales: jax.Array,
    *,
    cfg: NMConfig,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 2048,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = (x @ decompress(int8 vals, idx)) * scales[col].

    Same tiling contract as :func:`nm_spmm_pallas`; additionally
    ``vals`` must be int8 and ``scales`` float32 of shape (N,).
    """
    mm, kk = x.shape
    kc, nn = vals.shape
    if kc * cfg.m != kk * cfg.n:
        raise ValueError(f"vals rows {kc} inconsistent with K={kk} and {cfg.tag}")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    if vals.dtype != jnp.int8:
        raise ValueError(f"quantized kernel needs int8 vals, got {vals.dtype}")
    if scales.shape != (nn,):
        raise ValueError(
            f"scales shape {scales.shape} != (N,) = ({nn},)")
    block_k = min(block_k, kk)
    block_m = min(block_m, mm)
    block_n = min(block_n, nn)
    if kk % block_k or block_k % cfg.m:
        raise ValueError(f"K={kk} block_k={block_k} m={cfg.m} not tileable")
    if mm % block_m or nn % block_n:
        raise ValueError(f"M={mm}/N={nn} not divisible by blocks {block_m}/{block_n}")
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k
    bkc = block_k * cfg.n // cfg.m

    grid = (mm // block_m, nn // block_n, nk)
    kernel = functools.partial(
        _nm_spmm_q_kernel, n=cfg.n, m=cfg.m, nk=nk, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkc, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((bkc, block_n), lambda i, j, k: (k, j)),
            # per-column scales: tiny, constant over the k sweep.
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, vals, idx, scales.astype(jnp.float32).reshape(1, nn))


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def nm_spmm_pallas(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    cfg: NMConfig,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 2048,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ decompress(vals, idx). See module docstring.

    Shape requirements (enforced): M % block_m == 0, N % block_n == 0,
    K % block_k == 0 (block_k clamped to K), block_k % m == 0.
    """
    mm, kk = x.shape
    kc, nn = vals.shape
    if kc * cfg.m != kk * cfg.n:
        raise ValueError(f"vals rows {kc} inconsistent with K={kk} and {cfg.tag}")
    if idx.shape != vals.shape:
        raise ValueError("idx/vals shape mismatch")
    block_k = min(block_k, kk)
    block_m = min(block_m, mm)
    block_n = min(block_n, nn)
    if kk % block_k or block_k % cfg.m:
        raise ValueError(f"K={kk} block_k={block_k} m={cfg.m} not tileable")
    if mm % block_m or nn % block_n:
        raise ValueError(f"M={mm}/N={nn} not divisible by blocks {block_m}/{block_n}")
    out_dtype = out_dtype or x.dtype
    nk = kk // block_k
    bkc = block_k * cfg.n // cfg.m

    grid = (mm // block_m, nn // block_n, nk)
    kernel = functools.partial(
        _nm_spmm_kernel, n=cfg.n, m=cfg.m, nk=nk, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # dense operand: constant across the n sweep when nk == 1 -> the
            # pipeline keeps it VMEM-resident (paper's stationary tile).
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkc, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((bkc, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, vals, idx)
