"""Fused-epilogue spec for the compressed-GEMM entry point.

A decode-step GEMM is memory-bound: the matmul output is tiny (M <= 8
rows), so any separate XLA op that re-reads it — dequant scale, bias
add, activation — costs another round trip over the output bytes plus
kernel-launch latency that dominates at M=1. :class:`Epilogue` names
the two things a projection does to its accumulator (``bias`` add and a
pointwise ``activation``) so the Pallas decode kernels can run them at
accumulator writeback instead; the reference implementations apply the
*same* composition, which keeps kernel-vs-reference parity exact on the
integer lattice.

The composition contract every implementation follows::

    y32 = f32(x) @ f32(densify(w))          # f32 accumulation
    y32 = y32 * scales                      # int8 family only
    y32 = y32 + f32(bias)                   # when bias is not None
    y32 = ACTIVATIONS[activation](y32)      # when activation is not None
    y   = y32.astype(out_dtype)

``activation`` is a *name* (static, part of the compiled kernel), never
a callable — kernels specialize on it. ``bias`` is a ``(N,)`` array
operand (traced like any other).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["ACTIVATIONS", "Epilogue", "apply_epilogue_f32", "resolve_epilogue"]

ACTIVATIONS = {
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu_sq": lambda y: jnp.square(jnp.maximum(y, 0.0)),
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """What a projection fuses into the GEMM writeback.

    bias: optional ``(N,)`` array added to the f32 accumulator.
    activation: optional name from :data:`ACTIVATIONS`, applied after
      the bias add (still in f32, before the output-dtype cast).
    """

    bias: Optional[jax.Array] = None
    activation: Optional[str] = None

    def __post_init__(self):
        if self.activation is not None and self.activation not in ACTIVATIONS:
            raise ValueError(
                f"unknown epilogue activation {self.activation!r}; known: "
                f"{sorted(ACTIVATIONS)}")


def resolve_epilogue(epilogue: Optional[Epilogue]):
    """Destructure into the (bias operand, static activation name) pair
    the kernels consume; ``None`` means the identity epilogue."""
    if epilogue is None:
        return None, None
    if not isinstance(epilogue, Epilogue):
        raise TypeError(
            f"epilogue must be an Epilogue or None, got "
            f"{type(epilogue).__name__}")
    return epilogue.bias, epilogue.activation


def apply_epilogue_f32(y32: jax.Array, bias: Optional[jax.Array],
                       activation: Optional[str]) -> jax.Array:
    """The shared f32 composition — reference impls and the non-decode
    fallback call this so 'fused' and 'unfused' are the same arithmetic."""
    if bias is not None:
        y32 = y32 + bias.astype(jnp.float32)
    if activation is not None:
        y32 = ACTIVATIONS[activation](y32)
    return y32
