"""Deprecated positional kernel surface.

These wrappers keep the pre-typed ``(vals, idx, cfg, ...)`` call shape
alive for exactly one release. Every call emits a
:class:`DeprecationWarning` whose message starts with
``repro.kernels.raw`` — CI promotes those to errors (see pyproject
``filterwarnings``), so no new in-repo call site can appear. The
API-freeze test in ``tests/test_api.py`` additionally bans the raw
names outside this module (the old in-package re-export shims are
gone and must stay gone).

Migration:

==================================  =====================================
old call                            new call
==================================  =====================================
``nm_matmul_raw(x, vals, idx,       ``repro.api.nm_matmul(x, w)`` with
cfg, ...)``                         ``w = sparsify(...)`` (an NMWeight
                                    carrying nm + KernelPolicy)
``nm_matmul_q_raw(x, vals, idx,     ``repro.api.nm_matmul(x, qw)`` with
scales, cfg, ...)``                 ``qw = quantize(...)`` (a QNMWeight;
                                    type selects the int8 family)
``indexmac_gather_spmm(vals, idx,   ``repro.api.indexmac_gather(w, b)``
b, cfg, ...)``                      with an axis-1 NMWeight
==================================  =====================================
"""
from __future__ import annotations

import warnings


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.raw.{name} is deprecated and will be removed in "
        f"the next release; use {repl}",
        DeprecationWarning,
        stacklevel=3,
    )


def nm_matmul_raw(x, vals, idx, cfg, use_kernel=True, block=None,
                  force=False):
    """Deprecated: use ``repro.api.nm_matmul(x, w)`` with a typed
    :class:`NMWeight` (``repro.api.sparsify``)."""
    from repro.kernels.indexmac import ops

    _warn("nm_matmul_raw",
          "repro.api.nm_matmul(x, w) with an NMWeight from sparsify()")
    return ops.nm_matmul_positional(x, vals, idx, cfg, use_kernel, block,
                                    force)


def nm_matmul_q_raw(x, vals, idx, scales, cfg, use_kernel=True, block=None,
                    force=False):
    """Deprecated: use ``repro.api.nm_matmul(x, qw)`` with a typed
    :class:`QNMWeight` (``repro.api.quantize``)."""
    from repro.kernels.indexmac import ops

    _warn("nm_matmul_q_raw",
          "repro.api.nm_matmul(x, qw) with a QNMWeight from quantize()")
    return ops.nm_matmul_q_positional(x, vals, idx, scales, cfg, use_kernel,
                                      block, force)


def indexmac_gather_spmm(vals, idx, b, cfg, use_kernel=True, block=None):
    """Deprecated: use ``repro.api.indexmac_gather(w, b)`` with an
    axis-1 :class:`NMWeight`."""
    from repro.kernels.indexmac_gather import ops

    _warn("indexmac_gather_spmm",
          "repro.api.indexmac_gather(w, b) with an axis-1 NMWeight")
    return ops.indexmac_gather_positional(
        vals, idx, b, cfg, use_kernel, block or ops.DEFAULT_BLOCK)
