"""Shape normalization for the N:M Pallas kernel.

The kernel needs M/N/K divisible by its blocks, block_k a multiple of the
sparsity block M, and TPU-friendly tile granularity (sublane 8, lane 128).
Real transformer shapes rarely oblige — so instead of falling back to the
dense reference, the op pads up to the nearest tileable geometry:

* x gets zero rows (M) and zero columns (K),
* vals/idx get zero rows (whole compressed K-blocks) and zero columns (N),
* the kernel output is sliced back to the logical (M, N).

Zero-padding is exact: a zero value kills its index's contribution
regardless of the index byte, zero x columns multiply zero W rows, and
fp32 accumulation of exact zeros is lossless — the padded kernel result
equals the unpadded reference bit-for-bit on the logical slice.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import NMConfig, pad_compressed_kn

_SUBLANE = 8  # second-to-last dim granularity (fp32)
_LANE = 128  # last dim granularity

# Above this ratio of padded to logical output work the dense reference
# is assumed cheaper than the mostly-empty kernel launch.
_DEFAULT_WASTE_LIMIT = 4.0

# The decode kernel family pads M to one sublane by design (that is the
# family's whole point), so its limit looks at N/K padding only — and is
# looser: a decode GEMM is memory-bound, so lane padding on a narrow
# projection (e.g. a 16-wide KV head padded to one 128 lane) still beats
# a separate XLA launch per epilogue op.
_DEFAULT_DECODE_WASTE_LIMIT = 16.0


def pad_waste_limit() -> float:
    return float(os.environ.get("REPRO_PAD_WASTE_LIMIT", _DEFAULT_WASTE_LIMIT))


def decode_pad_waste_limit() -> float:
    return float(os.environ.get("REPRO_DECODE_PAD_WASTE_LIMIT",
                                _DEFAULT_DECODE_WASTE_LIMIT))


def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b


@dataclasses.dataclass(frozen=True)
class PadPlan:
    """Resolved geometry: clamped blocks + padded dims for one call."""

    m: int
    n: int
    k: int
    pm: int
    pn: int
    pk: int
    block: tuple  # (block_m, block_n, block_k), each divides its padded dim

    @property
    def padded_shape(self) -> tuple:
        return (self.pm, self.pk, self.pn)

    @property
    def needs_padding(self) -> bool:
        return (self.pm, self.pn, self.pk) != (self.m, self.n, self.k)

    @property
    def waste(self) -> float:
        """Padded / logical output-work ratio (1.0 = no padding)."""
        return (self.pm * self.pn * self.pk) / (self.m * self.n * self.k)

    @property
    def waste_nk(self) -> float:
        """Waste over N/K only — the decode family's metric (its M
        padding to one sublane is intrinsic, not a routing signal)."""
        return (self.pn * self.pk) / (self.n * self.k)


def plan_nm_matmul(
    m: int, n: int, k: int, cfg: NMConfig, block: tuple
) -> Optional[PadPlan]:
    """Clamp ``block`` to the (padded) problem and compute padded dims.

    Returns None when no legal geometry exists (degenerate dims).
    K blocks must satisfy two granularities at once: block_k % cfg.m == 0
    (whole sparsity blocks per tile) and the *compressed* tile height
    block_k * n/m a sublane multiple — both folded into ``step_k``.
    """
    if m <= 0 or n <= 0 or k <= 0:
        return None
    bm, bn, bk = block
    step_k = cfg.m * (_SUBLANE // math.gcd(cfg.n, _SUBLANE))
    bm = max(_SUBLANE, min(_round_up(bm, _SUBLANE), _round_up(m, _SUBLANE)))
    bn = max(_LANE, min(_round_up(bn, _LANE), _round_up(n, _LANE)))
    bk = max(step_k, min(bk - bk % step_k, _round_up(k, step_k)))
    return PadPlan(
        m=m, n=n, k=k,
        pm=_round_up(m, bm), pn=_round_up(n, bn), pk=_round_up(k, bk),
        block=(bm, bn, bk),
    )


def pad_nm_operands(
    x2: jax.Array, vals: jax.Array, idx: jax.Array, plan: PadPlan, cfg: NMConfig
):
    """Zero-pad (x, vals, idx) to the plan's geometry."""
    if plan.pk > plan.k or plan.pm > plan.m:
        x2 = jnp.pad(
            x2, ((0, plan.pm - plan.m), (0, plan.pk - plan.k))
        )
    kc_pad = plan.pk * cfg.n // cfg.m
    vals, idx = pad_compressed_kn(vals, idx, kc_pad=kc_pad, n_pad=plan.pn)
    return x2, vals, idx
