"""Kernel registry: named implementations per op, priority dispatch,
and an inspectable record of every routing decision.

Ops (``nm_matmul``, ``indexmac_gather``) register candidate
implementations with a ``supports(ctx) -> None | str`` predicate (None
means "I can run this"; a string is the human-readable reason it
cannot). ``dispatch`` walks candidates in descending priority, runs the
first supported one, and appends a :class:`DispatchRecord` to a bounded
history — tests and the serving engine use the record to assert *which*
path executed (e.g. that an odd transformer shape really hit the padded
Pallas kernel rather than silently falling back to the dense reference).

Dispatch happens at trace time: under ``jax.jit`` one record is written
per compilation, not per call — the routing is shape-static, so one
record per compiled shape is the complete story.

Every implementation is registered for a **backend** (``"tpu"``,
``"gpu"``, or ``"any"`` for the backend-neutral XLA references); the
dispatch context carries the resolved backend of the call (see
:mod:`repro.kernels.backend`) and implementations registered for a
*different* backend are filtered out silently — they are not
"unsupported on this call", they are a different lowering of the same
family, so they never pollute the record's ``reason`` string.

Alongside the bounded record history the registry keeps **per-family
dispatch counters** (``dispatch_counts``): a ``(op, impl, backend) ->
count`` map that never evicts, so tests assert "the decode family
dispatched N times on the tpu backend and the reference route zero
times" without sniffing the record list. When observability is enabled
(:mod:`repro.obs`) every routing decision also increments the
``kernel_dispatch_total{op=,impl=,backend=}`` metric.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Optional

from repro import obs as _obs


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    """One routing decision (newest-last in the history).

    Public surface (re-exported as ``repro.api.DispatchRecord``): tests,
    benchmarks and fleet monitoring consume these fields — treat them as
    frozen. ``repro.api.explain_dispatch`` returns the same shape for a
    dry-run routing query (no kernel executed).
    """

    op: str  # dispatch family, e.g. "nm_matmul_decode_q"
    impl: str  # chosen implementation, e.g. "pallas_decode_q"
    shape: tuple  # logical (M, K, N)
    padded: Optional[tuple]  # (M', K', N') when the impl padded, else None
    block: Optional[tuple]  # (block_m, block_n, block_k) when applicable
    reason: str  # why higher-priority impls were skipped ("" if none)
    backend: str = "tpu"  # resolved kernel backend the call routed under


class KernelForceError(RuntimeError):
    """KernelPolicy("force") demanded the Pallas kernel but the shape
    cannot normalize to any legal kernel geometry. Raised instead of a
    silent fall-through to the reference path — a forced weight that
    quietly serves XLA timings is a corrupted benchmark, not a fallback.
    """


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    op: str
    name: str
    priority: int
    supports: Callable[[dict], Optional[str]]
    run: Callable[..., Any]
    uses_plan: bool = False  # True: records carry ctx["plan"] geometry
    backend: str = "any"  # "tpu" | "gpu" | "any" (backend-neutral refs)


_LOCK = threading.Lock()
_IMPLS: dict[str, list[KernelImpl]] = {}
_HISTORY: collections.deque[DispatchRecord] = collections.deque(maxlen=256)
_COUNTS: collections.Counter = collections.Counter()  # (op, impl, backend) -> n


def make_ctx(shape, *, nm, use_kernel: bool, plan=None, dtype=None,
             force: bool = False, backend: str = "tpu", **extra) -> dict:
    """Dispatch context for a compressed-GEMM op.

    ``shape`` is the logical (M, K, N); ``nm`` the NMConfig of the
    compressed operand; ``force=True`` tells padded impls to ignore the
    waste limit (KernelPolicy mode "force"); ``backend`` is the
    *resolved* kernel backend of the call (see
    :mod:`repro.kernels.backend` — never ``"auto"`` here). Extra keys
    (e.g. the gather port's ``tileable``) pass through to ``supports``
    predicates.
    """
    return {"shape": tuple(shape), "cfg": nm, "use_kernel": use_kernel,
            "plan": plan, "dtype": dtype, "force": force,
            "backend": backend, **extra}


def weight_ctx(w, shape, *, plan=None, dtype=None, backend=None,
               **extra) -> dict:
    """Dispatch context derived from a typed weight node's own metadata
    (:class:`NMWeight` or its quantized sibling — anything carrying
    ``nm`` and ``kernel_policy``) — the weight, not the call site,
    decides nm / kernel policy / backend. ``backend`` overrides the
    policy's (already-resolved callers); ``None`` resolves the policy's
    static field here."""
    from repro.kernels.backend import resolve_backend

    pol = w.kernel_policy
    if backend is None:
        backend = resolve_backend(getattr(pol, "backend", "auto"))
    return make_ctx(shape, nm=w.nm, use_kernel=pol.mode != "off",
                    plan=plan, dtype=dtype, force=pol.mode == "force",
                    backend=backend, **extra)


def register(
    op: str,
    name: str,
    *,
    priority: int = 0,
    supports: Callable[[dict], Optional[str]] = lambda ctx: None,
    uses_plan: bool = False,
    backend: str = "any",
):
    """Decorator registering ``fn`` as implementation ``name`` of ``op``
    for ``backend`` (``"any"`` = backend-neutral, e.g. XLA references)."""

    def deco(fn):
        impl = KernelImpl(op, name, priority, supports, fn, uses_plan,
                          backend)
        with _LOCK:
            impls = [i for i in _IMPLS.get(op, ()) if i.name != name]
            impls.append(impl)
            impls.sort(key=lambda i: -i.priority)
            _IMPLS[op] = impls
        return fn

    return deco


def implementations(op: str) -> tuple[KernelImpl, ...]:
    with _LOCK:
        return tuple(_IMPLS.get(op, ()))


def dispatch(op: str, ctx: dict, *args, **kwargs):
    """Run the highest-priority supported implementation of ``op``.

    ``ctx`` must carry ``shape=(M, K, N)``; when the chosen impl is a
    padded kernel, ``ctx["plan"]`` (a PadPlan) supplies the padded
    geometry recorded alongside. Implementations registered for a
    different backend than ``ctx["backend"]`` are filtered silently
    (they are a parallel lowering, not a fallback reason).
    """
    backend = ctx.get("backend", "tpu")
    skipped = []
    for impl in implementations(op):
        if impl.backend not in ("any", backend):
            continue
        why = impl.supports(ctx)
        if why is not None:
            skipped.append(f"{impl.name}: {why}")
            continue
        out = impl.run(*args, **kwargs)
        plan = ctx.get("plan")
        uses_plan = plan is not None and impl.uses_plan
        _record(
            DispatchRecord(
                op=op,
                impl=impl.name,
                shape=tuple(ctx.get("shape", ())),
                padded=plan.padded_shape if uses_plan else None,
                block=plan.block if uses_plan else None,
                reason="; ".join(skipped),
                backend=backend,
            )
        )
        return out
    raise LookupError(
        f"no implementation of {op!r} supports this call on backend "
        f"{backend!r}: {'; '.join(skipped)}"
    )


def explain(op: str, ctx: dict) -> DispatchRecord:
    """The :class:`DispatchRecord` ``dispatch`` *would* write for this
    context, without running anything — the dry-run behind
    ``repro.api.explain_dispatch``. Raises LookupError when no
    implementation supports the call (same contract as dispatch)."""
    backend = ctx.get("backend", "tpu")
    skipped = []
    for impl in implementations(op):
        if impl.backend not in ("any", backend):
            continue
        why = impl.supports(ctx)
        if why is not None:
            skipped.append(f"{impl.name}: {why}")
            continue
        plan = ctx.get("plan")
        uses_plan = plan is not None and impl.uses_plan
        return DispatchRecord(
            op=op,
            impl=impl.name,
            shape=tuple(ctx.get("shape", ())),
            padded=plan.padded_shape if uses_plan else None,
            block=plan.block if uses_plan else None,
            reason="; ".join(skipped),
            backend=backend,
        )
    raise LookupError(
        f"no implementation of {op!r} supports this call on backend "
        f"{backend!r}: {'; '.join(skipped)}"
    )


def _record(rec: DispatchRecord) -> None:
    with _LOCK:
        _HISTORY.append(rec)
        _COUNTS[(rec.op, rec.impl, rec.backend)] += 1
    bundle = _obs.get_obs()
    if bundle is not None:
        bundle.metrics.inc("kernel_dispatch_total", op=rec.op,
                           impl=rec.impl, backend=rec.backend)


def last_dispatch(op: Optional[str] = None) -> Optional[DispatchRecord]:
    """Most recent record (for ``op`` if given), or None."""
    with _LOCK:
        for rec in reversed(_HISTORY):
            if op is None or rec.op == op:
                return rec
    return None


def dispatch_history(op: Optional[str] = None) -> list[DispatchRecord]:
    with _LOCK:
        return [r for r in _HISTORY if op is None or r.op == op]


def dispatch_counts(op_prefix: Optional[str] = None,
                    backend: Optional[str] = None) -> dict:
    """Cumulative ``(op, impl, backend) -> count`` of every routing
    decision made since process start (or :func:`clear_history`). Unlike
    the bounded record history this never evicts — the supported way for
    tests and monitoring to assert which families executed on which
    backend (e.g. decode-family count > 0, reference-route count == 0,
    everything under ``backend="gpu"``)."""
    with _LOCK:
        return {k: v for k, v in _COUNTS.items()
                if (op_prefix is None or k[0].startswith(op_prefix))
                and (backend is None or k[2] == backend)}


def clear_history() -> None:
    """Reset both the bounded record history and the dispatch counters."""
    with _LOCK:
        _HISTORY.clear()
        _COUNTS.clear()
