"""Bench regression gate: fail when a timed row regresses vs baseline.

Compares ``BENCH_results.json`` (fresh run) against the checked-in
``benchmarks/BENCH_baseline.json``. Every shared *timed* row — the
``fig4/5/6_measured_*`` / ``tpu_kernel_*`` families and the serving
throughput families ``serve_decode_*`` / ``serve_paged_decode_*`` (us
per generated token = inverse tokens/sec) — is gated at the 1.5x
threshold on its **share of the total gated time**:

    ratio_i = (new_i / sum(new)) / (base_i / sum(base))

Machine speed cancels exactly in that quotient (both runs are divided by
their own totals), so the gate compares the *shape* of the timing
profile — one kernel path getting slower relative to the rest — and is
robust to CI runners of different speeds and to process-level noise that
scales all timings together. A *uniform* slowdown is invisible to
self-normalization, so the ``bench_calibration`` row (a fixed Pallas
kernel call timed in the same process) additionally guards the total at
a deliberately loose 3x (per-process timing variance on shared runners
makes a tight absolute threshold flaky). The paged engine's
dimensionless rate rows (``serve_paged_hitrate_*`` prefix-cache hit
rate, ``serve_paged_util_*`` pool utilization) gate on a *minimum*
instead — higher is better and machine speed does not move a rate, so
a fall below ``baseline / threshold`` fails outright. Analytic rows
(model-derived numbers, byte accounting, module wall times) are
reported but never gate. Runs of different *smoke* settings never compare (identically
named rows at very different magnitudes); the ``--measured`` /
``--serve`` flags only decide which row families exist, so a results
file produced with a subset of the baseline's flags simply gates the
intersection — that is what lets the bench-smoke lane (fig/tpu rows)
and the serve lane (serve rows) share one baseline superset.

CI runs ``python benchmarks/run.py --measured --smoke`` (bench-smoke)
or ``... --serve --smoke`` (serve lane), then
``python benchmarks/check_regression.py``.

Refresh the baseline after an intentional perf change (any machine —
normalization absorbs machine speed; the cold REPRO_AUTOTUNE_CACHE
matches CI, which also starts cold, so both sides pick blocks the same
way; keep ALL flags so the baseline covers every lane)::

    JAX_PLATFORMS=cpu PYTHONPATH=src:. \\
        REPRO_AUTOTUNE_CACHE=$(mktemp -u) \\
        REPRO_BENCH_JSON=benchmarks/BENCH_baseline.json \\
        python benchmarks/run.py --measured --smoke --serve

and commit ``benchmarks/BENCH_baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# row-name prefixes that represent steady-state kernel/serving timings
GATED_PREFIXES = ("fig4_measured", "fig5_measured", "fig6_measured",
                  "tpu_kernel_", "serve_decode_", "serve_itl_",
                  "serve_paged_decode_", "serve_prefill_bs_",
                  "serve_prefill_dense_")
# dimensionless rate rows (higher is better): gated on a MINIMUM — the
# paged engine's prefix-hit rate or pool utilization collapsing, or the
# block-sparse prefill speedup shrinking toward 1x, means the machinery
# broke even if raw throughput still looks fine. Excluded from the
# share normalization (they are not times).
RATE_PREFIXES = ("serve_paged_hitrate_", "serve_paged_util_",
                 "serve_prefill_bs_speedup_")
CALIBRATION_ROW = "bench_calibration"
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "BENCH_baseline.json")


def _rows(payload: dict) -> dict[str, float]:
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--results", default="BENCH_results.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when a row's share of total gated time "
                         "exceeds its baseline share by this factor "
                         "(default 1.5)")
    ap.add_argument("--global-threshold", type=float, default=3.0,
                    help="fail when the calibration-normalized total "
                         "exceeds baseline by this factor (uniform-"
                         "slowdown guard; loose on purpose)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="ignore rows whose baseline time is below this "
                         "(too noisy to gate)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base_payload = json.load(f)
    with open(args.results) as f:
        res_payload = json.load(f)
    # smoke and full runs emit identically named rows at very different
    # magnitudes — never compare across smoke settings. The measured /
    # serve flags need no such check: they gate which row families
    # *exist*, so a lane running a subset of the baseline's flags just
    # compares the intersection of rows.
    base_mode = base_payload.get("mode") or {}
    res_mode = res_payload.get("mode") or {}
    if base_mode.get("smoke") != res_mode.get("smoke"):
        print(f"error: smoke-mode mismatch — baseline {base_mode}, results "
              f"{res_mode}; regenerate one side with matching run.py "
              "flags (CI always passes --smoke)", file=sys.stderr)
        return 1
    base = _rows(base_payload)
    res = _rows(res_payload)

    shared = sorted(set(base) & set(res) - {CALIBRATION_ROW})
    if not shared:
        print("error: no shared rows between baseline and results — was "
              "the baseline generated with the same run.py mode "
              "(--measured --smoke)?", file=sys.stderr)
        return 1
    rates = [n for n in shared
             if n.startswith(RATE_PREFIXES) and base[n] > 0]
    gated = [n for n in shared
             if n.startswith(GATED_PREFIXES) and base[n] >= args.min_us
             and res[n] > 0 and not n.startswith(RATE_PREFIXES)]
    if not gated:
        print("error: no gated (timed) rows shared with the baseline",
              file=sys.stderr)
        return 1
    total_b = sum(base[n] for n in gated)
    total_r = sum(res[n] for n in gated)

    failures = []
    print(f"gated rows: {len(gated)}; total {total_b / 1e3:.1f}ms "
          f"(baseline) vs {total_r / 1e3:.1f}ms (new)")
    print(f"{'row':48s} {'base':>10s} {'new':>10s} {'ratio':>6s}  gate")
    for name in shared:
        b, r = base[name], res[name]
        if name in gated:
            ratio = (r / total_r) / (b / total_b)
            flag = "ok"
            if ratio > args.threshold:
                failures.append((name, ratio))
                flag = "FAIL"
        elif name in rates:
            # rate rows gate on a floor: new must stay within 1/threshold
            # of the baseline rate (machine speed does not move a rate,
            # so no normalization is needed)
            ratio = r / b
            flag = "ok(min)"
            if ratio < 1.0 / args.threshold:
                failures.append((name, ratio))
                flag = "FAIL"
        else:
            ratio = r / b if b > 0 else float("nan")
            flag = " "
        print(f"{name:48s} {b:10.1f} {r:10.1f} {ratio:6.2f}  {flag}")

    # uniform-slowdown guard: calibration-normalized total
    cal_b, cal_r = base.get(CALIBRATION_ROW, 0.0), res.get(CALIBRATION_ROW,
                                                           0.0)
    if cal_b > 0 and cal_r > 0:
        g = (total_r / cal_r) / (total_b / cal_b)
        print(f"calibration-normalized total: {g:.2f}x "
              f"(guard threshold {args.global_threshold:.1f}x)")
        if g > args.global_threshold:
            failures.append(("<calibration-normalized total>", g))
    else:
        print(f"warning: missing {CALIBRATION_ROW} row; uniform-slowdown "
              "guard skipped", file=sys.stderr)

    if failures:
        print(f"\n{len(failures)} regression(s) beyond threshold:",
              file=sys.stderr)
        for name, ratio in failures:
            print(f"  {name}: {ratio:.2f}x", file=sys.stderr)
        print("If intentional, refresh benchmarks/BENCH_baseline.json "
              "(see this script's docstring).", file=sys.stderr)
        return 1
    print(f"\nbench gate OK: {len(gated)} timed rows within "
          f"{args.threshold:.2f}x of baseline (share-normalized)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
