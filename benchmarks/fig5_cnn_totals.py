"""Paper Fig. 5: total-execution speedup per CNN.

Paper: avg 1.95x @1:4 and 1.88x @2:4 across ResNet50 / DenseNet121 /
InceptionV3 (each normalized to Row-Wise-SpMM of the same sparsity).
"""
from __future__ import annotations

from benchmarks.cnn_specs import CNNS
from repro.core.cost_model import VectorCoreModel
from repro.core.sparsity import NMConfig


def run():
    model = VectorCoreModel()
    results = {}
    for cnn, fn in CNNS.items():
        layers = fn()
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            base = sum(model.cycles_rowwise(m, k, n, cfg)
                       for _, m, k, n in layers)
            prop = sum(model.cycles_indexmac(m, k, n, cfg)
                       for _, m, k, n in layers)
            results[(cnn, cfg.tag)] = base / prop
    return results


def main():
    res = run()
    out = []
    for tag in ("1:4", "2:4"):
        sps = [res[(c, tag)] for c in CNNS]
        avg = sum(sps) / len(sps)
        for c in CNNS:
            print(f"fig5 {c:12s} {tag}: {res[(c, tag)]:.2f}x")
        print(f"fig5 average {tag}: {avg:.2f}x "
              f"(paper: {'1.95' if tag == '1:4' else '1.88'}x)")
        out.append((f"fig5_avg_{tag}", 0.0, f"speedup={avg:.3f}"))
    return out


if __name__ == "__main__":
    main()
