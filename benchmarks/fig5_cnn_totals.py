"""Paper Fig. 5: total-execution speedup per CNN.

Paper: avg 1.95x @1:4 and 1.88x @2:4 across ResNet50 / DenseNet121 /
InceptionV3 (each normalized to Row-Wise-SpMM of the same sparsity).

``measured_main()`` sums real per-layer kernel timings (Pallas
``nm_matmul`` vs Row-Wise-SpMM) into whole-CNN totals for the two
config-backed CNNs (ResNet50 / DenseNet121 — the backbones the
``SparseCNN`` forward model executes), in both value families: float and
the int8 ``QNMWeight`` path. Layer measurements are shared with fig4
through ``benchmarks.measured``'s cache.
"""
from __future__ import annotations

from benchmarks.cnn_specs import CNNS
from repro.core.cost_model import VectorCoreModel
from repro.core.sparsity import NMConfig

MEASURED_CNNS = ("resnet50", "densenet121")


def run():
    model = VectorCoreModel()
    results = {}
    for cnn, fn in CNNS.items():
        layers = fn()
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            base = sum(model.cycles_rowwise(m, k, n, cfg)
                       for _, m, k, n in layers)
            prop = sum(model.cycles_indexmac(m, k, n, cfg)
                       for _, m, k, n in layers)
            results[(cnn, cfg.tag)] = base / prop
    return results


def measured_main(smoke: bool = False):
    """Whole-CNN totals from real kernel timings -> (rows, layer records)."""
    from benchmarks.measured import layer_subset, measure_layer

    rows, layer_rows = [], []
    for cnn in MEASURED_CNNS:
        layers = layer_subset(CNNS[cnn](), smoke)
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            for quantized in (False, True):
                recs = []
                for name, m, k, n in layers:
                    r = measure_layer(f"{cnn}_{name}", m, k, n, cfg,
                                      quantized=quantized, smoke=smoke)
                    r["fig"] = "fig5"
                    recs.append(r)
                layer_rows += recs
                total_p = sum(r["t_pallas_us"] for r in recs)
                total_r = sum(r["t_rowwise_us"] for r in recs)
                fam = recs[0]["family"]
                print(f"fig5-measured {cnn:12s} {cfg.tag} {fam}: "
                      f"total {total_p / 1e3:.1f}ms vs rowwise "
                      f"{total_r / 1e3:.1f}ms "
                      f"({total_r / total_p:.2f}x, {len(recs)} layers)")
                rows.append((
                    f"fig5_measured_{cnn}_{cfg.tag}_{fam}", total_p,
                    f"total_speedup_vs_rowwise={total_r / total_p:.3f};"
                    f"layers={len(recs)}"))
    return rows, layer_rows


def main():
    res = run()
    out = []
    for tag in ("1:4", "2:4"):
        sps = [res[(c, tag)] for c in CNNS]
        avg = sum(sps) / len(sps)
        for c in CNNS:
            print(f"fig5 {c:12s} {tag}: {res[(c, tag)]:.2f}x")
        print(f"fig5 average {tag}: {avg:.2f}x "
              f"(paper: {'1.95' if tag == '1:4' else '1.88'}x)")
        out.append((f"fig5_avg_{tag}", 0.0, f"speedup={avg:.3f}"))
    return out


if __name__ == "__main__":
    main()
