"""GEMM shapes (im2col) for the paper's three CNNs at 224x224 input.

Each layer is (name, M, K, N): sparse weights A (M=C_out, K=C_in*kh*kw)
times dense im2col'd features B (K, N=H_out*W_out) — the mapping the paper
uses (§IV: "convolutions of each layer ... mapped to sparse-dense matrix
multiplications A x B").

ResNet50 / DenseNet121 tables are derived from the CNNConfig entries in
``repro.configs`` (the same configs the ``SparseCNN`` forward model and
the measured fig benchmarks execute — one source of truth; parity with
the published block structure is asserted in tests/test_conv.py).
InceptionV3 uses the torchvision module table (representative branch
convs per module).
"""
from __future__ import annotations

from repro.configs import get_cnn_config
from repro.models.conv import cnn_layer_gemms


def resnet50_gemms() -> list[tuple[str, int, int, int]]:
    return cnn_layer_gemms(get_cnn_config("resnet50"))


def densenet121_gemms() -> list[tuple[str, int, int, int]]:
    return cnn_layer_gemms(get_cnn_config("densenet121"))


# torchvision InceptionV3 branch convs: (name, C_out, C_in*kh*kw, H*W)
def inceptionv3_gemms() -> list[tuple[str, int, int, int]]:
    L: list[tuple[str, int, int, int]] = []

    def add(name, cout, cin, k, hw):
        L.append((name, cout, cin * k, hw * hw))

    add("stem1", 32, 3, 9, 149); add("stem2", 32, 32, 9, 147)
    add("stem3", 64, 32, 9, 147); add("stem4", 80, 64, 1, 73)
    add("stem5", 192, 80, 9, 71)
    # 3x InceptionA @35, ch_in 192/256/288
    for i, cin in enumerate((192, 256, 288)):
        t = f"A{i+1}"
        add(t + "_1x1", 64, cin, 1, 35); add(t + "_5x5r", 48, cin, 1, 35)
        add(t + "_5x5", 64, 48, 25, 35); add(t + "_3x3r", 64, cin, 1, 35)
        add(t + "_3x3a", 96, 64, 9, 35); add(t + "_3x3b", 96, 96, 9, 35)
        add(t + "_pool", [32, 64, 64][i], cin, 1, 35)
    add("B_3x3", 384, 288, 9, 17)  # reduction A
    add("B_r1", 64, 288, 1, 35); add("B_r2", 96, 64, 9, 35)
    add("B_r3", 96, 96, 9, 17)
    # 4x InceptionC @17 (7x1/1x7 factorized), c7 = 128/160/160/192
    for i, c7 in enumerate((128, 160, 160, 192)):
        t = f"C{i+1}"
        add(t + "_1x1", 192, 768, 1, 17)
        add(t + "_7a", c7, 768, 1, 17); add(t + "_7b", c7, c7, 7, 17)
        add(t + "_7c", 192, c7, 7, 17)
        add(t + "_db1", c7, 768, 1, 17); add(t + "_db2", c7, c7, 7, 17)
        add(t + "_db3", c7, c7, 7, 17); add(t + "_db4", c7, c7, 7, 17)
        add(t + "_db5", 192, c7, 7, 17)
        add(t + "_pool", 192, 768, 1, 17)
    add("D_r1", 192, 768, 1, 17); add("D_3x3", 320, 192, 9, 8)
    add("D_7a", 192, 768, 1, 17); add("D_7b", 192, 192, 7, 17)
    add("D_7c", 192, 192, 7, 17); add("D_33", 192, 192, 9, 8)
    # 2x InceptionE @8
    for i, cin in enumerate((1280, 2048)):
        t = f"E{i+1}"
        add(t + "_1x1", 320, cin, 1, 8)
        add(t + "_3x3r", 384, cin, 1, 8); add(t + "_3x3a", 384, 384, 3, 8)
        add(t + "_3x3b", 384, 384, 3, 8)
        add(t + "_dbr", 448, cin, 1, 8); add(t + "_db1", 384, 448, 9, 8)
        add(t + "_db2", 384, 384, 3, 8); add(t + "_db3", 384, 384, 3, 8)
        add(t + "_pool", 192, cin, 1, 8)
    return L


CNNS = {
    "resnet50": resnet50_gemms,
    "densenet121": densenet121_gemms,
    "inceptionv3": inceptionv3_gemms,
}
