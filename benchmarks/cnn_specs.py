"""GEMM shapes (im2col) for the paper's three CNNs at 224x224 input.

Each layer is (name, M, K, N): sparse weights A (M=C_out, K=C_in*kh*kw)
times dense im2col'd features B (K, N=H_out*W_out) — the mapping the paper
uses (§IV: "convolutions of each layer ... mapped to sparse-dense matrix
multiplications A x B").

ResNet50 / DenseNet121 dims are generated from the exact published block
structure; InceptionV3 uses the torchvision module table (representative
branch convs per module).
"""
from __future__ import annotations


def resnet50_gemms() -> list[tuple[str, int, int, int]]:
    layers = [("conv1", 64, 3 * 49, 112 * 112)]
    stages = [  # (mid, out, blocks, hw)
        (64, 256, 3, 56), (128, 512, 4, 28), (256, 1024, 6, 14),
        (512, 2048, 3, 7)]
    in_ch = 64
    for si, (mid, out, blocks, hw) in enumerate(stages):
        n = hw * hw
        for b in range(blocks):
            tag = f"s{si+2}b{b+1}"
            layers.append((f"{tag}_1x1a", mid, in_ch, n))
            layers.append((f"{tag}_3x3", mid, mid * 9, n))
            layers.append((f"{tag}_1x1b", out, mid, n))
            if b == 0:
                layers.append((f"{tag}_proj", out, in_ch, n))
            in_ch = out
    return layers


def densenet121_gemms() -> list[tuple[str, int, int, int]]:
    growth = 32
    layers = [("conv1", 64, 3 * 49, 112 * 112)]
    ch = 64
    hw = 56
    for bi, nlayers in enumerate([6, 12, 24, 16]):
        n = hw * hw
        for li in range(nlayers):
            tag = f"d{bi+1}l{li+1}"
            layers.append((f"{tag}_1x1", 4 * growth, ch, n))
            layers.append((f"{tag}_3x3", growth, 4 * growth * 9, n))
            ch += growth
        if bi < 3:  # transition: 1x1 halving channels, then 2x2 pool
            layers.append((f"t{bi+1}_1x1", ch // 2, ch, n))
            ch //= 2
            hw //= 2
    return layers


# torchvision InceptionV3 branch convs: (name, C_out, C_in*kh*kw, H*W)
def inceptionv3_gemms() -> list[tuple[str, int, int, int]]:
    L: list[tuple[str, int, int, int]] = []

    def add(name, cout, cin, k, hw):
        L.append((name, cout, cin * k, hw * hw))

    add("stem1", 32, 3, 9, 149); add("stem2", 32, 32, 9, 147)
    add("stem3", 64, 32, 9, 147); add("stem4", 80, 64, 1, 73)
    add("stem5", 192, 80, 9, 71)
    # 3x InceptionA @35, ch_in 192/256/288
    for i, cin in enumerate((192, 256, 288)):
        t = f"A{i+1}"
        add(t + "_1x1", 64, cin, 1, 35); add(t + "_5x5r", 48, cin, 1, 35)
        add(t + "_5x5", 64, 48, 25, 35); add(t + "_3x3r", 64, cin, 1, 35)
        add(t + "_3x3a", 96, 64, 9, 35); add(t + "_3x3b", 96, 96, 9, 35)
        add(t + "_pool", [32, 64, 64][i], cin, 1, 35)
    add("B_3x3", 384, 288, 9, 17)  # reduction A
    add("B_r1", 64, 288, 1, 35); add("B_r2", 96, 64, 9, 35)
    add("B_r3", 96, 96, 9, 17)
    # 4x InceptionC @17 (7x1/1x7 factorized), c7 = 128/160/160/192
    for i, c7 in enumerate((128, 160, 160, 192)):
        t = f"C{i+1}"
        add(t + "_1x1", 192, 768, 1, 17)
        add(t + "_7a", c7, 768, 1, 17); add(t + "_7b", c7, c7, 7, 17)
        add(t + "_7c", 192, c7, 7, 17)
        add(t + "_db1", c7, 768, 1, 17); add(t + "_db2", c7, c7, 7, 17)
        add(t + "_db3", c7, c7, 7, 17); add(t + "_db4", c7, c7, 7, 17)
        add(t + "_db5", 192, c7, 7, 17)
        add(t + "_pool", 192, 768, 1, 17)
    add("D_r1", 192, 768, 1, 17); add("D_3x3", 320, 192, 9, 8)
    add("D_7a", 192, 768, 1, 17); add("D_7b", 192, 192, 7, 17)
    add("D_7c", 192, 192, 7, 17); add("D_33", 192, 192, 9, 8)
    # 2x InceptionE @8
    for i, cin in enumerate((1280, 2048)):
        t = f"E{i+1}"
        add(t + "_1x1", 320, cin, 1, 8)
        add(t + "_3x3r", 384, cin, 1, 8); add(t + "_3x3a", 384, 384, 3, 8)
        add(t + "_3x3b", 384, 384, 3, 8)
        add(t + "_dbr", 448, cin, 1, 8); add(t + "_db1", 384, 448, 9, 8)
        add(t + "_db2", 384, 384, 3, 8); add(t + "_db3", 384, 384, 3, 8)
        add(t + "_pool", 192, cin, 1, 8)
    return L


CNNS = {
    "resnet50": resnet50_gemms,
    "densenet121": densenet121_gemms,
    "inceptionv3": inceptionv3_gemms,
}
