"""Paper Fig. 4: per-layer speedup of 'Proposed' (vindexmac, Alg. 3) over
'Row-Wise-SpMM' (Alg. 2) on ResNet50 layers, 1:4 and 2:4 sparsity.

Paper bands: 1.60-2.15x (1:4), 1.63-1.99x (2:4); speedup decreases toward
late layers; 2:4 slightly below 1:4.

Two modes: ``main()`` reproduces the paper bands from the analytic
``VectorCoreModel``; ``measured_main()`` times the real padded Pallas
``nm_matmul`` dispatch against the row-wise / gather baselines on every
(sub-sampled, in smoke mode) ResNet50 layer, keeping the analytic
speedup as a cross-check column per row (``benchmarks.measured``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.cnn_specs import resnet50_gemms
from repro.core.cost_model import VectorCoreModel
from repro.core.sparse_matmul import indexmac_spmm, rowwise_spmm
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix


def run(verbose: bool = True):
    model = VectorCoreModel()
    layers = resnet50_gemms()
    rows = []
    for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
        sp = [model.speedup(m, k, n, cfg) for _, m, k, n in layers]
        rows.append((cfg.tag, min(sp), sum(sp) / len(sp), max(sp)))
        if verbose:
            for (name, m, k, n), s in list(zip(layers, sp))[::6]:
                print(f"  fig4 {cfg.tag} {name:12s} M{m:4d} K{k:5d} N{n:6d}"
                      f"  speedup {s:.2f}x")
    # numeric check: Alg.3 == Alg.2 on a real layer (semantic equivalence)
    name, m, k, n = layers[8]
    cfg = NMConfig(2, 4)
    a = random_nm_matrix(jax.random.PRNGKey(0), (32, k - k % 16), cfg, axis=1)
    vals, idx = compress_nm(a, cfg, axis=1)
    b = jax.random.normal(jax.random.PRNGKey(1), (a.shape[1], 64))
    t0 = time.perf_counter()
    c2 = rowwise_spmm(vals, idx, b, cfg).block_until_ready()
    t_alg2 = time.perf_counter() - t0
    t0 = time.perf_counter()
    c3 = indexmac_spmm(vals, idx, b, cfg).block_until_ready()
    t_alg3 = time.perf_counter() - t0
    err = float(jnp.abs(c2 - c3).max())
    assert err < 1e-3, err
    return rows, (t_alg2 * 1e6, t_alg3 * 1e6)


def measured_main(smoke: bool = False):
    """Per-layer kernel measurements -> (summary rows, per-layer records)."""
    from benchmarks.measured import layer_subset, measure_layer

    layers = layer_subset(resnet50_gemms(), smoke)
    rows, layer_rows = [], []
    for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
        recs = []
        for name, m, k, n in layers:
            r = measure_layer(f"resnet50_{name}", m, k, n, cfg, smoke=smoke)
            r["fig"] = "fig4"
            recs.append(r)
            print(f"  fig4-measured {cfg.tag} {name:12s} "
                  f"pallas {r['t_pallas_us']:9.1f}us "
                  f"rowwise {r['t_rowwise_us']:9.1f}us "
                  f"speedup {r['speedup_vs_rowwise']:.2f}x "
                  f"(analytic {r['analytic_speedup']:.2f}x, "
                  f"{r['pallas_impl']})")
        layer_rows += recs
        sp = [r["speedup_vs_rowwise"] for r in recs]
        t_total = sum(r["t_pallas_us"] for r in recs)
        rows.append((
            f"fig4_measured_resnet50_{cfg.tag}", t_total,
            f"speedup_vs_rowwise_avg={sum(sp) / len(sp):.3f};"
            f"range={min(sp):.2f}-{max(sp):.2f};layers={len(recs)}"))
    return rows, layer_rows


def main():
    rows, (us2, us3) = run()
    out = []
    for tag, lo, avg, hi in rows:
        print(f"fig4 resnet50 {tag}: speedup {lo:.2f}-{hi:.2f}x "
              f"(avg {avg:.2f}x)")
        out.append((f"fig4_resnet50_{tag}", us3, f"speedup_avg={avg:.3f};"
                    f"range={lo:.2f}-{hi:.2f}"))
    return out


if __name__ == "__main__":
    main()
