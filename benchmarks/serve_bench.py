"""Serving throughput benchmark: dense vs 2:4 vs int8-2:4 engines on a
single device and on a forced-8-device host mesh (2 data x 4 model).

Each (variant, device-count) cell serves a fixed request queue through
the real engine (batched admissions, chunked prefill, device-side
sampling) after a warmup request has paid the two step compiles —
best-of-3 passes, so a transient contention window on a shared runner
does not masquerade as a serving regression — and reports three
schema-2 rows:

  serve_decode_{variant}_{D}dev    us per generated token   (GATED)
  serve_ttft_{variant}_{D}dev      mean time-to-first-token us
  serve_ttft_p50_{variant}_{D}dev  p50 TTFT us (info, ungated)
  serve_ttft_p99_{variant}_{D}dev  p99 TTFT us (info, ungated)
  serve_itl_{variant}_{D}dev       p50 inter-token latency us
                                   (derived carries p99)

Inter-token latency pools each finished request's own token-timestamp
gaps (``Request.t_tokens``) — not a diff over the engine's global
decode clock, which would charge admission/preemption stalls between
*other* requests' steps to every request.

The timed passes always run with observability OFF (the gated rows
measure the zero-overhead path). A final untimed pass per device count
runs with a fresh ``repro.obs`` bundle enabled and attaches its metrics
snapshot to ``BENCH_results.json`` (``serve_metrics``).

The ``serve_decode_*`` and ``serve_itl_*`` families gate in
``check_regression.py`` — us/token is inverse tokens/sec, and the
share-normalized comparison (row / sum of gated rows, new vs baseline)
cancels runner speed, so the gate fires when one engine variant slows
*relative to the others*, e.g. a sparse decode-dispatch regression that
dense serving doesn't see. The sparse variants run with
``use_kernel=True`` and preflight every compressed GEMM with
``api.explain_dispatch`` — a decode step must route to the Pallas
decode family for its timings to be admitted at all.

Every cell runs in a subprocess: the 8-device cells must set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initializes, and fresh processes keep cells from warming each other.

Standalone:  PYTHONPATH=src:. python benchmarks/serve_bench.py [--smoke]
Harness:     python benchmarks/run.py --serve [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

VARIANTS = ("dense", "nm24", "int8")
MESH_8DEV = (2, 4)  # (data, model) for the forced host mesh


# ---------------------------------------------------------------------------
# child: one (devices, smoke) cell set — runs all variants, prints ROWS json
# ---------------------------------------------------------------------------


def _child(devices: int, smoke: bool) -> None:
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro import api, compat
    from repro.configs import get_reduced
    from repro.models.transformer import LM
    from repro.serving.engine import Request, ServeEngine, ShardedServeEngine

    slots, prefill_len, chunk = 4, 16, 8
    requests = 6 if smoke else 24
    max_new = 8 if smoke else 32
    mesh = None
    if devices > 1:
        mesh = compat.make_mesh(MESH_8DEV, ("data", "model"))

    def build(variant):
        cfg = get_reduced("yi-9b", sparse=variant != "dense")
        if cfg.sparsity is not None:
            # the sparse variants measure the kernel path, not the XLA
            # reference: the decode-family dispatch is what serve_itl_*
            # rows gate
            cfg = dataclasses.replace(
                cfg, sparsity=dataclasses.replace(
                    cfg.sparsity, use_kernel=True))
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        if variant != "dense":
            _preflight_decode_dispatch(params, variant)
        kw = dict(slots=slots, max_seq=128, prefill_len=prefill_len,
                  prefill_chunk=chunk,
                  quantize="int8" if variant == "int8" else None)
        if mesh is not None:
            return cfg, ShardedServeEngine(lm, params, mesh=mesh, **kw)
        return cfg, ServeEngine(lm, params, **kw)

    def _preflight_decode_dispatch(params, variant):
        # the public dry-run replaces record sniffing: every compressed
        # GEMM at decode shape (M = slots) must route to a Pallas
        # decode-family kernel before any timing is trusted.
        leaves = [x for x in jax.tree.leaves(
            params, is_leaf=api.is_sparse) if api.is_sparse(x)]
        for w in leaves:
            rec = api.explain_dispatch((slots, w.dense_dim), w)
            if not (rec.op.startswith("nm_matmul_decode")
                    and rec.impl.startswith("pallas")
                    and rec.backend in ("tpu", "gpu")):
                raise RuntimeError(
                    f"serve bench ({variant}) needs the Pallas decode "
                    f"dispatch for every GEMM; K={w.dense_dim} "
                    f"N={w.vals.shape[-1]} would route to "
                    f"{rec.op}/{rec.impl} on backend {rec.backend}: "
                    f"{rec.reason}")

    rows = []
    for variant in VARIANTS:
        cfg, eng = build(variant)
        rng = np.random.default_rng(0)

        def req(i):
            return Request(
                rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, size=prefill_len).astype(np.int32),
                max_new=max_new)

        eng.submit(req(-1))  # warmup: pays the prefill+decode compiles
        eng.run()
        # best-of-3 measured passes (same policy as the tpu_kernel rows):
        # a transient contention window on a shared runner slows one pass,
        # the min is the steady-state the 1.5x share gate should compare
        passes = []
        for _ in range(3):
            eng.decode_times.clear()
            n_warm = len(eng.finished)
            t0 = time.perf_counter()
            for i in range(requests):
                eng.submit(req(i))
            done = eng.run()[n_warm:]  # finished is cumulative
            wall = time.perf_counter() - t0
            toks = sum(len(r.out) for r in done)
            assert len(done) == requests, (variant, len(done))
            ttfts = np.asarray(
                [r.t_first - r.t_submit for r in done])
            # per-request inter-token gaps, pooled across the pass
            itl = np.concatenate([r.itl_s() for r in done])
            passes.append((wall / toks, toks / wall, float(ttfts.mean()),
                           float(np.percentile(itl, 50)),
                           float(np.percentile(itl, 99)),
                           float(np.percentile(ttfts, 50)),
                           float(np.percentile(ttfts, 99))))
        sizes = eng.compiled_cache_sizes()
        assert sizes["prefill"] in (-1, 1) and sizes["decode"] in (-1, 1), \
            (variant, sizes)  # recompiles would poison the timings
        us_tok, toks_s, ttft, p50, p99, tp50, tp99 = min(passes)
        dev = f"{devices}dev"
        rows.append((f"serve_decode_{variant}_{dev}", us_tok * 1e6,
                     f"{toks_s:.1f}tok/s"))
        rows.append((f"serve_ttft_{variant}_{dev}", ttft * 1e6,
                     f"chunk={chunk}"))
        rows.append((f"serve_ttft_p50_{variant}_{dev}", tp50 * 1e6,
                     "info"))
        rows.append((f"serve_ttft_p99_{variant}_{dev}", tp99 * 1e6,
                     "info"))
        rows.append((f"serve_itl_{variant}_{dev}", p50 * 1e6,
                     f"p99={p99 * 1e6:.0f}us"))
    rows += _paged_cell(devices, smoke, mesh)
    rows += _blocksparse_cell(devices, smoke, mesh)
    print("ROWS" + json.dumps(rows))
    print("METRICS" + json.dumps(_metrics_pass(devices, smoke, mesh)))


def _paged_cell(devices: int, smoke: bool, mesh) -> list[tuple]:
    """High-churn paged-KV cell: mixed-length prompts with shared
    prefixes over a deliberately undersized page pool, so page
    recycling, prefix-cache reuse, and (non-smoke) preemption are all
    load-bearing in the measured number. Emits the gated
    ``serve_paged_decode`` row plus min-gated rate rows (prefix hit
    rate, pool utilization) — a drop in either means the paging
    machinery stopped doing its job even if throughput looks fine."""
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.configs import get_reduced
    from repro.models.transformer import LM
    from repro.serving.engine import Request, ServeEngine, ShardedServeEngine

    slots, prefill_len, chunk, page_size = 4, 16, 8, 8
    max_seq = 64
    pool_pages = 16  # full residency would need slots * 8 = 32
    requests = 6 if smoke else 24
    max_new = 8 if smoke else 32

    cfg = get_reduced("yi-9b")
    cfg = dataclasses.replace(
        cfg, sparsity=dataclasses.replace(cfg.sparsity, use_kernel=True))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    kw = dict(slots=slots, max_seq=max_seq, prefill_len=prefill_len,
              prefill_chunk=chunk, paged=True, page_size=page_size,
              pool_pages=pool_pages)
    if mesh is not None:
        eng = ShardedServeEngine(lm, params, mesh=mesh, **kw)
    else:
        eng = ServeEngine(lm, params, **kw)

    rng = np.random.default_rng(0)
    # two prompt families, each sharing its first page (8 tokens after
    # left-padding) within the family — the prefix cache sees real reuse
    base_long = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    base_short = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)

    def req(i):
        if i % 2:  # short prompt: 4 zero-pad + 4 shared = shared page 0
            prompt = np.concatenate([base_short, rng.integers(
                0, cfg.vocab_size, size=8).astype(np.int32)])
        else:      # long prompt: first 8 tokens shared
            prompt = np.concatenate([base_long, rng.integers(
                0, cfg.vocab_size, size=8).astype(np.int32)])
        return Request(rid=i, prompt=prompt,
                       max_new=max_new - (i % 3) * (max_new // 4))

    eng.submit(req(-2))  # warmup: pays the prefill+decode compiles
    eng.run()
    passes = []
    for _ in range(3):
        eng.decode_times.clear()
        n_warm = len(eng.finished)
        t0 = time.perf_counter()
        for i in range(requests):
            eng.submit(req(i))
        done = eng.run()[n_warm:]
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        assert len(done) == requests, ("paged", len(done))
        ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
        passes.append((wall / toks, toks / wall, ttft))
    sizes = eng.compiled_cache_sizes()
    assert sizes["prefill"] in (-1, 1) and sizes["decode"] in (-1, 1), \
        ("paged", sizes)  # recompiles would poison the timings
    st = eng.throughput_stats()
    assert st["prefix_hit_pages"] > 0, st  # shared pages must be reused
    us_tok, toks_s, ttft = min(passes)
    dev = f"{devices}dev"
    return [
        (f"serve_paged_decode_{dev}", us_tok * 1e6, f"{toks_s:.1f}tok/s"),
        (f"serve_paged_ttft_{dev}", ttft * 1e6,
         f"qdepth={st['queue_depth_mean']:.1f} "
         f"preempt={st['preemptions']}"),
        (f"serve_paged_hitrate_{dev}", st["prefix_hit_rate"],
         f"{st['prefix_hit_pages']}/{st['prefix_lookup_pages']}pages"),
        (f"serve_paged_util_{dev}", st["page_util_mean"],
         f"max={st['page_util_max']:.2f}"),
    ]


def _blocksparse_cell(devices: int, smoke: bool, mesh) -> list[tuple]:
    """Block-sparse prefill cell: a thin long-context GQA model serves
    FULL prefills (``prefill_chunk == prefill_len``, the shape that
    routes the ``bs_attention`` prefill family) under a local MaskSpec,
    against the dense sliding-window path with identical visibility
    semantics. Emits the gated ``serve_prefill_bs_*`` /
    ``serve_prefill_dense_*`` timing rows plus the min-gated speedup
    rate row. The cell refuses to report at all unless (a) trace-time
    dispatch counters prove a sparse lowering ran and the dense
    ``masked_reference`` fallback never did, and (b) the masked
    engine's tokens match the dense engine's exactly (f32 compute for
    the pass, so parity is bit-meaningful)."""
    import dataclasses
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.configs import get_reduced
    from repro.configs.base import FFNConfig
    from repro.kernels import registry
    from repro.kernels.blocksparse_attn.mask import MaskSpec, compile_mask
    from repro.models import common
    from repro.models.transformer import LM
    from repro.serving.engine import Request, ServeEngine, ShardedServeEngine

    seq = 512 if smoke else 1024
    window, block = 192, 64
    slots, requests = 2, 4
    spec = MaskSpec("local", block=block, window=window)
    plan = compile_mask(spec, seq, seq)
    assert plan is not None and plan.density <= 0.5, plan

    prev = common.get_compute_dtype()
    common.set_compute_dtype(jnp.float32)
    try:
        base = get_reduced("yi-9b", sparse=False)

        def variant(**fields):
            def blk(b):
                # thin FFN: the cell measures the attention path, not
                # the (identical either way) projection/FFN GEMMs
                b = dataclasses.replace(b, mlp=FFNConfig(d_ff=64))
                return dataclasses.replace(
                    b, mixer=dataclasses.replace(b.mixer, **fields))

            pl = tuple((blk(e), r) for e, r in base.plan)
            return dataclasses.replace(base, plan=pl, max_seq=seq + 8)

        cfg_d = variant(mask=None, window=window)
        cfg_b = variant(mask=spec, window=None)
        lm_d, lm_b = LM(cfg_d), LM(cfg_b)
        params = lm_d.init(jax.random.PRNGKey(0))  # mask changes no params

        # preflight: the full-prefill shape must route a sparse lowering
        mx = cfg_b.plan[0][0].mixer
        rec = api.explain_dispatch_attention(
            (slots, seq, mx.q_heads, mx.head_dim),
            (slots, seq, mx.kv_heads, mx.head_dim), mask=spec,
            dtype=jnp.float32)
        if rec.impl == "masked_reference":
            raise RuntimeError(
                f"blocksparse prefill cell needs a sparse lowering; "
                f"Sq=Skv={seq} mask {spec.tag} would route to "
                f"{rec.impl}: {rec.reason}")

        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, base.vocab_size,
                                size=seq).astype(np.int32)
                   for _ in range(requests)]

        def serve(lm):
            kw = dict(slots=slots, max_seq=seq + 8, prefill_len=seq)
            eng = (ShardedServeEngine(lm, params, mesh=mesh, **kw)
                   if mesh is not None else ServeEngine(lm, params, **kw))
            eng.submit(Request(rid=-1, prompt=prompts[0], max_new=1))
            eng.run()  # warmup: pays the prefill compile
            best, out = None, None
            for _ in range(3):
                n_warm = len(eng.finished)
                t0 = time.perf_counter()
                for i, p in enumerate(prompts):
                    eng.submit(Request(rid=i, prompt=p, max_new=1))
                done = eng.run()[n_warm:]
                wall = time.perf_counter() - t0
                assert len(done) == requests, len(done)
                out = {r.rid: tuple(r.out) for r in done}
                best = wall if best is None else min(best, wall)
            sizes = eng.compiled_cache_sizes()
            assert sizes["prefill"] in (-1, 1), sizes
            return best / requests, out

        dense_s, dense_out = serve(lm_d)
        registry.clear_history()
        bs_s, bs_out = serve(lm_b)
        counts = registry.dispatch_counts("bs_attention")
        sparse_n = sum(
            n for (op, impl, _), n in counts.items()
            if op == "bs_attention" and impl != "masked_reference")
        fallback_n = sum(
            n for (op, impl, _), n in counts.items()
            if op == "bs_attention" and impl == "masked_reference")
        assert sparse_n > 0 and fallback_n == 0, counts
        assert bs_out == dense_out, (dense_out, bs_out)
    finally:
        common.set_compute_dtype(prev)

    speedup = dense_s / bs_s
    assert speedup >= 1.5, (
        f"blocksparse prefill speedup {speedup:.2f}x < 1.5x at "
        f"density {plan.density:.2f}")
    dev = f"{devices}dev"
    return [
        (f"serve_prefill_bs_{dev}", bs_s * 1e6,
         f"density={plan.density:.2f} S={seq}"),
        (f"serve_prefill_dense_{dev}", dense_s * 1e6, f"window={window}"),
        (f"serve_prefill_bs_speedup_{dev}", speedup,
         f"{speedup:.2f}x vs dense"),
    ]


def _metrics_pass(devices: int, smoke: bool, mesh) -> dict:
    """One untimed paged serve with a fresh ``repro.obs`` bundle enabled.

    A *fresh* engine is built under the bundle on purpose: kernel
    dispatch and autotune-cache decisions happen at trace time, so only
    a run that pays its own compiles records the ``kernel_dispatch_*``
    and ``autotune_*`` metrics alongside the engine/scheduler/paging
    ones. Returns the ``MetricsRegistry.snapshot()`` dict that
    ``run.py`` attaches to ``BENCH_results.json``."""
    import dataclasses

    import jax
    import numpy as np

    import repro.obs as obs_mod
    from repro.configs import get_reduced
    from repro.models.transformer import LM
    from repro.serving.engine import Request, ServeEngine, ShardedServeEngine

    bundle = obs_mod.enable(obs_mod.Obs.create())
    try:
        cfg = get_reduced("yi-9b")
        cfg = dataclasses.replace(
            cfg, sparsity=dataclasses.replace(
                cfg.sparsity, use_kernel=True))
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        kw = dict(slots=4, max_seq=64, prefill_len=16, prefill_chunk=8,
                  paged=True, page_size=8, pool_pages=16)
        if mesh is not None:
            eng = ShardedServeEngine(lm, params, mesh=mesh, **kw)
        else:
            eng = ServeEngine(lm, params, **kw)
        rng = np.random.default_rng(0)
        for i in range(4 if smoke else 8):
            eng.submit(Request(
                rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, size=16).astype(np.int32),
                max_new=4))
        eng.run()
        return bundle.metrics.snapshot()
    finally:
        obs_mod.disable()


# ---------------------------------------------------------------------------
# parent: spawn one subprocess per device count
# ---------------------------------------------------------------------------


def bench_rows_and_metrics(smoke: bool = False) -> tuple[list, dict]:
    """All serve-bench rows plus the per-device-count obs metrics
    snapshots; spawns the per-device-count subprocesses."""
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    rows: list[tuple] = []
    metrics: dict = {}
    for devices in (1, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        # the timed cells must measure the zero-overhead path even when
        # the harness itself runs under REPRO_OBS=1
        env.pop("REPRO_OBS", None)
        if devices > 1:
            env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            env["JAX_PLATFORMS"] = "cpu"  # host mesh is CPU by definition
        cmd = [sys.executable, os.path.join(here, "serve_bench.py"),
               "--run-child", "--devices", str(devices)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=3600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"serve bench child (devices={devices}) failed:\n"
                + proc.stderr[-4000:])
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("ROWS")][0]
        rows += [tuple(r) for r in json.loads(line[len("ROWS"):])]
        mline = [l for l in proc.stdout.splitlines()
                 if l.startswith("METRICS")]
        if mline:
            metrics[f"{devices}dev"] = json.loads(mline[0][len("METRICS"):])
    return rows, metrics


def bench_rows(smoke: bool = False) -> list[tuple]:
    """All serve-bench rows; spawns the per-device-count subprocesses."""
    return bench_rows_and_metrics(smoke=smoke)[0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests / shorter generations (CI)")
    ap.add_argument("--run-child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=1, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.run_child:
        _child(args.devices, args.smoke)
        return
    print("name,us_per_call,derived")
    for name, us, derived in bench_rows(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
