"""Beyond-paper: roofline terms of the Pallas indexmac kernel vs dense
matmul on TPU v5e constants, over the paper's CNN GEMMs + transformer
projection GEMMs — for both value families (bf16 and the int8 QNMWeight
path, which streams one byte per kept value + a f32 scale per output
channel). Also times the interpret-mode kernels vs their oracles on one
shape (correctness + a real measured number for the CSV).

``kernel_records()`` returns the machine-readable per-kernel rows that
``benchmarks/run.py`` writes into BENCH_results.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.cnn_specs import resnet50_gemms
from repro.core.cost_model import (
    tpu_dense_cost,
    tpu_indexmac_cost,
    tpu_indexmac_q_cost,
)
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels import autotune
from repro.kernels.indexmac.kernel import nm_spmm_pallas, nm_spmm_pallas_q
from repro.kernels.indexmac.ref import nm_matmul_q_ref, nm_matmul_ref

TRANSFORMER_GEMMS = [
    # (name, M=tokens, K, N) — decode-ish (small M) and prefill-ish (large M)
    ("yi_ffn_decode", 16, 4096, 11008),
    ("yi_ffn_prefill", 8192, 4096, 11008),
    ("dsv2_expert_decode", 64, 5120, 1536),
    ("chameleon_qkv_decode", 16, 8192, 10240),
]

# (family tag, cost fn) — the int8 family halves the weight-value bytes
# again on top of the N:M compression.
_FAMILIES = (
    ("bf16", tpu_indexmac_cost),
    ("int8", tpu_indexmac_q_cost),
)


def _gemms():
    return ([("r50_" + t, mm, kk, nn) for t, mm, kk, nn in
             resnet50_gemms()[::12]] + TRANSFORMER_GEMMS)


def kernel_records() -> list[dict]:
    """Per-(N:M, family, GEMM) roofline accounting, machine-readable."""
    out = []
    for cfg in (NMConfig(2, 4), NMConfig(1, 4)):
        for vtag, cost_fn in _FAMILIES:
            for name, m, k, n in _gemms():
                dense = tpu_dense_cost(m, k, n)
                sp = cost_fn(m, k, n, cfg)
                t_d = max(dense.t_mem(), dense.t_compute())
                t_s = max(sp.t_mem(), sp.t_compute())
                out.append({
                    "nm": cfg.tag,
                    "family": vtag,
                    "gemm": name,
                    "m": m, "k": k, "n": n,
                    "hbm_bytes": sp.hbm_bytes,
                    "dense_hbm_bytes": dense.hbm_bytes,
                    "bytes_vs_dense": sp.hbm_bytes / dense.hbm_bytes,
                    "roofline_speedup_vs_dense": t_d / t_s,
                    "bound": ("mem" if sp.t_mem() > sp.t_compute()
                              else "comp"),
                })
    return out


def run(verbose=True):
    rows = []
    for r in kernel_records():
        rows.append((f"{r['nm']}-{r['family']}", r["gemm"],
                     r["roofline_speedup_vs_dense"], r["bytes_vs_dense"],
                     r["bound"]))
        if verbose:
            print(f"  tpu {r['nm']} {r['family']} {r['gemm']:22s} bytes x"
                  f"{r['bytes_vs_dense']:.2f} "
                  f"roofline speedup {r['roofline_speedup_vs_dense']:.2f}x "
                  f"({r['bound']}-bound)")
    return rows


def timed_correctness(cfgs=(NMConfig(2, 4), NMConfig(1, 4))):
    """Autotune the block triple for one shape, then time the winners —
    per N:M pattern and per value family, since all four rows feed the
    bench regression gate and must be independent measurements
    (interpret mode on CPU: the numbers are smoke signals, not TPU
    measurements — the same sweeps persist real timings on hardware)."""
    from benchmarks.measured import best_us

    out = {}
    k, n, m = 1024, 512, 128
    for cfg in cfgs:
        bm, bn, bk = autotune.ensure_tuned(m, n, k, cfg, dtype=jnp.float32)
        w = random_nm_matrix(jax.random.PRNGKey(0), (k, n), cfg, axis=0)
        vals, idx = compress_nm(w, cfg, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
        y_ref = nm_matmul_ref(x, vals, idx, cfg)
        f = lambda: nm_spmm_pallas(  # noqa: E731
            x, vals, idx, cfg=cfg, block_m=bm, block_n=bn, block_k=bk,
            interpret=True)
        y = f().block_until_ready()
        us = best_us(f, repeats=3)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 1e-3, err
        out[(cfg.tag, "bf16")] = (us, err, (bm, bn, bk))

        # int8 family: its own autotune keys (value dtype int8), its own
        # timer.
        qbm, qbn, qbk = autotune.ensure_tuned(m, n, k, cfg, dtype=jnp.int8)
        scales = jnp.max(jnp.abs(vals), axis=0) / 127.0
        qvals = jnp.clip(jnp.round(vals / scales[None, :]), -127,
                         127).astype(jnp.int8)
        yq_ref = nm_matmul_q_ref(x, qvals, idx, scales, cfg)
        fq = lambda: nm_spmm_pallas_q(  # noqa: E731
            x, qvals, idx, scales, cfg=cfg, block_m=qbm, block_n=qbn,
            block_k=qbk, interpret=True)
        yq = fq().block_until_ready()
        us_q = best_us(fq, repeats=3)
        err_q = float(jnp.abs(yq - yq_ref).max())
        assert err_q < 1e-3, err_q
        out[(cfg.tag, "int8")] = (us_q, err_q, (qbm, qbn, qbk))
    return out


def main():
    rows = run()
    timed = timed_correctness()
    out = []
    for tag in ("2:4", "1:4"):
        for vtag in ("bf16", "int8"):
            fam = f"{tag}-{vtag}"
            dec = [r for r in rows if r[0] == fam and "decode" in r[1]]
            avg = float(np.mean([r[2] for r in dec]))
            us, _, block = timed[(tag, vtag)]
            print(f"tpu_kernel {fam}: decode-GEMM roofline speedup avg "
                  f"{avg:.2f}x (weight-bytes x"
                  f"{float(np.mean([r[3] for r in dec])):.2f})")
            out.append((f"tpu_kernel_{fam}_decode", us,
                        f"roofline_speedup={avg:.3f};block={block[0]}x"
                        f"{block[1]}x{block[2]}"))
    return out


if __name__ == "__main__":
    main()
