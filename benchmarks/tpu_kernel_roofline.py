"""Beyond-paper: roofline terms of the Pallas indexmac kernel vs dense
matmul on TPU v5e constants, over the paper's CNN GEMMs + transformer
projection GEMMs. Also times the interpret-mode kernel vs oracle on one
shape (correctness + a real measured number for the CSV).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.cnn_specs import resnet50_gemms
from repro.core.cost_model import tpu_dense_cost, tpu_indexmac_cost
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix
from repro.kernels import autotune
from repro.kernels.indexmac.kernel import nm_spmm_pallas
from repro.kernels.indexmac.ref import nm_matmul_ref

TRANSFORMER_GEMMS = [
    # (name, M=tokens, K, N) — decode-ish (small M) and prefill-ish (large M)
    ("yi_ffn_decode", 16, 4096, 11008),
    ("yi_ffn_prefill", 8192, 4096, 11008),
    ("dsv2_expert_decode", 64, 5120, 1536),
    ("chameleon_qkv_decode", 16, 8192, 10240),
]


def run(verbose=True):
    rows = []
    for cfg in (NMConfig(2, 4), NMConfig(1, 4)):
        for name, m, k, n in (
                [("r50_" + t, mm, kk, nn) for t, mm, kk, nn in
                 resnet50_gemms()[::12]] + TRANSFORMER_GEMMS):
            dense = tpu_dense_cost(m, k, n)
            sp = tpu_indexmac_cost(m, k, n, cfg)
            t_d = max(dense.t_mem(), dense.t_compute())
            t_s = max(sp.t_mem(), sp.t_compute())
            rows.append((cfg.tag, name, t_d / t_s,
                         sp.hbm_bytes / dense.hbm_bytes,
                         "mem" if sp.t_mem() > sp.t_compute() else "comp"))
            if verbose:
                print(f"  tpu {cfg.tag} {name:22s} bytes x"
                      f"{sp.hbm_bytes/dense.hbm_bytes:.2f} "
                      f"roofline speedup {t_d/t_s:.2f}x ({rows[-1][4]}-bound)")
    return rows


def timed_correctness():
    """Autotune the block triple for one shape, then time the winner
    (interpret mode on CPU: the number is a smoke signal, not a TPU
    measurement — the same sweep persists real timings on hardware)."""
    cfg = NMConfig(2, 4)
    k, n, m = 1024, 512, 128
    bm, bn, bk = autotune.ensure_tuned(m, n, k, cfg, dtype=jnp.float32)
    w = random_nm_matrix(jax.random.PRNGKey(0), (k, n), cfg, axis=0)
    vals, idx = compress_nm(w, cfg, axis=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    y_ref = nm_matmul_ref(x, vals, idx, cfg)
    f = lambda: nm_spmm_pallas(x, vals, idx, cfg=cfg, block_m=bm,  # noqa
                               block_n=bn, block_k=bk, interpret=True)
    y = f().block_until_ready()
    t0 = time.perf_counter()
    y = f().block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(y - y_ref).max())
    assert err < 1e-3, err
    return us, err, (bm, bn, bk)


def main():
    rows = run()
    us, err, block = timed_correctness()
    out = []
    for tag in ("2:4", "1:4"):
        dec = [r for r in rows if r[0] == tag and "decode" in r[1]]
        avg = float(np.mean([r[2] for r in dec]))
        print(f"tpu_kernel {tag}: decode-GEMM roofline speedup avg "
              f"{avg:.2f}x (weight-bytes x"
              f"{float(np.mean([r[3] for r in dec])):.2f})")
        out.append((f"tpu_kernel_{tag}_decode", us,
                    f"roofline_speedup={avg:.3f};block={block[0]}x"
                    f"{block[1]}x{block[2]}"))
    return out


if __name__ == "__main__":
    main()
