"""Paper Fig. 6: total memory-access reduction of 'Proposed' vs
'Row-Wise-SpMM'. Paper: -48% average @1:4, -65% average @2:4 (reduction is
larger at 2:4 because the baseline issues twice the per-nonzero B loads).
"""
from __future__ import annotations

from benchmarks.cnn_specs import CNNS
from repro.core.sparse_matmul import indexmac_traffic, rowwise_spmm_traffic
from repro.core.sparsity import NMConfig


def run():
    results = {}
    for cnn, fn in CNNS.items():
        layers = fn()
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            base = sum(rowwise_spmm_traffic(m, k, n, cfg).total
                       for _, m, k, n in layers)
            prop = sum(indexmac_traffic(m, k, n, cfg).total
                       for _, m, k, n in layers)
            results[(cnn, cfg.tag)] = 1 - prop / base
    return results


def main():
    res = run()
    out = []
    for tag, paper in (("1:4", 0.48), ("2:4", 0.65)):
        reds = [res[(c, tag)] for c in CNNS]
        avg = sum(reds) / len(reds)
        for c in CNNS:
            print(f"fig6 {c:12s} {tag}: -{100*res[(c, tag)]:.0f}%")
        print(f"fig6 average {tag}: -{100*avg:.0f}% (paper: -{100*paper:.0f}%)")
        out.append((f"fig6_avg_{tag}", 0.0, f"reduction={avg:.3f}"))
    return out


if __name__ == "__main__":
    main()
