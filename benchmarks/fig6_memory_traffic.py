"""Paper Fig. 6: total memory-access reduction of 'Proposed' vs
'Row-Wise-SpMM'. Paper: -48% average @1:4, -65% average @2:4 (reduction is
larger at 2:4 because the baseline issues twice the per-nonzero B loads).

``measured_main()`` replaces the idealized per-layer byte accounting with
the *actual dispatch geometry*: for every layer it resolves the block
triple the real ``nm_matmul`` dispatch would use (autotune cache /
default) and the resulting ``PadPlan``, and reports HBM bytes at the
padded shape next to the logical shape — the padding byte overhead the
idealized model hides — plus the per-layer analytic traffic reduction as
the cross-check column.
"""
from __future__ import annotations

from benchmarks.cnn_specs import CNNS
from repro.core.sparse_matmul import indexmac_traffic, rowwise_spmm_traffic
from repro.core.sparsity import NMConfig


def run():
    results = {}
    for cnn, fn in CNNS.items():
        layers = fn()
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            base = sum(rowwise_spmm_traffic(m, k, n, cfg).total
                       for _, m, k, n in layers)
            prop = sum(indexmac_traffic(m, k, n, cfg).total
                       for _, m, k, n in layers)
            results[(cnn, cfg.tag)] = 1 - prop / base
    return results


def measured_main(smoke: bool = False):
    """Dispatch-plan byte accounting per layer -> (rows, layer records)."""
    import jax.numpy as jnp

    from benchmarks.fig5_cnn_totals import MEASURED_CNNS
    from benchmarks.measured import layer_subset
    from repro.core.cost_model import tpu_indexmac_cost
    from repro.kernels import autotune
    from repro.kernels.padding import plan_nm_matmul

    rows, layer_rows = [], []
    for cnn in MEASURED_CNNS:
        layers = layer_subset(CNNS[cnn](), smoke)
        for cfg in (NMConfig(1, 4), NMConfig(2, 4)):
            overheads, reds = [], []
            for name, m, k, n in layers:
                k_run = -(-k // cfg.m) * cfg.m
                # forward orientation: patches (n, k) @ weight (k, m)
                block = autotune.best_block(n, m, k_run, cfg, jnp.float32)
                plan = plan_nm_matmul(n, m, k_run, cfg, tuple(block))
                logical = tpu_indexmac_cost(n, k_run, m, cfg).hbm_bytes
                padded = (tpu_indexmac_cost(plan.pm, plan.pk, plan.pn,
                                            cfg).hbm_bytes
                          if plan is not None else logical)
                red = 1 - (indexmac_traffic(m, k_run, n, cfg).total
                           / rowwise_spmm_traffic(m, k_run, n, cfg).total)
                overheads.append(padded / logical)
                reds.append(red)
                layer_rows.append({
                    "layer": f"{cnn}_{name}", "fig": "fig6", "nm": cfg.tag,
                    "m": m, "k": k, "n": n, "k_run": k_run, "smoke": smoke,
                    "block": list(plan.block) if plan else None,
                    "padded": list(plan.padded_shape) if plan else None,
                    "hbm_bytes_logical": logical,
                    "hbm_bytes_padded": padded,
                    "pad_byte_overhead": round(padded / logical, 4),
                    "traffic_reduction": round(red, 4),
                })
            avg_ov = sum(overheads) / len(overheads)
            avg_red = sum(reds) / len(reds)
            print(f"fig6-measured {cnn:12s} {cfg.tag}: traffic -"
                  f"{100 * avg_red:.0f}%, dispatch-plan pad overhead x"
                  f"{avg_ov:.3f} ({len(overheads)} layers)")
            rows.append((
                f"fig6_measured_{cnn}_{cfg.tag}", 0.0,
                f"reduction={avg_red:.3f};pad_overhead={avg_ov:.3f};"
                f"layers={len(overheads)}"))
    return rows, layer_rows


def main():
    res = run()
    out = []
    for tag, paper in (("1:4", 0.48), ("2:4", 0.65)):
        reds = [res[(c, tag)] for c in CNNS]
        avg = sum(reds) / len(reds)
        for c in CNNS:
            print(f"fig6 {c:12s} {tag}: -{100*res[(c, tag)]:.0f}%")
        print(f"fig6 average {tag}: -{100*avg:.0f}% (paper: -{100*paper:.0f}%)")
        out.append((f"fig6_avg_{tag}", 0.0, f"reduction={avg:.3f}"))
    return out


if __name__ == "__main__":
    main()
