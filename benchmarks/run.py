"""Benchmark harness: one entry per paper table/figure + beyond-paper TPU
kernel roofline. Prints ``name,us_per_call,derived`` CSV rows and writes
a machine-readable ``BENCH_results.json`` next to the CSV stream:

  {"schema": 2,
   "mode":    {"measured": bool, "smoke": bool},
   "rows":    [{"name", "us_per_call", "derived"}, ...],
   "layers":  [{"layer", "fig", "nm", "family", "m", "k", "n",
                "t_pallas_us", "t_rowwise_us", "t_gather_us",
                "speedup_vs_rowwise", "analytic_speedup", ...}, ...],
   "kernels": [{"nm", "family" (bf16|int8), "gemm", "m", "k", "n",
                "hbm_bytes", "dense_hbm_bytes", "bytes_vs_dense",
                "roofline_speedup_vs_dense", "bound"}, ...]}

``--measured`` additionally runs the fig4/5/6 measured modes — the real
padded Pallas ``nm_matmul`` dispatch timed against the row-wise / gather
baselines on the paper's CNN layer shapes (``--smoke`` sub-samples the
layers for CI). ``--serve`` runs ``benchmarks/serve_bench.py`` — serving
throughput / TTFT / inter-token latency for dense vs 2:4 vs int8-2:4
engines on one device and a forced-8-device host mesh. Either flag also
emits a ``bench_calibration`` row (a fixed Pallas kernel call) that
``benchmarks/check_regression.py`` uses as the uniform-slowdown guard
when gating against ``benchmarks/BENCH_baseline.json`` (per-row gating
is share-normalized; see that script's docstring).

Refresh the checked-in baseline after an intentional perf change (cold
autotune cache — CI runs cold too, so block choices match; keep all
flags so the baseline is a superset of every CI lane's rows):

  JAX_PLATFORMS=cpu PYTHONPATH=src:. REPRO_AUTOTUNE_CACHE=$(mktemp -u) \\
      REPRO_BENCH_JSON=benchmarks/BENCH_baseline.json \\
      python benchmarks/run.py --measured --smoke --serve
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

OUT_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_results.json")


def _dedupe_layers(layer_rows: list[dict]) -> list[dict]:
    """fig4 and fig5 share cached measurements (same layer/nm/family ->
    same numbers); keep the first record of each. fig6's records carry
    no ``family`` key, so they never collide with the timed ones."""
    seen, out = set(), []
    for r in layer_rows:
        key = (r["layer"], r["nm"], r.get("family"))
        if key not in seen:
            seen.add(key)
            out.append(r)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--measured", action="store_true",
                    help="also time the real Pallas dispatch on the CNN "
                         "layer GEMMs (fig4/5/6 measured modes)")
    ap.add_argument("--smoke", action="store_true",
                    help="sub-sample layers / cap the pixel dim so the "
                         "measured sweep fits the CI budget")
    ap.add_argument("--serve", action="store_true",
                    help="also run the serving throughput bench "
                         "(benchmarks/serve_bench.py; spawns 1-device and "
                         "forced-8-device subprocesses)")
    args = ap.parse_args(argv)

    from benchmarks import (  # noqa: PLC0415
        fig4_resnet_layers,
        fig5_cnn_totals,
        fig6_memory_traffic,
        tpu_kernel_roofline,
    )

    rows = []
    for mod in (fig4_resnet_layers, fig5_cnn_totals, fig6_memory_traffic,
                tpu_kernel_roofline):
        t0 = time.perf_counter()
        out = mod.main()
        dt = (time.perf_counter() - t0) * 1e6
        for name, us, derived in out:
            rows.append((name, us if us else dt, derived))

    layer_rows: list[dict] = []
    if args.measured or args.serve:
        from benchmarks import measured  # noqa: PLC0415

        rows.append(measured.calibration_row())
    if args.measured:
        for mod in (fig4_resnet_layers, fig5_cnn_totals,
                    fig6_memory_traffic):
            mrows, mlayers = mod.measured_main(smoke=args.smoke)
            rows += mrows
            layer_rows += mlayers
        layer_rows = _dedupe_layers(layer_rows)
    serve_metrics: dict = {}
    if args.serve:
        from benchmarks import serve_bench  # noqa: PLC0415

        srows, serve_metrics = serve_bench.bench_rows_and_metrics(
            smoke=args.smoke)
        rows += srows

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    payload = {
        "schema": 2,
        "mode": {"measured": args.measured, "smoke": args.smoke,
                 "serve": args.serve},
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
        "layers": layer_rows,
        "kernels": tpu_kernel_roofline.kernel_records(),
    }
    if serve_metrics:
        # per-device-count obs metrics snapshots from the serve bench's
        # untimed obs-on pass (the timed rows stay obs-off)
        payload["serve_metrics"] = serve_metrics
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    # stderr: stdout from the CSV header down is machine-consumed
    print(f"wrote {OUT_JSON} ({len(payload['rows'])} rows, "
          f"{len(payload['layers'])} layer records, "
          f"{len(payload['kernels'])} kernel records)", file=sys.stderr)


if __name__ == "__main__":
    main()
