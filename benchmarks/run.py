"""Benchmark harness: one entry per paper table/figure + beyond-paper TPU
kernel roofline. Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (  # noqa: PLC0415
        fig4_resnet_layers,
        fig5_cnn_totals,
        fig6_memory_traffic,
        tpu_kernel_roofline,
    )

    rows = []
    for mod in (fig4_resnet_layers, fig5_cnn_totals, fig6_memory_traffic,
                tpu_kernel_roofline):
        t0 = time.perf_counter()
        out = mod.main()
        dt = (time.perf_counter() - t0) * 1e6
        for name, us, derived in out:
            rows.append((name, us if us else dt, derived))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
