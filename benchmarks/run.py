"""Benchmark harness: one entry per paper table/figure + beyond-paper TPU
kernel roofline. Prints ``name,us_per_call,derived`` CSV rows and writes
a machine-readable ``BENCH_results.json`` next to the CSV stream:

  {"schema": 1,
   "rows":    [{"name", "us_per_call", "derived"}, ...],
   "kernels": [{"nm", "family" (bf16|int8), "gemm", "m", "k", "n",
                "hbm_bytes", "dense_hbm_bytes", "bytes_vs_dense",
                "roofline_speedup_vs_dense", "bound"}, ...]}

The ``kernels`` section carries the per-kernel byte/speedup accounting
(both value families — the int8 QNMWeight path included), so the bench
trajectory is diffable across commits; CI's bench-smoke job uploads the
file as an artifact.
"""
from __future__ import annotations

import json
import os
import sys
import time

OUT_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_results.json")


def main() -> None:
    from benchmarks import (  # noqa: PLC0415
        fig4_resnet_layers,
        fig5_cnn_totals,
        fig6_memory_traffic,
        tpu_kernel_roofline,
    )

    rows = []
    for mod in (fig4_resnet_layers, fig5_cnn_totals, fig6_memory_traffic,
                tpu_kernel_roofline):
        t0 = time.perf_counter()
        out = mod.main()
        dt = (time.perf_counter() - t0) * 1e6
        for name, us, derived in out:
            rows.append((name, us if us else dt, derived))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    payload = {
        "schema": 1,
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
        "kernels": tpu_kernel_roofline.kernel_records(),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    # stderr: stdout from the CSV header down is machine-consumed
    print(f"wrote {OUT_JSON} ({len(payload['rows'])} rows, "
          f"{len(payload['kernels'])} kernel records)", file=sys.stderr)


if __name__ == "__main__":
    main()
