"""Measured-mode harness for the paper-figure benchmarks.

The analytic ``VectorCoreModel`` reproduces the paper's *simulated*
RISC-V numbers; this module measures the repo's *real* execution paths
on the same CNN layer GEMMs. Each layer ``A(M=C_out, K) x B(K, N=H*W)``
is run in the conv-forward orientation ``SparseConv2D`` executes —
patches ``(N_pix, K)`` @ sparse weight ``(K, C_out)`` — through:

* the padded Pallas ``nm_matmul`` dispatch (``KernelPolicy "force"``;
  interpret mode on CPU, compiled Mosaic on real TPUs) — the routing is
  preflighted with ``api.explain_dispatch`` so a silent fallback to the
  dense reference fails loudly rather than producing a bogus
  "measurement";
* the Row-Wise-SpMM baseline (Alg. 2 semantic model, XLA);
* the gather-port baseline (``indexmac_gather`` dispatch family).

Results are cached per (shape, pattern, family) within the process so
fig4 and fig5 share layer measurements instead of re-timing them.

Smoke mode (CI) subsamples the layer list and caps N = H*W so the whole
sweep stays within the bench-smoke budget; rows carry ``smoke: true``
and the regression gate only compares rows of the same mode.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import api
from repro.core.cost_model import VectorCoreModel
from repro.core.sparse_matmul import rowwise_spmm
from repro.core.sparsity import NMConfig, compress_nm, random_nm_matrix

SMOKE_MAX_PIX = 256  # cap on N = H_out*W_out per layer in smoke mode
SMOKE_LAYER_STRIDE = 12  # every 12th layer in smoke mode

_CACHE: dict = {}  # (m, k, n, tag, quantized) -> measured row


def layer_subset(
    layers: list[tuple[str, int, int, int]], smoke: bool
) -> list[tuple[str, int, int, int]]:
    """Smoke mode: subsample layers and cap the pixel dim (deterministic,
    so row names line up with the checked-in baseline)."""
    if not smoke:
        return list(layers)
    return [(name, m, k, min(n, SMOKE_MAX_PIX))
            for name, m, k, n in layers[::SMOKE_LAYER_STRIDE]]


def best_us(fn, *, repeats: int = 2) -> float:
    """Best-of-``repeats`` wall time of ``fn().block_until_ready()``, us."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        ts.append((time.perf_counter() - t0) * 1e6)
    return min(ts)


def measure_layer(
    name: str,
    m: int,
    k: int,
    n: int,
    cfg: NMConfig,
    *,
    quantized: bool = False,
    smoke: bool = False,
    repeats: int = 3,
    seed: int = 0,
) -> dict:
    """Measure one paper layer GEMM on the real kernel paths.

    ``(m, k, n)`` is the paper's table entry (M=C_out, K=C_in*kh*kw,
    N=H_out*W_out). K not divisible by the sparsity block (e.g. the
    stem's 147) is rounded up to the next block multiple (``k_run``).
    """
    key = (m, k, n, cfg.tag, quantized)
    if key in _CACHE:
        row = dict(_CACHE[key])
        row["layer"] = name
        return row
    k_run = -(-k // cfg.m) * cfg.m
    w = random_nm_matrix(jax.random.PRNGKey(seed), (k_run, m), cfg, axis=0)
    sw = api.sparsify(w, cfg, kernel_policy="force")
    if quantized:
        sw = api.quantize(sw)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, k_run),
                          dtype=jnp.float32)

    # preflight the routing: the public dry-run says which impl the real
    # call will take, before any compile time is spent.
    rec = api.explain_dispatch((n, k_run), sw, dtype=jnp.float32)
    if not (rec.impl.startswith("pallas")
            and rec.backend in ("tpu", "gpu")):
        raise RuntimeError(
            f"measured mode requires the Pallas dispatch; layer {name} "
            f"({m}x{k_run}x{n}, {cfg.tag}) would route to "
            f"{rec.impl} on backend {rec.backend}: {rec.reason}")
    f_pallas = jax.jit(lambda x, w: api.nm_matmul(x, w))
    y = f_pallas(x, sw).block_until_ready()  # compile + warm
    t_pallas = best_us(lambda: f_pallas(x, sw), repeats=repeats)

    # Row-Wise-SpMM baseline (Alg. 2), paper orientation: A (m, k) sparse.
    a_vals, a_idx = compress_nm(api.densify(sw).T.astype(jnp.float32),
                                cfg, axis=1)
    bt = x.T
    f_row = jax.jit(lambda v, i, b: rowwise_spmm(v, i, b, cfg))
    c2 = f_row(a_vals, a_idx, bt).block_until_ready()
    err = float(jnp.max(jnp.abs(c2.T - y)))
    scale = float(jnp.max(jnp.abs(c2))) or 1.0
    if err / scale > 1e-3:
        raise RuntimeError(
            f"kernel/baseline mismatch on {name}: rel err {err / scale:.2e}")
    t_row = best_us(lambda: f_row(a_vals, a_idx, bt), repeats=repeats)

    # gather-port baseline (its own dispatch family; XLA ref when the
    # shape isn't tileable for the gather kernel).
    gw = api.NMWeight(vals=a_vals, idx=a_idx, nm=cfg, axis=1,
                      kernel_policy=api.KernelPolicy("auto"))
    grec = api.explain_dispatch(bt.shape, gw)
    f_gather = jax.jit(lambda w, b: api.indexmac_gather(w, b))
    f_gather(gw, bt).block_until_ready()
    t_gather = best_us(lambda: f_gather(gw, bt), repeats=repeats)

    row = {
        "layer": name,
        "nm": cfg.tag,
        "family": "int8" if quantized else "f32",
        "m": m, "k": k, "n": n, "k_run": k_run,
        "smoke": smoke,
        "pallas_impl": rec.impl,
        "block": list(rec.block) if rec.block else None,
        "padded": list(rec.padded) if rec.padded else None,
        "gather_impl": grec.impl,
        "t_pallas_us": round(t_pallas, 1),
        "t_rowwise_us": round(t_row, 1),
        "t_gather_us": round(t_gather, 1),
        "speedup_vs_rowwise": round(t_row / t_pallas, 3),
        "speedup_vs_gather": round(t_gather / t_pallas, 3),
        "analytic_speedup": round(
            VectorCoreModel().speedup(m, k_run, n, cfg), 3),
    }
    _CACHE[key] = row
    return row


def calibration_row() -> tuple[str, float, str]:
    """A fixed kernel-path timing for the regression gate's *uniform-
    slowdown guard*: per-row gating is share-normalized (each row over
    the gated total, so machine speed cancels without this row), but a
    slowdown hitting every kernel path equally is invisible to shares —
    ``check_regression.py`` catches that case by comparing the gated
    total over this row, at a deliberately loose threshold. Runs the
    same execution regime as the gated rows (the padded Pallas dispatch,
    interpret mode on CPU), not a dense XLA matmul whose throughput
    scales differently with machine speed."""
    cfg = NMConfig(2, 4)
    w = random_nm_matrix(jax.random.PRNGKey(0), (1024, 256), cfg, axis=0)
    sw = api.sparsify(w, cfg, kernel_policy="force")
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
    f = jax.jit(lambda x, w: api.nm_matmul(x, w))
    f(x, sw).block_until_ready()
    us = best_us(lambda: f(x, sw), repeats=5)
    return ("bench_calibration", us, "nm_matmul_pallas_256x1024x256_2:4")
